"""Shape/hyperparameter registry for every TM workload in the paper.

Each config fixes the static shapes baked into one pair of AOT artifacts
(`tm_infer_<name>.hlo.txt`, `tm_train_<name>.hlo.txt`).  The rust runtime
reads `artifacts/manifest.json` (emitted by aot.py) to know these shapes.

Dataset dimensionalities mirror the paper's workloads (Table 2 / Fig 9);
the data itself is synthetic — see DESIGN.md §Substitutions.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class TMConfig:
    name: str
    features: int          # Boolean features F (after booleanization)
    classes: int           # M
    clauses: int           # C, clauses per class (even; polarity alternates +,-)
    T: int                 # class-sum clamp threshold (feedback target)
    s: float               # specificity (Type I decrement probability 1/s)
    train_batch: int = 32  # samples consumed per AOT train step (lax.scan)

    @property
    def literals(self) -> int:
        """L = 2F: literal 2f is feature f, literal 2f+1 is its complement."""
        return 2 * self.features

    @property
    def total_clauses(self) -> int:
        return self.classes * self.clauses

    @property
    def n_states(self) -> int:
        """TA states per action side; state >= N means Include."""
        return 128

    def to_manifest(self) -> dict:
        d = asdict(self)
        d["literals"] = self.literals
        d["total_clauses"] = self.total_clauses
        d["n_states"] = self.n_states
        return d


# The paper's workloads.  Feature counts follow the real datasets'
# dimensionality after the booleanization used by MATADOR/REDRESS
# (thermometer for continuous sensor channels, threshold for images).
CONFIGS: dict[str, TMConfig] = {
    c.name: c
    for c in [
        # Tiny config for the quickstart example and fast tests.  T must be
        # attainable (< clauses/2 = max positive votes) or feedback never
        # freezes and every clause collapses onto the same attractor.
        TMConfig("quickstart", features=16, classes=2, clauses=10, T=4, s=3.0),
        # Table 2 workloads (UCI-shaped).
        TMConfig("emg", features=64, classes=6, clauses=100, T=20, s=3.0),
        TMConfig("har", features=256, classes=6, clauses=100, T=20, s=5.0),
        TMConfig("gesture", features=96, classes=5, clauses=80, T=15, s=3.5),
        TMConfig("sensorless", features=96, classes=11, clauses=100, T=20, s=4.0),
        TMConfig("gasdrift", features=256, classes=6, clauses=100, T=20, s=5.0),
        # Fig 9 / Table 1 workloads (MATADOR comparison).
        TMConfig("mnist", features=784, classes=10, clauses=200, T=50, s=10.0),
        TMConfig("cifar2", features=512, classes=2, clauses=300, T=40, s=8.0),
        TMConfig("kws6", features=350, classes=6, clauses=150, T=30, s=6.0),
    ]
}


def get(name: str) -> TMConfig:
    return CONFIGS[name]
