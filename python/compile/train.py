"""L2: vanilla Tsetlin Machine training (Type I / Type II feedback).

This is the algorithm the paper's Model Training Node runs (Fig 8,
citing [8, 12, 21]).  It is written as a jittable ``train_step`` that
consumes one batch of booleanized samples and returns the updated TA
state; aot.py lowers it per config so the *rust* coordinator can retrain
on-field through PJRT with Python nowhere in the loop.

Semantics follow Granmo's vanilla TM:

- TA state in [0, 2N); action = Include iff state >= N.
- Per sample, the target class y and one uniformly-sampled other class
  receive feedback, gated per clause with probability (T - clamp(s_y))/2T
  and (T + clamp(s_neg))/2T respectively.
- Type I (combats false negatives; to pol=+1 clauses of y, pol=-1 of neg):
    clause==1 & literal==1 -> state+1 (boost-true-positive, deterministic)
    clause==1 & literal==0 -> state-1 with prob 1/s
    clause==0             -> state-1 with prob 1/s
- Type II (combats false positives; the opposite-polarity clauses):
    clause==1 & literal==0 & Exclude -> state+1 (deterministic)

The batch is consumed sequentially with ``lax.scan`` — exact vanilla
semantics, no batch-averaging approximation.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import TMConfig


def _clause_outputs_train(include: jnp.ndarray, x_lit: jnp.ndarray) -> jnp.ndarray:
    """Training-semantics clause outputs for one class: bool[C]."""
    # include: bool[C, L]; empty clause -> 1 during training.
    return jnp.all(jnp.logical_or(~include, x_lit[None, :].astype(bool)), axis=1)


def _class_feedback(ta_cls, x_lit, sign, key, cfg: TMConfig):
    """Feedback deltas for one class slice.

    Args:
      ta_cls: i32[C, L] TA states of the class receiving feedback.
      x_lit:  i32[L] literal values of the sample.
      sign:   +1 if this is the target class, -1 if the negative class.
    Returns:
      i32[C, L] new TA states.
    """
    n = cfg.n_states
    c = cfg.clauses
    include = ta_cls >= n
    out = _clause_outputs_train(include, x_lit)  # bool[C]
    pol = 1 - 2 * (jnp.arange(c, dtype=jnp.int32) % 2)  # +1/-1 alternating
    votes = jnp.sum(pol * out.astype(jnp.int32))
    clamped = jnp.clip(votes, -cfg.T, cfg.T).astype(jnp.float32)
    # Target: push sum up toward T; negative class: push down toward -T.
    p = (cfg.T - sign * clamped) / (2.0 * cfg.T)

    k_gate, k_dec = jax.random.split(key)
    gate = jax.random.uniform(k_gate, (c,)) < p  # per-clause feedback gate
    dec = jax.random.uniform(k_dec, (c, cfg.literals)) < (1.0 / cfg.s)

    x = x_lit.astype(bool)[None, :]  # [1, L]
    out_b = out[:, None]  # [C, 1]

    # Type I deltas (applied to clauses whose polarity == sign).
    reward = jnp.logical_and(out_b, x)  # clause 1, literal 1 -> +1
    punish = jnp.logical_and(dec, ~reward)  # elsewhere: -1 w.p. 1/s
    type1 = reward.astype(jnp.int32) - punish.astype(jnp.int32)

    # Type II deltas (applied to clauses whose polarity == -sign).
    type2 = jnp.logical_and(
        jnp.logical_and(out_b, ~x), ~include
    ).astype(jnp.int32)

    is_type1 = (pol == sign)[:, None]  # [C, 1]
    delta = jnp.where(is_type1, type1, type2)
    delta = jnp.where(gate[:, None], delta, 0)
    return jnp.clip(ta_cls + delta, 0, 2 * n - 1)


def make_train_step(cfg: TMConfig):
    """Build the jittable per-batch train step for a config.

    Signature (all static shapes, AOT-friendly):
      ta_state i32[M, C, L], x_lit i32[B, L], ys i32[B], seed i32[2]
        -> i32[M, C, L]
    """

    def sample_update(ta, xyk):
        x_lit, y, key = xyk
        k_neg, k_t, k_n = jax.random.split(key, 3)
        # Uniform over the other M-1 classes.
        neg = (y + 1 + jax.random.randint(k_neg, (), 0, cfg.classes - 1)) % cfg.classes
        ta_y = _class_feedback(
            jax.lax.dynamic_index_in_dim(ta, y, axis=0, keepdims=False),
            x_lit, +1, k_t, cfg,
        )
        ta = jax.lax.dynamic_update_index_in_dim(ta, ta_y, y, axis=0)
        ta_n = _class_feedback(
            jax.lax.dynamic_index_in_dim(ta, neg, axis=0, keepdims=False),
            x_lit, -1, k_n, cfg,
        )
        ta = jax.lax.dynamic_update_index_in_dim(ta, ta_n, neg, axis=0)
        return ta, None

    def train_step(ta_state, x_lit, ys, seed):
        key = jax.random.wrap_key_data(
            seed.astype(jnp.uint32), impl="threefry2x32"
        )
        keys = jax.random.split(key, cfg.train_batch)
        ta, _ = jax.lax.scan(sample_update, ta_state, (x_lit, ys, keys))
        return ta

    return train_step


def init_ta_state(cfg: TMConfig, key) -> jnp.ndarray:
    """TA states start on the Exclude side of the boundary (N-1 or N-2)."""
    shape = (cfg.classes, cfg.clauses, cfg.literals)
    return cfg.n_states - 1 - jax.random.bernoulli(key, 0.5, shape).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_accuracy(cfg: TMConfig, ta_state, x_lit, ys):
    """Dense-forward accuracy over a test set (test/bench helper, not AOT)."""
    from .model import include_mask_from_state
    from .kernels import ref

    include = (ta_state >= cfg.n_states).reshape(cfg.total_clauses, cfg.literals)

    def one(x):
        out = ref.clause_eval_dense_ref(x, include, training=False)
        pol = 1 - 2 * (jnp.arange(cfg.clauses, dtype=jnp.int32) % 2)
        sums = (pol[None, :] * out.reshape(cfg.classes, cfg.clauses)).sum(axis=1)
        return jnp.argmax(sums)

    preds = jax.vmap(one)(x_lit)
    return jnp.mean((preds == ys).astype(jnp.float32))
