"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 rust crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per config this emits:
  artifacts/tm_infer_<name>.hlo.txt   (inc_mask u32[K,L], xs u32[L])
                                        -> (sums i32[M,32], preds i32[32])
  artifacts/tm_train_<name>.hlo.txt   (ta i32[M,C,L], x i32[B,L],
                                        ys i32[B], seed i32[2]) -> (ta',)
plus artifacts/manifest.json describing every artifact's shapes so the
rust side never hard-codes them.

Usage: python -m compile.aot --outdir ../artifacts [--configs a,b,...]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, TMConfig
from . import model as M
from . import train as T


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned on parse)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_infer(cfg: TMConfig) -> str:
    def infer(inc_mask, xs_packed):
        return M.tm_infer_packed(
            inc_mask, xs_packed, classes=cfg.classes, clauses=cfg.clauses
        )

    inc = jax.ShapeDtypeStruct((cfg.total_clauses, cfg.literals), jnp.uint32)
    xs = jax.ShapeDtypeStruct((cfg.literals,), jnp.uint32)
    return to_hlo_text(jax.jit(infer).lower(inc, xs))


def lower_train(cfg: TMConfig) -> str:
    step = T.make_train_step(cfg)
    ta = jax.ShapeDtypeStruct((cfg.classes, cfg.clauses, cfg.literals), jnp.int32)
    x = jax.ShapeDtypeStruct((cfg.train_batch, cfg.literals), jnp.int32)
    ys = jax.ShapeDtypeStruct((cfg.train_batch,), jnp.int32)
    seed = jax.ShapeDtypeStruct((2,), jnp.int32)
    return to_hlo_text(jax.jit(step).lower(ta, x, ys, seed))


def manifest_entry(cfg: TMConfig) -> dict:
    d = cfg.to_manifest()
    d["infer_hlo"] = f"tm_infer_{cfg.name}.hlo.txt"
    d["train_hlo"] = f"tm_train_{cfg.name}.hlo.txt"
    d["infer_args"] = {
        "inc_mask": ["u32", [cfg.total_clauses, cfg.literals]],
        "xs_packed": ["u32", [cfg.literals]],
    }
    d["infer_outs"] = {
        "class_sums": ["i32", [cfg.classes, 32]],
        "preds": ["i32", [32]],
    }
    d["train_args"] = {
        "ta_state": ["i32", [cfg.classes, cfg.clauses, cfg.literals]],
        "x_lit": ["i32", [cfg.train_batch, cfg.literals]],
        "ys": ["i32", [cfg.train_batch]],
        "seed": ["i32", [2]],
    }
    return d


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(CONFIGS))
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    names = [n for n in args.configs.split(",") if n]
    manifest = {"configs": {}}
    for name in names:
        cfg = CONFIGS[name]
        infer_text = lower_infer(cfg)
        train_text = lower_train(cfg)
        entry = manifest_entry(cfg)
        for key, text in (("infer_hlo", infer_text), ("train_hlo", train_text)):
            path = os.path.join(args.outdir, entry[key])
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
        manifest["configs"][name] = entry

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")

    # Flat TSV twin for the rust side (offline environment: no serde).
    tsv = os.path.join(args.outdir, "manifest.tsv")
    cols = [
        "name", "features", "classes", "clauses", "T", "s",
        "train_batch", "n_states", "infer_hlo", "train_hlo",
    ]
    with open(tsv, "w") as f:
        f.write("\t".join(cols) + "\n")
        for name in names:
            e = manifest["configs"][name]
            f.write("\t".join(str(e[c]) for c in cols) + "\n")
    print(f"wrote {tsv}")


if __name__ == "__main__":
    main()
