"""L2: the Tsetlin Machine compute graph, calling the L1 Pallas kernels.

Two entry points get AOT-lowered per config (see aot.py):

- ``tm_infer_packed`` — the deployment inference graph.  Takes the
  include masks (the runtime-tunable "model") and one bit-sliced batch of
  32 datapoints; returns class sums and predictions.  This is what the
  rust runtime executes via PJRT as the golden model for the accelerator
  simulator.
- ``tm_forward_dense`` — per-sample forward used by the trainer.

The "model" crossing the rust<->HLO boundary is the include-mask tensor
(u32[K, L]), i.e. exactly the information content of the paper's
compressed instruction stream; ta_state (i32[M, C, L]) only appears in
the training artifact.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.clause_eval import clause_eval_packed
from .kernels.class_sum import class_sums
from .kernels import ref

ALL_ONES = jnp.uint32(0xFFFFFFFF)


def include_mask_from_state(ta_state: jnp.ndarray, n_states: int) -> jnp.ndarray:
    """u32[M*C, L] include mask from i32[M, C, L] TA state.

    A TA whose state is in the upper half (>= N) acts Include (Fig 2).
    """
    m, c, l = ta_state.shape
    inc = ta_state >= n_states
    return jnp.where(inc, ALL_ONES, jnp.uint32(0)).reshape(m * c, l)


@functools.partial(jax.jit, static_argnames=("classes", "clauses", "block_k"))
def tm_infer_packed(
    inc_mask: jnp.ndarray,
    xs_packed: jnp.ndarray,
    *,
    classes: int,
    clauses: int,
    block_k: int = 256,
):
    """Inference over one bit-sliced batch of 32 datapoints.

    Args:
      inc_mask:  u32[M*C, L] — the runtime-tunable model.
      xs_packed: u32[L] — bit b of word l = literal l of datapoint b.

    Returns:
      (class_sums i32[M, 32], preds i32[32])
    """
    words = clause_eval_packed(xs_packed, inc_mask, block_k=block_k)
    sums = class_sums(words, classes, clauses)
    preds = jnp.argmax(sums, axis=0).astype(jnp.int32)
    return sums, preds


def tm_forward_dense(include: jnp.ndarray, x_lit: jnp.ndarray, *, classes: int, clauses: int, training: bool):
    """Per-sample forward with dense bool literals (training semantics).

    Args:
      include: bool[M*C, L]
      x_lit:   i32/bool[L]
    Returns:
      (clause_out i32[M*C], class_sums i32[M])
    """
    out = ref.clause_eval_dense_ref(x_lit, include, training=training)
    # Polarity restarts at +1 per class (matches the ISA and class_sum kernel).
    pol = 1 - 2 * (jnp.arange(clauses, dtype=jnp.int32) % 2)
    sums = (pol[None, :] * out.reshape(classes, clauses)).sum(axis=1)
    return out, sums


def literals_from_features(x_feat: jnp.ndarray) -> jnp.ndarray:
    """Interleave features with complements: literal 2f = x_f, 2f+1 = ~x_f.

    Matches the ISA's TA ordering (rust/src/isa): offsets walk TAs in
    (feature, complement) interleaved order.

    Args:
      x_feat: i32/bool[..., F] in {0,1}
    Returns:
      i32[..., 2F]
    """
    x = x_feat.astype(jnp.int32)
    return jnp.stack([x, 1 - x], axis=-1).reshape(*x.shape[:-1], 2 * x.shape[-1])
