"""L1 Pallas kernel: bit-sliced packed clause evaluation.

This is the paper's compute hot-spot (Fig 4.5/4.6): AND together the
packed u32 literal words selected by each clause's Include set, over a
32-datapoint bit-sliced batch.  On the eFPGA this is the literal-select +
clause-output-register datapath; here it is a VPU-style u32 lane kernel.

Hardware adaptation (DESIGN.md §2): the eFPGA's BRAM-resident feature
memory maps to the kernel's VMEM block of ``xs_packed`` (replicated per
grid step); the 32-bit clause output register file maps to a u32 lane
vector.  Tiling is over clauses (grid dim 0) with the full literal row in
VMEM — for the largest config (MNIST: 256x1568 u32 = 1.6 MiB/block) this
fits comfortably in a 16 MiB VMEM budget; see DESIGN.md §7 for the block
sweep.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the rust runtime can
execute the artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ALL_ONES = jnp.uint32(0xFFFFFFFF)

# Clause rows per grid step.  Chosen so block VMEM stays < ~2 MiB for the
# largest config while keeping the grid small for interpret-mode speed.
DEFAULT_BLOCK_K = 256


def _clause_eval_kernel(x_ref, inc_ref, out_ref):
    """One grid step: clause outputs for a [block_k, L] include block.

    x_ref:   u32[L]          packed literals (same block every step)
    inc_ref: u32[block_k, L] include masks (0 or 0xFFFFFFFF)
    out_ref: u32[block_k]    clause output words
    """
    lits = x_ref[...]
    inc = inc_ref[...]
    # Exclude => neutral all-ones; Include => the literal word.
    masked = lits[None, :] | ~inc
    words = jnp.bitwise_and.reduce(masked, axis=1)
    # Empty clause (no includes anywhere in the row) outputs 0 at inference.
    nonempty = jnp.bitwise_or.reduce(inc, axis=1) != jnp.uint32(0)
    out_ref[...] = jnp.where(nonempty, words, jnp.uint32(0))


@functools.partial(jax.jit, static_argnames=("block_k",))
def clause_eval_packed(
    xs_packed: jnp.ndarray, inc_mask: jnp.ndarray, block_k: int = DEFAULT_BLOCK_K
) -> jnp.ndarray:
    """Pallas clause evaluation over a bit-sliced batch.

    Args:
      xs_packed: u32[L] — bit b of word l = literal l of datapoint b.
      inc_mask:  u32[K, L] — 0xFFFFFFFF where TA is Include, else 0.
      block_k:   clause rows per grid step.

    Returns:
      u32[K] clause output words (bit b = clause output for datapoint b).
    """
    k, l = inc_mask.shape
    block_k = min(block_k, k)
    # Pad K so the grid divides evenly; zero rows are empty clauses -> 0.
    k_pad = -k % block_k
    if k_pad:
        inc_mask = jnp.pad(inc_mask, ((0, k_pad), (0, 0)))
    grid = (inc_mask.shape[0] // block_k,)

    out = pl.pallas_call(
        _clause_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l,), lambda i: (0,)),
            pl.BlockSpec((block_k, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_k,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((inc_mask.shape[0],), jnp.uint32),
        interpret=True,
    )(xs_packed.astype(jnp.uint32), inc_mask.astype(jnp.uint32))
    return out[:k]


def vmem_bytes(block_k: int, literals: int) -> int:
    """Estimated VMEM footprint of one grid step (inputs + outputs).

    Used by the perf pass (DESIGN.md §7) to pick ``block_k`` — interpret
    mode gives no hardware timing, so we optimize structure analytically.
    """
    x = 4 * literals
    inc = 4 * block_k * literals
    out = 4 * block_k
    return x + inc + out
