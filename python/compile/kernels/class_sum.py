"""L1 Pallas kernel: polarity-signed class-sum accumulation.

The second half of the paper's datapath (Fig 4.6): unpack each clause
output word into its 32 per-datapoint bits, sign by the alternating
clause polarity (+/- bit of the ISA), and accumulate per class.

Grid is over classes: one grid step owns one class's C clause words and
emits its i32[32] sum row.  VMEM per step is tiny (C*4 bytes in,
C*32*4 intermediate, 32*4 out), so no further tiling is needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _class_sum_kernel(words_ref, out_ref):
    """words_ref: u32[C] (one class), out_ref: i32[1, 32]."""
    words = words_ref[...]
    c = words.shape[0]
    bits = (
        (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & jnp.uint32(1)
    ).astype(jnp.int32)  # [C, 32]
    # Polarity alternates within a class starting at +1 (ISA +/- toggle).
    pol = (1 - 2 * (jnp.arange(c, dtype=jnp.int32) % 2))[:, None]
    out_ref[...] = jnp.sum(pol * bits, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("classes", "clauses"))
def class_sums(clause_words: jnp.ndarray, classes: int, clauses: int) -> jnp.ndarray:
    """Pallas class sums.

    Args:
      clause_words: u32[M*C] clause output words, class-major.
    Returns:
      i32[M, 32] class sums per batched datapoint.
    """
    assert clause_words.shape[0] == classes * clauses
    return pl.pallas_call(
        _class_sum_kernel,
        grid=(classes,),
        in_specs=[pl.BlockSpec((clauses,), lambda m: (m,))],
        out_specs=pl.BlockSpec((1, 32), lambda m: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((classes, 32), jnp.int32),
        interpret=True,
    )(clause_words.astype(jnp.uint32))
