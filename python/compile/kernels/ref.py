"""Pure-jnp correctness oracles for the Pallas kernels.

These are the *reference semantics* of the paper's bitwise datapath:

- ``clause_eval_packed_ref``: the 32-wide bit-sliced clause computation of
  Fig 4.5/4.6 — each u32 word holds one literal across 32 batched
  datapoints; a clause output word is the AND of the words of its included
  literals (an empty clause outputs 0 at inference, Fig 3.2).
- ``class_sums_ref``: the polarity-signed accumulation of clause output
  bits into per-class sums (Fig 3.1), one sum per batched datapoint.
- ``clause_eval_dense_ref``: per-sample Boolean clause output with
  *training* semantics (empty clause outputs 1), used by the trainer.

Every Pallas kernel in this package must match these bit-for-bit; pytest +
hypothesis enforce it across shapes/dtypes.
"""

import jax.numpy as jnp

ALL_ONES = jnp.uint32(0xFFFFFFFF)


def clause_eval_packed_ref(xs_packed: jnp.ndarray, inc_mask: jnp.ndarray) -> jnp.ndarray:
    """Clause output words for a 32-datapoint bit-sliced batch.

    Args:
      xs_packed: u32[L] — bit b of word l = literal l of datapoint b.
      inc_mask:  u32[K, L] — 0xFFFFFFFF where TA(k, l) is Include, else 0.

    Returns:
      u32[K] — bit b of word k = clause k's output for datapoint b.
    """
    xs_packed = xs_packed.astype(jnp.uint32)
    inc_mask = inc_mask.astype(jnp.uint32)
    # Include propagates the literal; Exclude contributes neutral 1s.
    masked = xs_packed[None, :] | ~inc_mask  # [K, L]
    words = jnp.bitwise_and.reduce(masked, axis=1)  # [K]
    # Inference semantics: a clause with no Includes outputs 0.
    nonempty = jnp.bitwise_or.reduce(inc_mask, axis=1) != 0
    return jnp.where(nonempty, words, jnp.uint32(0))


def class_sums_ref(clause_words: jnp.ndarray, classes: int, clauses: int) -> jnp.ndarray:
    """Polarity-signed class sums from clause output words.

    Polarity alternates +1/-1 with clause index within a class (the ISA's
    +/- bit toggles on every clause change, Fig 3.4).

    Args:
      clause_words: u32[M*C].
    Returns:
      i32[M, 32] — class sum per class per batched datapoint.
    """
    k = clause_words.shape[0]
    assert k == classes * clauses
    bits = (
        (clause_words[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & jnp.uint32(1)
    ).astype(jnp.int32)  # [K, 32]
    # Polarity restarts at +1 for each class and alternates within it.
    pol = (1 - 2 * (jnp.arange(clauses, dtype=jnp.int32) % 2))[None, :, None]
    signed = pol * bits.reshape(classes, clauses, 32)
    return signed.sum(axis=1)


def predict_ref(class_sums: jnp.ndarray) -> jnp.ndarray:
    """argmax over classes, per batched datapoint: i32[32]."""
    return jnp.argmax(class_sums, axis=0).astype(jnp.int32)


def clause_eval_dense_ref(x_lit: jnp.ndarray, include: jnp.ndarray, training: bool) -> jnp.ndarray:
    """Per-sample clause outputs.

    Args:
      x_lit:   bool/i32[L] — literal values for ONE datapoint.
      include: bool[K, L]  — TA include actions.
      training: empty-clause semantics (True -> 1, False -> 0).

    Returns:
      i32[K] clause outputs in {0, 1}.
    """
    x = x_lit.astype(bool)
    inc = include.astype(bool)
    out = jnp.all(jnp.logical_or(~inc, x[None, :]), axis=1)
    if not training:
        out = jnp.logical_and(out, jnp.any(inc, axis=1))
    return out.astype(jnp.int32)


def pack_literals_ref(x_lit_batch: jnp.ndarray) -> jnp.ndarray:
    """Bit-slice a batch of <=32 datapoints into u32 words.

    Args:
      x_lit_batch: bool/i32[B<=32, L].
    Returns:
      u32[L] with bit b = datapoint b's literal (missing datapoints are 0).
    """
    b = x_lit_batch.shape[0]
    assert b <= 32
    vals = x_lit_batch.astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(b, dtype=jnp.uint32))[:, None]
    return jnp.bitwise_or.reduce(vals * weights, axis=0)
