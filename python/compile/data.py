"""Synthetic booleanized datasets (python mirror of rust/src/datasets).

Per DESIGN.md §Substitutions: the UCI/vision/audio datasets the paper
evaluates are unavailable offline, so every workload is a synthetic
class-prototype generator with the same dimensionality and class count.
Each class has a random Boolean prototype; samples flip each prototype
bit with probability ``noise``.  ``drift`` applies a persistent random
bit-rot to a fraction of feature positions — the sensor
aging/environment-change mechanism the paper's recalibration story needs
(Fig 8).

The rust generator (rust/src/datasets/synth.rs) implements the identical
process with the identical xorshift64* stream so train/test splits agree
across the language boundary; ``test_cross_language.py`` locks the
streams together.
"""

import numpy as np


class XorShift64Star:
    """Tiny deterministic PRNG shared bit-for-bit with the rust side."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = (seed or 0x9E3779B97F4A7C15) & self.MASK

    def next_u64(self) -> int:
        x = self.state
        x ^= (x >> 12)
        x ^= (x << 25) & self.MASK
        x ^= (x >> 27)
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & self.MASK

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        return self.next_u64() % n


def make_dataset(
    features: int,
    classes: int,
    n: int,
    noise: float = 0.08,
    seed: int = 1,
    drift: float = 0.0,
    informative: float = 1.0,
):
    """Returns (x u8[n, features], y i32[n]).

    ``drift`` permanently inverts that fraction of feature positions
    (chosen from the stream) before sampling — models sensor drift.
    ``informative`` is the fraction of features that discriminate between
    classes; the rest share a common background prototype.

    Draw order is locked with rust/src/datasets/synth.rs: background (F),
    informative mask (F), per-class patterns (M x F, always consuming F
    draws), drift set (F), then samples.
    """
    rng = XorShift64Star(seed)
    background = np.zeros(features, dtype=np.uint8)
    for f in range(features):
        background[f] = 1 if rng.next_f64() < 0.5 else 0
    info_mask = np.zeros(features, dtype=bool)
    for f in range(features):
        info_mask[f] = rng.next_f64() < informative

    protos = np.zeros((classes, features), dtype=np.uint8)
    for c in range(classes):
        for f in range(features):
            bit = 1 if rng.next_f64() < 0.5 else 0  # always consume
            protos[c, f] = bit if info_mask[f] else background[f]

    # Always consume exactly F draws here so the sample stream below is
    # identical for every drift value (drifted vs clean sets stay paired).
    flipped = np.zeros(features, dtype=bool)
    for f in range(features):
        if rng.next_f64() < drift:
            flipped[f] = True

    x = np.zeros((n, features), dtype=np.uint8)
    y = np.zeros(n, dtype=np.int32)
    for i in range(n):
        c = rng.below(classes)
        y[i] = c
        for f in range(features):
            bit = protos[c, f]
            if rng.next_f64() < noise:
                bit ^= 1
            if flipped[f]:
                bit ^= 1
            x[i, f] = bit
    return x, y


def to_literals(x: np.ndarray) -> np.ndarray:
    """Interleaved literals i32[n, 2F]: 2f = x_f, 2f+1 = ~x_f."""
    n, f = x.shape
    lit = np.zeros((n, 2 * f), dtype=np.int32)
    lit[:, 0::2] = x
    lit[:, 1::2] = 1 - x
    return lit
