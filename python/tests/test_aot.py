"""AOT path: lowered HLO text is parseable-shaped and manifest-complete.

Full rust-side execution of these artifacts is covered by cargo tests;
here we assert the text interchange contract (ENTRY computation present,
expected parameter/result shapes in the signature) without needing the
rust toolchain.
"""

import json
import os
import re

import pytest

from compile.configs import CONFIGS, get
from compile import aot


@pytest.fixture(scope="module")
def quick_texts():
    cfg = get("quickstart")
    return aot.lower_infer(cfg), aot.lower_train(cfg)


def test_infer_hlo_entry_signature(quick_texts):
    infer, _ = quick_texts
    cfg = get("quickstart")
    assert len([l for l in infer.splitlines() if "ENTRY" in l]) == 1
    # Parameter and result shapes appear in the module text.
    assert f"u32[{cfg.total_clauses},{cfg.literals}]" in infer
    assert f"u32[{cfg.literals}]" in infer
    assert f"s32[{cfg.classes},32]" in infer
    assert "s32[32]" in infer
    # The ENTRY computation itself takes exactly the 2 documented params
    # (sub-computations like reducers have their own parameter() lines).
    entry_block = infer[infer.index("ENTRY"):]
    assert entry_block.count("parameter(") == 2


def test_train_hlo_entry_signature(quick_texts):
    _, train = quick_texts
    cfg = get("quickstart")
    assert len([l for l in train.splitlines() if "ENTRY" in l]) == 1
    assert f"s32[{cfg.classes},{cfg.clauses},{cfg.literals}]" in train
    assert f"s32[{cfg.train_batch},{cfg.literals}]" in train


def test_hlo_is_text_not_proto(quick_texts):
    # The interchange contract: human-readable HLO text (the 0.5.1
    # xla_extension text parser reassigns 64-bit ids; serialized protos
    # from jax >= 0.5 would be rejected).
    infer, train = quick_texts
    for text in (infer, train):
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text


def test_manifest_entry_covers_all_shapes():
    cfg = get("emg")
    e = aot.manifest_entry(cfg)
    assert e["infer_args"]["inc_mask"] == ["u32", [cfg.total_clauses, cfg.literals]]
    assert e["train_args"]["ta_state"] == ["i32", [cfg.classes, cfg.clauses, cfg.literals]]
    assert e["infer_hlo"] == "tm_infer_emg.hlo.txt"
    assert e["n_states"] == 128


def test_all_configs_have_even_clauses_and_valid_dims():
    for cfg in CONFIGS.values():
        assert cfg.clauses % 2 == 0, cfg.name  # polarity alternation needs pairs
        assert cfg.literals == 2 * cfg.features
        assert cfg.classes >= 2
        assert cfg.T > 0 and cfg.s > 1.0 or cfg.name == "quickstart"


def test_built_artifacts_match_manifest():
    """If `make artifacts` has run, every manifest entry must exist on disk."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    manifest = json.load(open(mpath))
    for name, entry in manifest["configs"].items():
        for key in ("infer_hlo", "train_hlo"):
            path = os.path.join(art, entry[key])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule")
