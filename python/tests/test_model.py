"""L2 model invariants: packed (deployment) inference == dense reference."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.configs import get
from compile import model as M
from compile.kernels import ref


def random_model(rng, classes, clauses, literals, density=0.08):
    k = classes * clauses
    include = rng.random((k, literals)) < density
    return include


@settings(max_examples=15, deadline=None)
@given(
    classes=st.integers(2, 5),
    clauses=st.integers(2, 16),
    features=st.integers(2, 40),
    seed=st.integers(0, 2**31),
)
def test_packed_inference_equals_dense(classes, clauses, features, seed):
    rng = np.random.default_rng(seed)
    literals = 2 * features
    include = random_model(rng, classes, clauses, literals)
    inc_mask = jnp.array(include.astype(np.uint32) * np.uint32(0xFFFFFFFF))

    feats = rng.integers(0, 2, size=(32, features)).astype(np.int32)
    lits = np.asarray(M.literals_from_features(jnp.array(feats)))
    packed = ref.pack_literals_ref(jnp.array(lits))

    sums, preds = M.tm_infer_packed(inc_mask, packed, classes=classes, clauses=clauses)

    # Dense per-sample reference (inference semantics).
    for b in range(32):
        out, dsums = M.tm_forward_dense(
            jnp.array(include), jnp.array(lits[b]),
            classes=classes, clauses=clauses, training=False,
        )
        np.testing.assert_array_equal(np.asarray(sums)[:, b], np.asarray(dsums))
        assert int(preds[b]) == int(jnp.argmax(dsums))


def test_literals_interleave():
    x = jnp.array([[1, 0, 1]], dtype=jnp.int32)
    lit = np.asarray(M.literals_from_features(x))
    np.testing.assert_array_equal(lit[0], [1, 0, 0, 1, 1, 0])


def test_include_mask_threshold():
    cfg = get("quickstart")
    ta = jnp.full((cfg.classes, cfg.clauses, cfg.literals), cfg.n_states - 1, jnp.int32)
    mask = M.include_mask_from_state(ta, cfg.n_states)
    assert int(jnp.count_nonzero(mask)) == 0
    ta = ta.at[0, 0, 0].set(cfg.n_states)
    mask = M.include_mask_from_state(ta, cfg.n_states)
    assert int(jnp.count_nonzero(mask)) == 1
    assert int(mask[0, 0]) == 0xFFFFFFFF


def test_training_vs_inference_empty_clause_semantics():
    # Empty clause: 1 during training, 0 at inference (Fig 3.2 discussion).
    include = jnp.zeros((2, 4), dtype=bool)
    x = jnp.array([1, 0, 1, 0], dtype=jnp.int32)
    train_out = ref.clause_eval_dense_ref(x, include, training=True)
    infer_out = ref.clause_eval_dense_ref(x, include, training=False)
    np.testing.assert_array_equal(np.asarray(train_out), [1, 1])
    np.testing.assert_array_equal(np.asarray(infer_out), [0, 0])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_full_packed_pipeline_from_ta_states(seed):
    """include_mask_from_state -> pallas infer == dense per-sample walk,
    starting from raw TA states (the exact tensor the train artifact
    emits)."""
    rng = np.random.default_rng(seed)
    classes, clauses, features = 3, 4, 8
    literals = 2 * features
    n_states = 128
    ta = rng.integers(0, 2 * n_states, size=(classes, clauses, literals)).astype(np.int32)
    # Sparsify: push most states below the include boundary.
    mask = rng.random(ta.shape) < 0.9
    ta = np.where(mask, np.minimum(ta, n_states - 1), ta)

    inc_mask = M.include_mask_from_state(jnp.array(ta), n_states)
    feats = rng.integers(0, 2, size=(32, features)).astype(np.int32)
    lits = np.asarray(M.literals_from_features(jnp.array(feats)))
    packed = ref.pack_literals_ref(jnp.array(lits))
    sums, preds = M.tm_infer_packed(inc_mask, packed, classes=classes, clauses=clauses)

    include = np.asarray(ta >= n_states).reshape(classes * clauses, literals)
    for b in range(32):
        _, dsums = M.tm_forward_dense(
            jnp.array(include), jnp.array(lits[b]),
            classes=classes, clauses=clauses, training=False,
        )
        np.testing.assert_array_equal(np.asarray(sums)[:, b], np.asarray(dsums))
