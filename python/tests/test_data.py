"""Synthetic data generator: determinism + the cross-language PRNG lock.

The known-answer vectors here are duplicated in rust
(rust/src/datasets/synth.rs tests) so the two implementations can never
silently diverge.
"""

import numpy as np

from compile.data import XorShift64Star, make_dataset, to_literals

# Known-answer vectors — must match rust/src/datasets/synth.rs.
KAT_SEED42_U64 = [
    0x56CE4AB7719BA3A0,
    0xC841EB53EBBB2DDA,
    0xCA466BE0C9980276,
    0xF1ACC7334A7B70DF,
]
KAT_SEED7_F64 = [0.820246666541, 0.928290156504, 0.089349592752]


def test_prng_known_answers():
    r = XorShift64Star(42)
    assert [r.next_u64() for _ in range(4)] == KAT_SEED42_U64


def test_prng_f64_known_answers():
    r = XorShift64Star(7)
    got = [round(r.next_f64(), 12) for _ in range(3)]
    assert got == KAT_SEED7_F64


def test_prng_zero_seed_not_stuck():
    r = XorShift64Star(0)
    assert r.next_u64() != 0


def test_dataset_deterministic():
    a = make_dataset(16, 3, 64, seed=9)
    b = make_dataset(16, 3, 64, seed=9)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_dataset_seed_changes_data():
    a = make_dataset(16, 3, 64, seed=9)
    b = make_dataset(16, 3, 64, seed=10)
    assert not np.array_equal(a[0], b[0])


def test_dataset_all_classes_present():
    _, y = make_dataset(8, 4, 400, seed=1)
    assert set(np.unique(y)) == {0, 1, 2, 3}


def test_drift_flips_consistent_positions():
    x0, y0 = make_dataset(32, 2, 128, noise=0.0, seed=5, drift=0.0)
    x1, y1 = make_dataset(32, 2, 128, noise=0.0, seed=5, drift=0.5)
    np.testing.assert_array_equal(y0, y1)
    # With zero noise the difference per class is exactly the drifted
    # feature set, identical for every sample of the same class.
    for c in (0, 1):
        d = (x0[y0 == c] ^ x1[y1 == c])
        assert (d == d[0]).all()


def test_to_literals_complement():
    x = np.array([[1, 0]], dtype=np.uint8)
    lit = to_literals(x)
    np.testing.assert_array_equal(lit[0], [1, 0, 0, 1])


def test_informative_fraction_shares_background():
    # informative=0: all classes identical (pure background).
    x, y = make_dataset(32, 3, 64, noise=0.0, seed=5, informative=0.0)
    protos = [x[y == c][0] for c in range(3) if (y == c).any()]
    for p in protos[1:]:
        np.testing.assert_array_equal(protos[0], p)


def test_informative_one_gives_distinct_prototypes():
    x, y = make_dataset(64, 2, 64, noise=0.0, seed=5, informative=1.0)
    a = x[y == 0][0]
    b = x[y == 1][0]
    assert (a != b).sum() > 10


def test_informative_draw_order_keeps_drift_pairing():
    x0, y0 = make_dataset(32, 2, 64, noise=0.0, seed=5, drift=0.0, informative=0.4)
    x1, y1 = make_dataset(32, 2, 64, noise=0.0, seed=5, drift=0.5, informative=0.4)
    np.testing.assert_array_equal(y0, y1)


CROSS_LANG_X = [1, 0, 0, 1, 0, 0, 1, 0, 1, 0, 0, 1, 0, 0, 1, 1, 1, 0, 1, 0, 0, 0, 1, 0, 1, 0, 0, 0, 0, 0, 1, 1]
CROSS_LANG_Y = [0, 0, 1, 1]


def test_cross_language_dataset_lock():
    # Mirrors rust/src/datasets/synth.rs::cross_language_dataset_lock.
    x, y = make_dataset(8, 2, 4, noise=0.1, seed=42, informative=0.5)
    assert x.flatten().tolist() == CROSS_LANG_X
    assert y.tolist() == CROSS_LANG_Y
