"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes and include densities; every case must match
bit-for-bit (the datapath is exact integer/bit logic, so allclose == equal).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.clause_eval import clause_eval_packed, vmem_bytes
from compile.kernels.class_sum import class_sums

ALL_ONES = np.uint32(0xFFFFFFFF)


def random_case(rng, classes, clauses, literals, density):
    k = classes * clauses
    inc = (rng.random((k, literals)) < density).astype(np.uint32) * ALL_ONES
    xs = rng.integers(0, 2**32, size=literals, dtype=np.uint32)
    return jnp.array(xs), jnp.array(inc)


@settings(max_examples=25, deadline=None)
@given(
    classes=st.integers(1, 6),
    clauses=st.integers(1, 24),
    literals=st.integers(1, 96),
    density=st.floats(0.0, 0.3),
    block_k=st.sampled_from([1, 3, 8, 64, 256]),
    seed=st.integers(0, 2**31),
)
def test_clause_eval_matches_ref(classes, clauses, literals, density, block_k, seed):
    rng = np.random.default_rng(seed)
    xs, inc = random_case(rng, classes, clauses, literals, density)
    got = clause_eval_packed(xs, inc, block_k=block_k)
    want = ref.clause_eval_packed_ref(xs, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    classes=st.integers(1, 8),
    clauses=st.integers(1, 32),
    seed=st.integers(0, 2**31),
)
def test_class_sums_match_ref(classes, clauses, seed):
    rng = np.random.default_rng(seed)
    words = jnp.array(rng.integers(0, 2**32, size=classes * clauses, dtype=np.uint32))
    got = class_sums(words, classes, clauses)
    want = ref.class_sums_ref(words, classes, clauses)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_empty_clause_outputs_zero_at_inference():
    xs = jnp.array(np.full(8, 0xFFFFFFFF, dtype=np.uint32))
    inc = jnp.zeros((4, 8), dtype=jnp.uint32)  # all clauses empty
    got = clause_eval_packed(xs, inc, block_k=2)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(4, dtype=np.uint32))


def test_single_include_propagates_literal():
    rng = np.random.default_rng(3)
    xs = rng.integers(0, 2**32, size=16, dtype=np.uint32)
    inc = np.zeros((16, 16), dtype=np.uint32)
    for k in range(16):
        inc[k, k] = ALL_ONES  # clause k includes exactly literal k
    got = clause_eval_packed(jnp.array(xs), jnp.array(inc), block_k=4)
    np.testing.assert_array_equal(np.asarray(got), xs)


def test_include_acts_as_and():
    # Clause includes literals 0 and 1: output word == xs[0] & xs[1].
    xs = np.array([0b1100, 0b1010, 0xFFFF], dtype=np.uint32)
    inc = np.array([[ALL_ONES, ALL_ONES, 0]], dtype=np.uint32)
    got = clause_eval_packed(jnp.array(xs), jnp.array(inc))
    assert int(got[0]) == (0b1100 & 0b1010)


def test_polarity_alternates_within_class():
    # One class, two clauses both firing for datapoint 0: +1 then -1 -> 0.
    words = jnp.array(np.array([1, 1], dtype=np.uint32))
    sums = class_sums(words, classes=1, clauses=2)
    assert int(sums[0, 0]) == 0
    # Only the positive clause fires -> +1.
    sums = class_sums(jnp.array(np.array([1, 0], dtype=np.uint32)), 1, 2)
    assert int(sums[0, 0]) == 1
    # Only the negative clause fires -> -1.
    sums = class_sums(jnp.array(np.array([0, 1], dtype=np.uint32)), 1, 2)
    assert int(sums[0, 0]) == -1


def test_pack_literals_roundtrip():
    rng = np.random.default_rng(11)
    batch = rng.integers(0, 2, size=(32, 24)).astype(np.int32)
    packed = ref.pack_literals_ref(jnp.array(batch))
    unpacked = (np.asarray(packed)[None, :] >> np.arange(32)[:, None]) & 1
    np.testing.assert_array_equal(unpacked, batch)


def test_pack_literals_partial_batch_zero_fills():
    batch = np.ones((5, 8), dtype=np.int32)
    packed = np.asarray(ref.pack_literals_ref(jnp.array(batch)))
    assert (packed == 0b11111).all()


@pytest.mark.parametrize("block_k,literals", [(64, 128), (256, 1568), (512, 1568)])
def test_vmem_budget(block_k, literals):
    # The structural perf constraint from DESIGN.md §7: one grid step must
    # stay far below a 16 MiB VMEM budget.
    assert vmem_bytes(block_k, literals) < 8 * 2**20
