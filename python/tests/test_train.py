"""Trainer semantics: feedback rules, state bounds, and actual learning."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import get, TMConfig
from compile import train as T, data


@pytest.fixture(scope="module")
def quick_cfg():
    return get("quickstart")


def run_training(cfg, n=512, epochs=6, noise=0.1, seed=7, drift=0.0):
    x, y = data.make_dataset(cfg.features, cfg.classes, n, noise=noise, seed=seed, drift=drift)
    lit = data.to_literals(x)
    step = jax.jit(T.make_train_step(cfg))
    ta = T.init_ta_state(cfg, jax.random.key(0))
    nb = (n // cfg.train_batch) * cfg.train_batch
    for epoch in range(epochs):
        for i in range(0, nb, cfg.train_batch):
            ta = step(
                ta,
                jnp.array(lit[i : i + cfg.train_batch]),
                jnp.array(y[i : i + cfg.train_batch]),
                jnp.array([epoch, i], dtype=jnp.int32),
            )
    acc = T.eval_accuracy(cfg, ta, jnp.array(lit), jnp.array(y))
    return ta, float(acc)


def test_learns_separable_data(quick_cfg):
    _, acc = run_training(quick_cfg, noise=0.05)
    assert acc > 0.9, f"TM failed to learn separable data: acc={acc}"


def test_state_bounds_invariant(quick_cfg):
    ta, _ = run_training(quick_cfg, epochs=2)
    assert int(ta.min()) >= 0
    assert int(ta.max()) <= 2 * quick_cfg.n_states - 1


def test_model_is_sparse():
    # The paper's compression premise (§2): includes are a small minority.
    cfg = get("emg")
    ta, acc = run_training(cfg, n=256, epochs=3)
    inc_frac = float((ta >= cfg.n_states).mean())
    assert inc_frac < 0.35, f"include fraction {inc_frac} too dense"
    assert acc > 0.5


def test_train_step_deterministic(quick_cfg):
    cfg = quick_cfg
    x, y = data.make_dataset(cfg.features, cfg.classes, cfg.train_batch, seed=3)
    lit = jnp.array(data.to_literals(x))
    ys = jnp.array(y)
    seed = jnp.array([1, 2], dtype=jnp.int32)
    step = jax.jit(T.make_train_step(cfg))
    ta0 = T.init_ta_state(cfg, jax.random.key(1))
    a = step(ta0, lit, ys, seed)
    b = step(ta0, lit, ys, seed)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_different_seed_different_update(quick_cfg):
    cfg = quick_cfg
    x, y = data.make_dataset(cfg.features, cfg.classes, cfg.train_batch, seed=3)
    lit = jnp.array(data.to_literals(x))
    ys = jnp.array(y)
    step = jax.jit(T.make_train_step(cfg))
    ta0 = T.init_ta_state(cfg, jax.random.key(1))
    a = step(ta0, lit, ys, jnp.array([1, 2], dtype=jnp.int32))
    b = step(ta0, lit, ys, jnp.array([3, 4], dtype=jnp.int32))
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_type2_feedback_deterministic_rule():
    """Type II: clause fired, literal 0, TA excluded -> state must rise
    toward Include when the gate passes; never past the boundary rules."""
    cfg = TMConfig("t2", features=4, classes=2, clauses=2, T=1000, s=1e9)
    # s -> inf: Type I decrements have prob ~0, making the step almost
    # deterministic; T huge: gate probability ~0.5 both sides.
    x, y = data.make_dataset(cfg.features, cfg.classes, cfg.train_batch, seed=5)
    lit = jnp.array(data.to_literals(x))
    ys = jnp.array(y)
    step = jax.jit(T.make_train_step(cfg))
    ta0 = T.init_ta_state(cfg, jax.random.key(0))
    ta1 = step(ta0, lit, ys, jnp.array([0, 1], dtype=jnp.int32))
    # With 1/s ~ 0 no decrements can occur: states never decrease.
    assert int((ta1 - ta0).min()) >= 0


def test_drift_degrades_accuracy(quick_cfg):
    """The recalibration premise: a model trained on clean data loses
    accuracy on drifted data (Fig 8 motivation)."""
    cfg = quick_cfg
    ta, acc_clean = run_training(cfg, noise=0.05)
    x, y = data.make_dataset(cfg.features, cfg.classes, 512, noise=0.05, seed=7, drift=0.3)
    lit = data.to_literals(x)
    acc_drift = float(T.eval_accuracy(cfg, ta, jnp.array(lit), jnp.array(y)))
    assert acc_drift < acc_clean - 0.1, (acc_clean, acc_drift)
