//! Whole-system integration: every inference path in the repo must agree
//! on every workload, and the paper's qualitative claims must hold on
//! the simulator (shape invariants from DESIGN.md §5/§6).

use rttm::accel::core::{AccelConfig, Core, PipelineMode};
use rttm::accel::multicore::MultiCore;
use rttm::accel::stream::{HeaderWidth, StreamCodec};
use rttm::baselines::{Matador, Mcu, McuKind};
use rttm::coordinator::{Engine, InferenceService, RecalibrationLoop, TrainingNode};
use rttm::datasets::workloads::{workload, workload_names};
use rttm::isa;
use rttm::model_cost::energy::EnergyModel;
use rttm::tm::{model::TMModel, reference};

fn fitted_core(model: &TMModel) -> Core {
    let need = isa::instruction_count(model).next_power_of_two().max(8192);
    let mut c = Core::new(AccelConfig::base().with_depths(need, 2048));
    c.program_model(model).unwrap();
    c
}

fn fitted_multicore(model: &TMModel, n: usize) -> MultiCore {
    let per_class: Vec<usize> = model
        .includes_per_class()
        .into_iter()
        .map(|v| if v == 0 { 2 } else { v })
        .collect();
    let heaviest = MultiCore::partition(&per_class, n)
        .into_iter()
        .map(|(s, e)| per_class[s..e].iter().sum::<usize>())
        .max()
        .unwrap_or(2);
    let cfg =
        AccelConfig::multicore_core().with_depths(heaviest.next_power_of_two().max(4096), 2048);
    let mut m = MultiCore::new(n, cfg);
    m.program_model(model).unwrap();
    m
}

/// Four-way agreement on every workload: dense reference, ISA software
/// walk (MCU), cycle-accurate simulator, multi-core simulator.
#[test]
fn all_paths_agree_on_every_workload() {
    for name in workload_names() {
        let w = workload(name).unwrap();
        // bench-scale training to keep the suite fast
        let data = w.dataset(256, 7);
        let model = rttm::trainer::train_model(&w.shape, &data, 2, 3);

        let mut core = fitted_core(&model);
        let mut multi = fitted_multicore(&model, 5);
        let mcu = Mcu::program_model(McuKind::Esp32, &model);

        let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
        let core_preds = core.run_rows(&rows).unwrap();
        let multi_preds = multi.run_rows(&rows).unwrap();
        for (i, x) in rows.iter().enumerate() {
            let lits = reference::literals_from_features(x);
            let dense = reference::predict_dense(&model, &lits);
            assert_eq!(core_preds[i], dense, "{name}: core dp {i}");
            assert_eq!(multi_preds[i], dense, "{name}: multicore dp {i}");
            assert_eq!(mcu.classify(x).unwrap(), dense, "{name}: mcu dp {i}");
        }
    }
}

/// Header-width interop: the same model programmed through 16/32/64-bit
/// streams produces identical outputs (16-bit skipped where the model
/// doesn't fit its fields — itself asserted).
#[test]
fn stream_width_interop() {
    let w = workload("emg").unwrap();
    let data = w.dataset(128, 9);
    let model = rttm::trainer::train_model(&w.shape, &data, 2, 5);
    let instrs = isa::encode(&model);
    let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
    let packed = isa::pack_features(&rows);

    let mut expected: Option<[u8; 32]> = None;
    for width in [HeaderWidth::W16, HeaderWidth::W32, HeaderWidth::W64] {
        let codec = StreamCodec::new(width);
        let header = codec.instruction_header(w.shape.classes, w.shape.clauses, instrs.len());
        if width == HeaderWidth::W16 && header.is_err() {
            continue; // model legitimately too big for the narrow header
        }
        let need = instrs.len().next_power_of_two().max(8192);
        let mut cfg = AccelConfig::base().with_depths(need, 2048);
        cfg.header_width = width;
        let mut core = Core::new(cfg);
        let mut words: Vec<u64> = header.unwrap().to_vec();
        words.extend(codec.pack_instructions(&instrs));
        words.extend(codec.feature_header(packed.len(), 1).unwrap());
        words.extend(codec.pack_feature_words(&packed));
        let results = core.feed_stream(&words).unwrap();
        assert_eq!(results.len(), 1, "{width:?}");
        match &expected {
            None => expected = Some(results[0].preds),
            Some(e) => assert_eq!(&results[0].preds, e, "{width:?}"),
        }
    }
    assert!(expected.is_some());
}

/// Pipelined and iterative cores always agree functionally; pipelined is
/// strictly faster.
#[test]
fn pipeline_modes_agree_functionally() {
    let w = workload("gesture").unwrap();
    let data = w.dataset(128, 11);
    let model = rttm::trainer::train_model(&w.shape, &data, 2, 2);
    let need = isa::instruction_count(&model).next_power_of_two().max(8192);

    let mut pipe = Core::new(AccelConfig::base().with_depths(need, 2048));
    let mut iter = Core::new(
        AccelConfig::base()
            .with_depths(need, 2048)
            .with_pipeline(PipelineMode::Iterative),
    );
    pipe.program_model(&model).unwrap();
    iter.program_model(&model).unwrap();
    let packed = isa::pack_features(&data.xs[..32].to_vec());
    let rp = pipe.run_batch(&packed).unwrap();
    let ri = iter.run_batch(&packed).unwrap();
    assert_eq!(rp.preds, ri.preds);
    assert_eq!(rp.class_sums, ri.class_sums);
    assert!(rp.cycles.total() < ri.cycles.total());
}

/// The paper's Q2 shape: the accelerator beats the MCU software baseline
/// by two orders of magnitude in latency and at least one in energy.
#[test]
fn accelerator_dominates_mcu() {
    let w = workload("emg").unwrap();
    let data = w.dataset(512, 7);
    let model = rttm::trainer::train_model(&w.shape, &data, 3, 3);
    let mut core = fitted_core(&model);
    let packed = isa::pack_features(&data.xs[..32].to_vec());
    let r = core.run_batch(&packed).unwrap();
    let batch_us = core.seconds(r.cycles.total()) * 1e6;
    let b_single_us = batch_us / 32.0;
    let b_single_uj = EnergyModel::for_config(&core.cfg).energy_uj(batch_us) / 32.0;

    let esp = Mcu::program_model(McuKind::Esp32, &model);
    let speedup = esp.single_latency_us() / b_single_us;
    let energy_red = esp.kind.power_w() * esp.single_latency_us() / b_single_uj;
    assert!(speedup > 100.0, "speedup only {speedup:.1}x");
    assert!(energy_red > 10.0, "energy reduction only {energy_red:.1}x");
}

/// The paper's Q1 shape: MATADOR is faster per datapoint (fixed custom
/// logic), but the proposed design stays within ~an order of magnitude
/// while remaining runtime-tunable.
#[test]
fn matador_faster_but_same_order() {
    let w = workload("cifar2").unwrap();
    let data = w.dataset(384, 7);
    let model = rttm::trainer::train_model(&w.shape, &data, 2, 3);
    let mut core = fitted_core(&model);
    let packed = isa::pack_features(&data.xs[..32].to_vec());
    let r = core.run_batch(&packed).unwrap();
    let b_single_us = core.seconds(r.cycles.total()) * 1e6 / 32.0;
    let mtdr = Matador::synthesize(&model);
    assert!(mtdr.single_latency_us() < b_single_us, "MATADOR must win raw latency");
    assert!(
        b_single_us / mtdr.single_latency_us() < 20.0,
        "gap {:.1}x too wide",
        b_single_us / mtdr.single_latency_us()
    );
}

/// End-to-end Fig 8 behaviour through the service + tuner, on a real
/// workload with the paper's recalibration motivation (gas drift).
#[test]
fn gasdrift_recalibration_story() {
    let w = workload("gasdrift").unwrap();
    let clean = w.dataset(768, 7);
    let drifted = w.drifted_dataset(768, 7, 0.30);

    let node = TrainingNode::native(w.shape.clone());
    let mut svc =
        InferenceService::new(Engine::custom(AccelConfig::base().with_depths(16384, 2048)));
    svc.reprogram(&node.retrain(&clean).unwrap()).unwrap();

    let acc_clean = svc.measure_accuracy(&clean.xs, &clean.ys).unwrap();
    let acc_drift = svc.measure_accuracy(&drifted.xs, &drifted.ys).unwrap();
    assert!(acc_clean > 0.85, "clean acc {acc_clean}");
    assert!(acc_drift < acc_clean - 0.1, "drift must hurt: {acc_clean} -> {acc_drift}");

    let looper = RecalibrationLoop::new(node, acc_clean - 0.05);
    let report = looper
        .run(&mut svc, &[(drifted.clone(), drifted.clone())])
        .unwrap();
    assert_eq!(report.recalibrations.len(), 1);
    assert!(
        report.recalibrations[0].accuracy_after > acc_drift + 0.1,
        "recovery {} -> {}",
        acc_drift,
        report.recalibrations[0].accuracy_after
    );
}

/// Pipelined execute cycles are exactly 3 + N — latency is linear in
/// model size (why runtime down-tuning to a smaller model pays off).
#[test]
fn latency_scales_with_model_size() {
    let w = workload("emg").unwrap();
    let data = w.dataset(256, 7);
    for epochs in [1usize, 4] {
        let model = rttm::trainer::train_model(&w.shape, &data, epochs, 3);
        let mut core = fitted_core(&model);
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        let r = core.run_batch(&packed).unwrap();
        let n = core.instruction_count() as u64;
        assert_eq!(r.cycles.execute, 3 + n);
    }
}

/// Sparsity accounting consistent across representations: includes ==
/// instructions (no empty classes in trained models) == MCU stream len.
#[test]
fn sparsity_accounting_consistent() {
    let w = workload("har").unwrap();
    let data = w.dataset(256, 7);
    let model = rttm::trainer::train_model(&w.shape, &data, 2, 3);
    let instrs = isa::encode(&model);
    assert_eq!(instrs.len(), isa::instruction_count(&model));
    let per_class = model.includes_per_class();
    if per_class.iter().all(|&c| c > 0) {
        assert_eq!(instrs.len(), model.include_count());
    }
    let mcu = Mcu::program_model(McuKind::Esp32, &model);
    assert_eq!(mcu.instrs.len(), instrs.len());
}
