//! Canary-gate acceptance on a live pool: under an abrupt drift
//! schedule, a BAD candidate is rejected at the canary stage — at most
//! one replica ever serves it, and pool predictions stay byte-identical
//! to the baseline for the entire canary window — then a GOOD candidate
//! promotes; versions stay strictly monotone and a concurrent client
//! sees zero request errors throughout.
//!
//! Slow (full drift schedule, real windows): `#[ignore]`d out of tier-1
//! and run by the CI `cargo test -- --ignored` job.

#[path = "common/pool_harness.rs"]
mod pool_harness;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use pool_harness::{
    assert_versions_strictly_monotone, drifty_workload, spawn_harness, train_initial, Traffic,
};
use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner, ShadowTrainer};
use rttm::coordinator::hyperparam::{BudgetedSearch, BudgetedTrial};
use rttm::coordinator::{CanaryVerdict, EngineSpec, InferenceService};
use rttm::datasets::synth::Dataset;
use rttm::datasets::workloads::DriftSchedule;
use rttm::model_cost::energy::EnergyModel;
use rttm::model_cost::resources::{
    compressed_model_bytes, estimate, fitted_config, ResourceBudget,
};
use rttm::TMModel;

/// Deterministic trainer that hands out a scripted sequence of
/// candidates, one per retrain — first the bad one, then the good one.
struct QueueTrainer(Mutex<VecDeque<TMModel>>);

impl ShadowTrainer for QueueTrainer {
    fn retrain(&self, _train: &Dataset, _valid: &Dataset) -> BudgetedSearch {
        let model = self
            .0
            .lock()
            .unwrap()
            .pop_front()
            .expect("scripted trainer exhausted: unexpected extra retune");
        let cfg = fitted_config(&model);
        let est = estimate(&cfg);
        let watts = EnergyModel::for_config(&cfg).watts;
        BudgetedSearch {
            trials: vec![BudgetedTrial {
                t: model.shape.t,
                s: model.shape.s,
                clauses: model.shape.clauses,
                accuracy: 0.0,
                instructions: rttm::isa::instruction_count(&model),
                estimate: est,
                watts,
                model_bytes: compressed_model_bytes(&model),
                admitted: true,
            }],
            winner: Some(model),
        }
    }
}

#[test]
#[ignore = "slow (live drift schedule); runs in the CI --ignored job"]
fn bad_candidate_rejected_at_canary_then_good_candidate_promotes() {
    let w = drifty_workload();
    // 14 windows x 256 samples; drift 0.4 from window 3 onward.
    let sched = DriftSchedule::abrupt(14, 256, 3, 0.4).seed(7);
    let model0 = train_initial(&w, &sched, 512);

    // The BAD candidate: untrained, tautology killers only — predicts
    // one class everywhere.  The GOOD candidate: trained on drifted
    // draws from the same universe, NOT overlapping the monitored
    // stream (the stream is sliced past sample 768; these are 0..512).
    let bad = TMModel::empty(w.shape.clone());
    let good = rttm::trainer::train_model(&w.shape, &w.drifted_dataset(512, sched.seed, 0.4), 4, 5);

    let pool = spawn_harness(EngineSpec::base(), 3);
    let handle = pool.handle.clone();

    let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
    cfg.accuracy_floor = 0.85;
    cfg.patience = 2;
    cfg.validation_windows = 1;
    cfg.background = false; // inline retrains: deterministic timeline
    cfg.canary_fraction = 0.25;
    cfg.canary_min_windows = 2;
    let trainer = Arc::new(QueueTrainer(Mutex::new(VecDeque::from([bad, good.clone()]))));
    let mut tuner = Autotuner::with_trainer(handle.clone(), w.shape.clone(), cfg, trainer);
    tuner.install(model0).unwrap();

    // Baseline answers on a fixed probe, pinned before any canary: the
    // pool (minus canary) must keep producing EXACTLY these for as long
    // as no promote happened.
    let probe: Vec<Vec<u8>> = sched.training_set(&w, 192).xs;
    let baseline_preds = handle.infer(probe.clone()).unwrap();

    // Zero-request-error witness across the whole deployment.
    let traffic = Traffic::start(handle.clone(), probe[..32].to_vec());

    let mut canary_probes = 0usize;
    let mut promoted = false;
    for win in &sched.stream(&w) {
        tuner.observe_window(&win.xs, &win.ys).unwrap();
        promoted = promoted
            || tuner
                .report
                .events
                .iter()
                .any(|e| matches!(e, AutotuneEvent::CanaryPromoted { .. }));
        if tuner.phase_name() == "canarying" && !promoted {
            // A candidate (bad OR good) is live on one replica: the
            // pool-minus-canary answers must be byte-identical to the
            // pre-canary baseline — live traffic cannot observe the
            // candidate, however the verdict turns out.
            assert_eq!(
                handle.infer(probe.clone()).unwrap(),
                baseline_preds,
                "live traffic observed a canary candidate"
            );
            canary_probes += 1;
        }
    }
    traffic.stop_assert_clean();
    assert!(canary_probes >= 2, "canary phases were never probed");

    // --- the story: reject then promote, in that order ----------------
    let report = &tuner.report;
    assert_eq!(report.canaries.len(), 2, "two canary evaluations: {:?}", report.events);
    assert_eq!(report.canaries[0].verdict, CanaryVerdict::Reject);
    assert_eq!(report.canaries[1].verdict, CanaryVerdict::Promote);
    // The bad candidate lost every paired window; the good one won all.
    assert!(report.canaries[0].windows.iter().all(|p| !p.candidate_wins));
    assert!(report.canaries[1].windows.iter().all(|p| p.candidate_wins));
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::CanaryRejected { .. })));
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::CanaryPromoted { .. })));
    // The rejected candidate never reached a Swapped broadcast: exactly
    // one swap (the promote).
    let swaps = report
        .events
        .iter()
        .filter(|e| matches!(e, AutotuneEvent::Swapped { .. }))
        .count();
    assert_eq!(swaps, 1);
    assert!(!report.events.iter().any(|e| matches!(e, AutotuneEvent::RolledBack { .. })));

    // ≤ 1 replica ever served each candidate: every canary staged on
    // the same dedicated replica (the highest-index one of the
    // 3-replica pool), and no canary is left active.
    for e in &report.events {
        if let AutotuneEvent::CanaryStarted { replica, .. } = e {
            assert_eq!(*replica, 2, "canary must use the dedicated replica");
        }
    }
    assert!(handle.canary_replica().is_none());

    // --- the promoted model serves the whole pool ----------------------
    let mut reference = InferenceService::new(EngineSpec::base().build());
    reference.reprogram(&good).unwrap();
    let want_good = reference.infer_all(&probe).unwrap();
    for _ in 0..6 {
        assert_eq!(handle.infer(probe.clone()).unwrap(), want_good);
    }
    assert_eq!(tuner.current_model().unwrap(), &good);

    // --- versions strictly monotone through every lifecycle ------------
    // install(1), canary bad(2), dismiss(3), canary good(4), promote(5).
    assert_versions_strictly_monotone(report);
    assert_eq!(handle.pool_stats().version, 5);

    pool.shutdown();
}
