//! Multi-tenant serving regression coverage: interleaved per-model
//! traffic must be byte-identical to isolated single-tenant pools,
//! per-model admission counters must reconcile exactly, a canary staged
//! on one tenant must never perturb another tenant's replicas, two
//! tenants registering byte-identical models must stay isolated under
//! distinct ids, and the `TimeShared` dwell guard must bound reprogram
//! thrash under adversarial alternation.  Setup lives in the shared
//! pool harness.

#[path = "common/pool_harness.rs"]
mod pool_harness;

use std::time::{Duration, Instant};

use pool_harness::{
    assert_model_reconciled, classed_load, model_stats_for, spawn_harness_sharded, trained,
    two_tenants, Traffic,
};
use rttm::coordinator::{
    AdmissionConfig, EngineSpec, InferenceService, IntegrityConfig, PoolConfig, Priority,
    ShardingPolicy, ShedPolicy,
};

/// Interleaved two-tenant traffic through one `TimeShared` pool returns
/// exactly what two isolated single-model services would have returned,
/// request for request, byte for byte.
#[test]
fn interleaved_tenants_match_isolated_pools_byte_for_byte() {
    let ((model_a, data_a), (model_b, data_b)) = two_tenants();

    // Isolated references: one dedicated service per tenant.
    let mut single_a = InferenceService::new(EngineSpec::base().build());
    single_a.reprogram(&model_a).unwrap();
    let want_a = single_a.infer_all(&data_a.xs).unwrap();
    let mut single_b = InferenceService::new(EngineSpec::base().build());
    single_b.reprogram(&model_b).unwrap();
    let want_b = single_b.infer_all(&data_b.xs).unwrap();
    // The tenants must disagree on tenant A's own rows, or serving the
    // wrong model would be invisible below.
    assert_ne!(want_a, single_b.infer_all(&data_a.xs).unwrap());

    let pool = spawn_harness_sharded(
        EngineSpec::base(),
        PoolConfig::fixed(4),
        ShardingPolicy::time_shared(),
    );
    let ida = pool.handle.register_model("tenant-a", model_a).unwrap();
    let idb = pool.handle.register_model("tenant-b", model_b).unwrap();
    let ha = pool.handle.with_model(ida);
    let hb = pool.handle.with_model(idb);

    // Two concurrent clients, one per tenant, plus the main thread
    // alternating between them — maximally interleaved on a 4-replica
    // pool.
    let clients: Vec<_> = [
        (ha.clone(), data_a.xs.clone(), want_a.clone()),
        (hb.clone(), data_b.xs.clone(), want_b.clone()),
    ]
    .into_iter()
    .map(|(h, xs, want)| {
        std::thread::spawn(move || {
            for _ in 0..24 {
                assert_eq!(h.infer(xs.clone()).unwrap(), want, "cross-tenant contamination");
            }
        })
    })
    .collect();
    for _ in 0..12 {
        assert_eq!(ha.infer(data_a.xs[..48].to_vec()).unwrap(), want_a[..48]);
        assert_eq!(hb.infer(data_b.xs[..48].to_vec()).unwrap(), want_b[..48]);
    }
    for c in clients {
        c.join().expect("tenant client panicked");
    }

    // Both tenants' rollups exist, reconcile, and show a fully drained
    // pool: block admission never rejects or sheds.
    for id in [ida, idb] {
        let m = model_stats_for(&pool.handle, id);
        assert_model_reconciled(&m);
        assert!(m.served() > 0, "tenant {id} served nothing");
        assert_eq!(m.rejected(), 0);
        assert_eq!(m.shed(), 0);
        assert_eq!(m.depth(), 0);
    }
    pool.shutdown();
}

/// Client-side tallies and the pool's per-model counters must agree
/// exactly under rejection pressure, and the per-model rollups must
/// partition the pool-wide class counters with nothing lost.
#[test]
fn per_model_counters_reconcile_under_reject_pressure() {
    let ((model_a, data_a), (model_b, data_b)) = two_tenants();
    let cfg = PoolConfig {
        replicas: 2,
        admission: AdmissionConfig::uniform(2, ShedPolicy::Reject),
        autoscale: None,
        integrity: IntegrityConfig::default(),
    };
    let pool = spawn_harness_sharded(EngineSpec::base(), cfg, ShardingPolicy::time_shared());
    let ida = pool.handle.register_model("tenant-a", model_a).unwrap();
    let idb = pool.handle.register_model("tenant-b", model_b).unwrap();
    let ha = pool.handle.with_model(ida);
    let hb = pool.handle.with_model(idb);

    // <= 32 rows per request so every classed_load call is exactly one
    // admission decision; 8 clients against 2 replicas with cap 2 keeps
    // the Reject policy busy on both tenants at once.
    let rows_a = data_a.xs[..16].to_vec();
    let rows_b = data_b.xs[..16].to_vec();
    let tb = {
        let hb = hb.clone();
        std::thread::spawn(move || classed_load(&hb, &rows_b, Priority::Normal, 8, 12))
    };
    let out_a = classed_load(&ha, &rows_a, Priority::Normal, 8, 12);
    let out_b = tb.join().expect("tenant-b load panicked");

    for (id, out) in [(ida, &out_a), (idb, &out_b)] {
        let m = model_stats_for(&pool.handle, id);
        // Front door: the pool saw exactly the requests the clients
        // sent, and refused exactly the ones the clients saw refused.
        assert_eq!(out.submitted(), 96);
        assert_eq!(out.other, 0, "unexpected error flavour for {id}");
        assert_eq!(m.submitted(), out.submitted());
        assert_eq!(m.rejected(), out.overloaded + out.deadline);
        // Back door, class by class; all clients drained, so nothing is
        // still queued and everything admitted was served.
        assert_model_reconciled(&m);
        assert_eq!(m.depth(), 0);
        assert_eq!(m.shed(), 0);
        assert_eq!(m.served(), out.ok);
    }

    // The per-model rollups partition the pool-wide Normal-class
    // counters exactly: no transition is double-counted or dropped.
    let sa = model_stats_for(&pool.handle, ida);
    let sb = model_stats_for(&pool.handle, idb);
    let pool_normal = pool.handle.admission_stats().classes[Priority::Normal.index()].clone();
    let ca = sa.class(Priority::Normal);
    let cb = sb.class(Priority::Normal);
    assert_eq!(pool_normal.admitted, ca.admitted + cb.admitted);
    assert_eq!(pool_normal.rejected, ca.rejected + cb.rejected);
    assert_eq!(pool_normal.served, ca.served + cb.served);
    assert_eq!(pool_normal.shed, ca.shed + cb.shed);
    pool.shutdown();
}

/// A canary staged on tenant A steals one of A's OWN pinned replicas
/// and leaves tenant B untouched: B's replicas never reprogram, B's
/// predictions stay byte-identical, and B records zero sharding
/// switches — before, during, and after promotion.
#[test]
fn canary_on_one_tenant_never_perturbs_the_other() {
    let ((model_a, data_a), (model_b, data_b)) = two_tenants();
    let (candidate_a, _) = trained(103);
    let mut single_c = InferenceService::new(EngineSpec::base().build());
    single_c.reprogram(&candidate_a).unwrap();
    let want_candidate = single_c.infer_all(&data_a.xs).unwrap();

    let pool = spawn_harness_sharded(
        EngineSpec::base(),
        PoolConfig::fixed(4),
        ShardingPolicy::Dedicated,
    );
    let ida = pool.handle.register_model("tenant-a", model_a).unwrap();
    let idb = pool.handle.register_model("tenant-b", model_b).unwrap();
    let ha = pool.handle.with_model(ida);
    let hb = pool.handle.with_model(idb);
    let want_b = hb.infer(data_b.xs.clone()).unwrap();

    // Snapshot tenant B's pinned replicas before any canary exists.
    let before = pool.handle.pool_stats();
    let b_replicas: Vec<usize> = before
        .replicas
        .iter()
        .enumerate()
        .filter(|(_, r)| r.assigned == Some(idb))
        .map(|(i, _)| i)
        .collect();
    assert!(!b_replicas.is_empty(), "dedicated rebalance left tenant B unpinned");
    let b_reprograms: Vec<u64> =
        b_replicas.iter().map(|&i| before.replicas[i].metrics.reprograms).collect();

    // Stage the canary on A; it must claim one of A's replicas.
    let c = ha.program_canary(candidate_a).unwrap();
    assert_eq!(pool.handle.canary_replicas(), vec![(ida, c)]);
    assert!(!b_replicas.contains(&c), "canary stole a replica pinned to tenant B");

    // Drive live traffic at both tenants and mirrored traffic at A's
    // canary while it is staged.
    for _ in 0..6 {
        assert_eq!(hb.infer(data_b.xs.clone()).unwrap(), want_b);
        assert_eq!(ha.infer_canary(data_a.xs[..32].to_vec()).unwrap(), want_candidate[..32]);
    }

    // Promote: A's fleet converges on the candidate...
    ha.promote_canary().unwrap();
    assert!(pool.handle.canary_replicas().is_empty());
    for _ in 0..4 {
        assert_eq!(ha.infer(data_a.xs.clone()).unwrap(), want_candidate);
        assert_eq!(hb.infer(data_b.xs.clone()).unwrap(), want_b);
    }

    // ...while tenant B never reprogrammed, never hosted a canary, and
    // never switched models.
    let after = pool.handle.pool_stats();
    for (&i, &was) in b_replicas.iter().zip(&b_reprograms) {
        assert_eq!(after.replicas[i].assigned, Some(idb), "tenant B replica reassigned");
        assert_eq!(after.replicas[i].canary_of, None);
        assert_eq!(
            after.replicas[i].metrics.reprograms, was,
            "tenant B replica {i} reprogrammed during tenant A's canary"
        );
    }
    assert_eq!(model_stats_for(&pool.handle, idb).switches, 0);
    pool.shutdown();
}

/// Regression for the registry tenant-aliasing bug: two tenants
/// registering byte-identical models must get DISTINCT ids — under the
/// old hash-only dedup, tenant B was handed tenant A's id, so a
/// retrain/promote on A silently rewrote B's serving model.  Here A
/// promotes a retrained candidate and B's predictions must stay
/// byte-identical to the original model throughout.
#[test]
fn identical_bytes_under_two_tenants_stay_isolated_across_promotion() {
    let (model, data) = trained(101);
    let (candidate, _) = trained(102);

    // Isolated references for the shared original and A's candidate.
    let mut single = InferenceService::new(EngineSpec::base().build());
    single.reprogram(&model).unwrap();
    let want_original = single.infer_all(&data.xs).unwrap();
    let mut single_c = InferenceService::new(EngineSpec::base().build());
    single_c.reprogram(&candidate).unwrap();
    let want_candidate = single_c.infer_all(&data.xs).unwrap();
    assert_ne!(want_original, want_candidate, "test premise: retrain must change answers");

    let pool = spawn_harness_sharded(
        EngineSpec::base(),
        PoolConfig::fixed(4),
        ShardingPolicy::Dedicated,
    );
    // The SAME bytes under two tenant names: fresh, isolated ids.
    let ida = pool.handle.register_model("tenant-a", model.clone()).unwrap();
    let idb = pool.handle.register_model("tenant-b", model).unwrap();
    assert_ne!(ida, idb, "identical bytes under two tenant names aliased onto one id");
    let ha = pool.handle.with_model(ida);
    let hb = pool.handle.with_model(idb);
    assert_eq!(ha.infer(data.xs.clone()).unwrap(), want_original);
    assert_eq!(hb.infer(data.xs.clone()).unwrap(), want_original);

    // Retrain tenant A: canary the candidate on A and promote it.
    ha.program_canary(candidate).unwrap();
    ha.promote_canary().unwrap();

    // A serves the candidate; B still serves the ORIGINAL bytes —
    // byte-identical answers, no reassignment of B's route.
    for _ in 0..4 {
        assert_eq!(ha.infer(data.xs.clone()).unwrap(), want_candidate);
        assert_eq!(
            hb.infer(data.xs.clone()).unwrap(),
            want_original,
            "tenant A's promotion leaked into tenant B's serving model"
        );
    }
    let stats = pool.handle.pool_stats();
    assert!(
        stats.replicas.iter().any(|r| r.assigned == Some(idb)),
        "tenant B lost its dedicated replica during tenant A's promotion"
    );
    assert_eq!(model_stats_for(&pool.handle, idb).switches, 0);
    pool.shutdown();
}

/// Adversarial alternation on a single `TimeShared` replica: both
/// tenants hammer the pool at once, forcing the lone replica to host
/// each in turn.  The dwell guard must cap model switches near
/// `elapsed / dwell` — not one reprogram per request — while both
/// tenants still make progress.
#[test]
fn dwell_guard_bounds_reprogram_thrash_under_alternation() {
    let ((model_a, data_a), (model_b, data_b)) = two_tenants();
    let dwell = Duration::from_millis(40);
    let pool = spawn_harness_sharded(
        EngineSpec::base(),
        PoolConfig::fixed(1),
        ShardingPolicy::TimeShared { dwell },
    );
    let ida = pool.handle.register_model("tenant-a", model_a).unwrap();
    let idb = pool.handle.register_model("tenant-b", model_b).unwrap();

    let t0 = Instant::now();
    let ta = Traffic::start(pool.handle.with_model(ida), data_a.xs[..16].to_vec());
    let tb = Traffic::start(pool.handle.with_model(idb), data_b.xs[..16].to_vec());
    std::thread::sleep(Duration::from_millis(400));
    let (served_a, failed_a) = ta.stop();
    let (served_b, failed_b) = tb.stop();
    let elapsed = t0.elapsed();

    assert_eq!(failed_a + failed_b, 0, "request errors during alternation");
    assert!(served_a > 0, "tenant A starved");
    assert!(served_b > 0, "tenant B starved");

    // Each switch needs `dwell` of residency first (the very first
    // adoption is free), so the count is bounded by elapsed/dwell plus
    // slack for the boundary switches.  Without the guard this would be
    // one switch per alternation — hundreds.
    let stats = pool.handle.pool_stats();
    let ceiling = (elapsed.as_millis() / dwell.as_millis()) as u64 + 2;
    assert!(
        (1..=ceiling).contains(&stats.sharding_switches),
        "sharding_switches = {} outside [1, {ceiling}] over {elapsed:?}",
        stats.sharding_switches
    );
    let switch_sum: u64 = pool.handle.model_stats().iter().map(|m| m.switches).sum();
    assert_eq!(
        switch_sum, stats.sharding_switches,
        "per-model switch counts must partition the total"
    );
    pool.shutdown();
}
