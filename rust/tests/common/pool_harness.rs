//! Shared serving-pool test harness: spawn helpers, trained fixtures,
//! drift-schedule workloads, a background request generator, and the
//! assertion helpers every pool integration test needs.
//!
//! Included per test binary via `#[path = "common/pool_harness.rs"]`
//! (integration tests are separate crates; this is the same pattern the
//! benches use for `benches/common`).  Keeps `serving_pool.rs`,
//! `autotune_live.rs` and `canary_live.rs` from re-implementing the
//! same setup three times.
#![allow(dead_code)]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use std::time::Duration;

use rttm::coordinator::autotune::AutotuneReport;
use rttm::coordinator::server::{spawn_pool, spawn_pool_cfg, spawn_pool_sharded, ServeError};
use rttm::coordinator::{
    EngineSpec, Fault, FaultPlan, ModelId, ModelStats, PoolConfig, PoolJoin, Priority,
    ServiceHandle, ShardingPolicy,
};
use rttm::datasets::synth::{Dataset, SynthSpec};
use rttm::datasets::workloads::{DriftSchedule, Workload};
use rttm::{TMModel, TMShape};

/// A trained model + the dataset it was trained on, at the small scale
/// the pool regression tests use (16 features, 4 classes, 8 clauses).
pub fn trained(seed: u64) -> (TMModel, Dataset) {
    let shape = TMShape::synthetic(16, 4, 8);
    let data = SynthSpec::new(16, 4, 192).noise(0.05).seed(seed).generate();
    let model = rttm::trainer::train_model(&shape, &data, 4, seed + 1);
    (model, data)
}

/// The drift-schedule integration workload shared by the live autotune
/// and canary tests.
pub fn drifty_workload() -> Workload {
    Workload {
        name: "drifty",
        shape: TMShape::synthetic(16, 3, 10),
        noise: 0.05,
        informative: 1.0,
        paper_accuracy: None,
        recalibration: "integration test",
    }
}

/// Train the initially-deployed model on fresh draws PAST the monitored
/// stream (same prototype universe), so windowed accuracy measures
/// generalization, never memorized training samples.
pub fn train_initial(w: &Workload, sched: &DriftSchedule, n: usize) -> TMModel {
    rttm::trainer::train_model(&w.shape, &sched.training_set(w, n), 4, 2)
}

/// A spawned replica pool plus its joiner, with one-call teardown.
pub struct PoolHarness {
    pub handle: ServiceHandle,
    pub join: PoolJoin,
}

pub fn spawn_harness(spec: EngineSpec, replicas: usize) -> PoolHarness {
    let (handle, join) = spawn_pool(spec, replicas);
    PoolHarness { handle, join }
}

/// [`spawn_harness`] under a full [`PoolConfig`] (classed admission
/// caps/policies, optional autoscaler) — the overload tests' entry.
pub fn spawn_harness_cfg(spec: EngineSpec, cfg: PoolConfig) -> PoolHarness {
    let (handle, join) = spawn_pool_cfg(spec, cfg);
    PoolHarness { handle, join }
}

/// [`spawn_harness_cfg`] under an explicit [`ShardingPolicy`] — the
/// multi-tenant tests' entry.
pub fn spawn_harness_sharded(
    spec: EngineSpec,
    cfg: PoolConfig,
    sharding: ShardingPolicy,
) -> PoolHarness {
    let (handle, join) = spawn_pool_sharded(spec, cfg, sharding);
    PoolHarness { handle, join }
}

/// Two distinct trained tenants at the shared pool-test scale.  Same
/// shape (so one engine spec fits both), different prototype draws —
/// the models disagree on enough rows that cross-tenant contamination
/// is observable as a byte-level prediction mismatch.
pub fn two_tenants() -> ((TMModel, Dataset), (TMModel, Dataset)) {
    (trained(101), trained(102))
}

impl PoolHarness {
    /// Shut the pool down and join every worker.
    pub fn shutdown(mut self) {
        self.handle.shutdown();
        self.join.join();
    }
}

/// One model's rollup out of [`ServiceHandle::model_stats`], by id.
pub fn model_stats_for(handle: &ServiceHandle, id: ModelId) -> ModelStats {
    handle
        .model_stats()
        .into_iter()
        .find(|m| m.id == id)
        .unwrap_or_else(|| panic!("no stats rollup for model {id}"))
}

/// Per-model, per-class admission reconciliation: every admitted
/// request is accounted exactly once at the back (`admitted == served +
/// shed + depth`), class by class.  (The front-door half, `submitted ==
/// admitted + rejected`, is reconciled against CLIENT-side tallies by
/// the callers — the pool derives `submitted` from the same two
/// counters, so asserting it here would be circular.)
pub fn assert_model_reconciled(m: &ModelStats) {
    for (i, c) in m.classes.iter().enumerate() {
        assert_eq!(
            c.admitted,
            c.served + c.shed + c.depth,
            "model {} ({}) class {i}: admitted != served + shed + depth ({c:?})",
            m.id,
            m.name,
        );
    }
}

/// Background request generator: one client thread hammering the pool
/// with a fixed request until stopped, counting successes and failures.
/// The canonical "zero request errors during the whole deployment"
/// witness — start it before the scenario, `stop_assert_clean` after.
pub struct Traffic {
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    failed: Arc<AtomicU64>,
    thread: std::thread::JoinHandle<()>,
}

impl Traffic {
    pub fn start(handle: ServiceHandle, rows: Vec<Vec<u8>>) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let failed = Arc::new(AtomicU64::new(0));
        let thread = {
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            let failed = Arc::clone(&failed);
            std::thread::spawn(move || {
                let n = rows.len();
                while !stop.load(Ordering::Relaxed) {
                    match handle.infer(rows.clone()) {
                        Ok(preds) => {
                            assert_eq!(preds.len(), n, "malformed reply");
                            served.fetch_add(preds.len() as u64, Ordering::Relaxed);
                        }
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    std::thread::yield_now();
                }
            })
        };
        Traffic { stop, served, failed, thread }
    }

    /// Inferences served so far (live, for "traffic flowed during X"
    /// assertions).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// Stop the client; returns (served, failed).
    pub fn stop(self) -> (u64, u64) {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.join().expect("traffic client panicked");
        (self.served.load(Ordering::Relaxed), self.failed.load(Ordering::Relaxed))
    }

    /// Stop and assert a clean deployment: zero request errors, some
    /// traffic actually served.  Returns the served count.
    pub fn stop_assert_clean(self) -> u64 {
        let (served, failed) = self.stop();
        assert_eq!(failed, 0, "request errors during deployment");
        assert!(served > 0, "no traffic flowed");
        served
    }
}

/// Outcome tally of a synchronous classed hammer ([`classed_load`]):
/// one bucket per interesting [`ServeError`] flavour, so overload tests
/// can reconcile client-side observations against the pool's admission
/// counters.
#[derive(Debug, Default, Clone)]
pub struct LoadOutcome {
    pub ok: u64,
    pub overloaded: u64,
    pub deadline: u64,
    pub other: u64,
}

impl LoadOutcome {
    /// Total requests this tally accounts for.
    pub fn submitted(&self) -> u64 {
        self.ok + self.overloaded + self.deadline + self.other
    }

    pub fn absorb(&mut self, o: &LoadOutcome) {
        self.ok += o.ok;
        self.overloaded += o.overloaded;
        self.deadline += o.deadline;
        self.other += o.other;
    }
}

/// Fire `clients` synchronous client threads, each sending `per_client`
/// copies of `rows` at `class`, and tally what came back.  Blocks until
/// every client drains — the deterministic "offered load of N clients"
/// used by the saturation tests (offered load is controlled by client
/// count, not a rate, so the test is timing-independent).
pub fn classed_load(
    handle: &ServiceHandle,
    rows: &[Vec<u8>],
    class: Priority,
    clients: usize,
    per_client: usize,
) -> LoadOutcome {
    let threads: Vec<_> = (0..clients)
        .map(|_| {
            let h = handle.clone();
            let rows = rows.to_vec();
            std::thread::spawn(move || {
                let mut out = LoadOutcome::default();
                for _ in 0..per_client {
                    match h.infer_class(rows.clone(), class) {
                        Ok(_) => out.ok += 1,
                        Err(ServeError::Overloaded) => out.overloaded += 1,
                        Err(ServeError::DeadlineExceeded) => out.deadline += 1,
                        Err(_) => out.other += 1,
                    }
                }
                out
            })
        })
        .collect();
    let mut total = LoadOutcome::default();
    for t in threads {
        total.absorb(&t.join().expect("load client panicked"));
    }
    total
}

/// Window-observed model versions must never go backwards.  (Strict
/// increase across DISTINCT adjacent values follows: non-decreasing
/// plus unequal is greater.)
pub fn assert_versions_strictly_monotone(report: &AutotuneReport) {
    for pair in report.windows.windows(2) {
        assert!(
            pair[1].model_version >= pair[0].model_version,
            "version went backwards"
        );
    }
}

/// Mean labeled accuracy over a half-open window index range.
pub fn mean_accuracy(report: &AutotuneReport, range: std::ops::Range<usize>) -> f64 {
    let n = range.len().max(1);
    range
        .map(|i| report.windows[i].accuracy.expect("labeled window"))
        .sum::<f64>()
        / n as f64
}

/// Sequential splitmix64 — the harness's only entropy source, so every
/// chaos schedule is a pure function of its seed.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Per-kind tally of what a [`ChaosPlan`] storm armed.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ChaosReport {
    pub flips: u64,
    pub stalls: u64,
    pub panics: u64,
    pub drops: u64,
}

impl ChaosReport {
    pub fn armed(&self) -> u64 {
        self.flips + self.stalls + self.panics + self.drops
    }
}

/// Seeded, composable chaos storm: a reproducible schedule of model
/// bit flips optionally interleaved with stalls, panics and dropped
/// replies, armed against a live pool while traffic flows.  The
/// schedule is a pure function of `(seed, replicas, rounds, knobs)` —
/// rerunning a failed chaos test replays the exact same fault sequence.
pub struct ChaosPlan {
    seed: u64,
    replicas: usize,
    rounds: usize,
    flip_bits: u32,
    stalls: bool,
    panics: bool,
    drops: bool,
}

impl ChaosPlan {
    /// Bit-flip-only storm over `replicas` replicas; enable the other
    /// fault kinds with the builder knobs.
    pub fn new(seed: u64, replicas: usize) -> Self {
        ChaosPlan {
            seed,
            replicas: replicas.max(1),
            rounds: 16,
            flip_bits: 4,
            stalls: false,
            panics: false,
            drops: false,
        }
    }

    pub fn rounds(mut self, n: usize) -> Self {
        self.rounds = n;
        self
    }

    pub fn flip_bits(mut self, n: u32) -> Self {
        self.flip_bits = n.max(1);
        self
    }

    /// Mix short worker stalls into the storm.
    pub fn with_stalls(mut self) -> Self {
        self.stalls = true;
        self
    }

    /// Mix worker panics (respawn supervision + breaker trips) in.
    pub fn with_panics(mut self) -> Self {
        self.panics = true;
        self
    }

    /// Mix dropped replies (the `WorkerGone` blind spot) in.
    pub fn with_drops(mut self) -> Self {
        self.drops = true;
        self
    }

    /// The storm's full fault sequence, derived from the seed alone.
    /// Every round flips model bits on one pseudo-randomly chosen
    /// replica; enabled extra fault kinds are rolled in per round.
    pub fn schedule(&self) -> Vec<FaultPlan> {
        let mut rng = self.seed;
        let mut plans = Vec::new();
        for _ in 0..self.rounds {
            let victim = (splitmix64(&mut rng) % self.replicas as u64) as usize;
            plans.push(FaultPlan::flip_model_bits(victim, splitmix64(&mut rng), self.flip_bits));
            let extra = (splitmix64(&mut rng) % self.replicas as u64) as usize;
            match splitmix64(&mut rng) % 8 {
                0 | 1 if self.stalls => {
                    plans.push(FaultPlan::stall(extra, Duration::from_millis(2)));
                }
                2 if self.panics => plans.push(FaultPlan::panic_on_job(extra, 1)),
                3 if self.drops => plans.push(FaultPlan::drop_reply(extra)),
                _ => {}
            }
        }
        plans
    }

    /// Arm the schedule against a live pool, pacing injections `gap`
    /// apart so faults land while traffic is in flight rather than
    /// stacking on the first pops.  Returns the per-kind tally.
    pub fn storm(&self, handle: &ServiceHandle, gap: Duration) -> ChaosReport {
        let mut report = ChaosReport::default();
        for plan in self.schedule() {
            match plan.fault {
                Fault::FlipModelBits { .. } => report.flips += 1,
                Fault::Stall(_) => report.stalls += 1,
                Fault::PanicOnJob { .. } => report.panics += 1,
                Fault::DropReply => report.drops += 1,
            }
            handle.inject_fault(plan);
            std::thread::sleep(gap);
        }
        report
    }
}
