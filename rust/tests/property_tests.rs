//! Randomized property tests (hand-rolled: proptest is unavailable in
//! the offline vendor set — the in-repo PRNG drives generation, failures
//! print the seed for replay).
//!
//! Invariants from DESIGN.md §6.

use rttm::accel::stream::{decode_stream, HeaderWidth, Message, StreamCodec};
use rttm::datasets::synth::XorShift64Star;
use rttm::isa;
use rttm::tm::{model::TMModel, reference};
use rttm::TMShape;

fn random_model(rng: &mut XorShift64Star, shape: &TMShape, density: f64) -> TMModel {
    let mut m = TMModel::empty(shape.clone());
    for class in 0..shape.classes {
        for clause in 0..shape.clauses {
            for lit in 0..shape.literals() {
                if rng.next_f64() < density {
                    m.set_include(class, clause, lit, true);
                }
            }
        }
    }
    m
}

fn random_shape(rng: &mut XorShift64Star) -> TMShape {
    TMShape::synthetic(
        1 + rng.below(24) as usize,
        1 + rng.below(5) as usize,
        1 + rng.below(12) as usize,
    )
}

/// ISA round-trip: encode -> walk == dense reference, for every input.
#[test]
fn prop_isa_walk_equals_dense_reference() {
    for seed in 0..120u64 {
        let mut rng = XorShift64Star::new(1000 + seed);
        let shape = random_shape(&mut rng);
        let density = rng.next_f64() * 0.4;
        let model = random_model(&mut rng, &shape, density);
        let instrs = isa::encode(&model);

        // 8 random datapoints per model.
        for _ in 0..8 {
            let feats: Vec<u8> = (0..shape.features)
                .map(|_| u8::from(rng.next_f64() < 0.5))
                .collect();
            let lits = reference::literals_from_features(&feats);
            let dense = reference::class_sums_dense(&model, &lits);
            let walked = isa::decode_infer(&instrs, &lits, shape.classes)
                .unwrap_or_else(|e| panic!("seed {seed}: decode error {e}"));
            assert_eq!(dense, walked, "seed {seed} shape {shape:?}");
        }
    }
}

/// Batched bit-sliced walk == 32 independent single-datapoint walks.
#[test]
fn prop_packed_walk_equals_32_singles() {
    for seed in 0..60u64 {
        let mut rng = XorShift64Star::new(9000 + seed);
        let shape = random_shape(&mut rng);
        let density = rng.next_f64() * 0.3;
        let model = random_model(&mut rng, &shape, density);
        let instrs = isa::encode(&model);

        let feat_rows: Vec<Vec<u8>> = (0..32)
            .map(|_| {
                (0..shape.features)
                    .map(|_| u8::from(rng.next_f64() < 0.5))
                    .collect()
            })
            .collect();
        let packed = isa::pack_features(&feat_rows);
        let batched = isa::decode_infer_packed(&instrs, &packed, shape.classes).unwrap();
        for (b, row) in feat_rows.iter().enumerate() {
            let lits = reference::literals_from_features(row);
            let single = isa::decode_infer(&instrs, &lits, shape.classes).unwrap();
            for m in 0..shape.classes {
                assert_eq!(batched[m][b], single[m], "seed {seed} class {m} dp {b}");
            }
        }
    }
}

/// Structural round-trip: encode -> decode_clauses reproduces every
/// non-empty clause (ordered, with polarity).
#[test]
fn prop_isa_structural_roundtrip() {
    for seed in 0..120u64 {
        let mut rng = XorShift64Star::new(5000 + seed);
        let shape = random_shape(&mut rng);
        let density = rng.next_f64() * 0.3;
        let model = random_model(&mut rng, &shape, density);
        let instrs = isa::encode(&model);
        let decoded =
            isa::encoder::decode_clauses(&instrs, shape.literals(), shape.classes).unwrap();

        for class in 0..shape.classes {
            let expect: Vec<(i32, Vec<usize>)> = (0..shape.clauses)
                .filter_map(|c| {
                    let tas = model.clause_includes(class, c);
                    (!tas.is_empty()).then(|| (TMModel::polarity(c), tas))
                })
                .collect();
            if expect.is_empty() {
                // Empty class -> exactly the tautology killer.
                assert_eq!(decoded[class], vec![(1, vec![0, 1])], "seed {seed}");
            } else {
                assert_eq!(decoded[class], expect, "seed {seed} class {class}");
            }
        }
    }
}

/// Stream protocol round-trip with random payloads and widths.
#[test]
fn prop_stream_roundtrip() {
    for seed in 0..80u64 {
        let mut rng = XorShift64Star::new(3000 + seed);
        let width = match rng.below(3) {
            0 => HeaderWidth::W16,
            1 => HeaderWidth::W32,
            _ => HeaderWidth::W64,
        };
        let codec = StreamCodec::new(width);
        let n_instr = 1 + rng.below(40) as usize;
        let instrs: Vec<isa::Instr> =
            (0..n_instr).map(|_| isa::Instr(rng.next_u64() as u16)).collect();
        let features = 1 + rng.below(30) as usize;
        let batches = 1 + rng.below(4) as usize;
        let feat_rows: Vec<Vec<u32>> = (0..batches)
            .map(|_| (0..features).map(|_| rng.next_u64() as u32).collect())
            .collect();

        let mut words = Vec::new();
        words.extend(codec.instruction_header(3, 50, n_instr).unwrap());
        words.extend(codec.pack_instructions(&instrs));
        words.extend(codec.feature_header(features, batches).unwrap());
        for row in &feat_rows {
            words.extend(codec.pack_feature_words(row));
        }

        let msgs = decode_stream(&codec, &words).unwrap();
        assert_eq!(msgs.len(), 2, "seed {seed}");
        assert_eq!(
            msgs[0],
            Message::Program { classes: 3, clauses: 50, instrs: instrs.clone() },
            "seed {seed}"
        );
        assert_eq!(msgs[1], Message::Infer { features, batches: feat_rows }, "seed {seed}");
    }
}

/// Instruction count formula matches the encoder.
#[test]
fn prop_instruction_count_formula() {
    for seed in 0..100u64 {
        let mut rng = XorShift64Star::new(7000 + seed);
        let shape = random_shape(&mut rng);
        let density = rng.next_f64() * 0.2;
        let model = random_model(&mut rng, &shape, density);
        assert_eq!(isa::encode(&model).len(), isa::instruction_count(&model), "seed {seed}");
    }
}

/// Corrupted streams never panic: they error or decode to something.
#[test]
fn prop_corrupted_streams_never_panic() {
    for seed in 0..200u64 {
        let mut rng = XorShift64Star::new(11000 + seed);
        let shape = random_shape(&mut rng);
        let model = random_model(&mut rng, &shape, 0.2);
        let mut instrs = isa::encode(&model);
        if instrs.is_empty() {
            continue;
        }
        // Flip a random bit in a random instruction.
        let i = rng.below(instrs.len() as u64) as usize;
        let bit = rng.below(16) as u16;
        instrs[i] = isa::Instr(instrs[i].0 ^ (1 << bit));
        let lits = vec![1u8; shape.literals()];
        // Must return (Ok or Err), not panic.
        let _ = isa::decode_infer(&instrs, &lits, shape.classes);
    }
}
