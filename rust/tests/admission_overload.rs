//! Saturation coverage for the admission front-end
//! (coordinator::admission + coordinator::server): classed storms at 2×
//! and 10× offered load, backpressure and shedding semantics, counter
//! reconciliation, byte-identical results at light load, and fault
//! recovery under overload via the `FaultPlan` surface.  Setup lives in
//! the shared pool harness.

#[path = "common/pool_harness.rs"]
mod pool_harness;

use std::time::Duration;

use pool_harness::{classed_load, spawn_harness, spawn_harness_cfg, trained, LoadOutcome};
use rttm::coordinator::admission::{ClassStats, PRIORITY_COUNT};
use rttm::coordinator::{
    AdmissionConfig, EngineSpec, FaultPlan, InferenceService, IntegrityConfig, PoolConfig,
    Priority, ShedPolicy,
};

/// Tight data-class queues that make overload observable: `Low` sheds
/// its oldest queued request, `Normal` rejects outright, the control
/// classes block (and are never refused).
fn overload_cfg(replicas: usize) -> PoolConfig {
    PoolConfig {
        replicas,
        admission: AdmissionConfig {
            queue_cap: [2, 2, 64, 64],
            policy: [
                ShedPolicy::ShedOldest,
                ShedPolicy::Reject,
                ShedPolicy::Block,
                ShedPolicy::Block,
            ],
        },
        autoscale: None,
        integrity: IntegrityConfig::default(),
    }
}

/// Per-class counter deltas across one storm.
fn class_deltas(
    before: &[ClassStats; PRIORITY_COUNT],
    after: &[ClassStats; PRIORITY_COUNT],
) -> [ClassStats; PRIORITY_COUNT] {
    let mut out: [ClassStats; PRIORITY_COUNT] = Default::default();
    for (slot, (a, b)) in out.iter_mut().zip(after.iter().zip(before)) {
        *slot = ClassStats {
            depth: a.depth - b.depth,
            admitted: a.admitted - b.admitted,
            rejected: a.rejected - b.rejected,
            shed: a.shed - b.shed,
            served: a.served - b.served,
            deadline_misses: a.deadline_misses - b.deadline_misses,
        };
    }
    out
}

#[test]
fn storms_shed_low_never_critical_and_counters_reconcile() {
    let (model, data) = trained(71);
    let pool = spawn_harness_cfg(EngineSpec::base(), overload_cfg(4));
    let h = pool.handle.clone();
    h.program(model).unwrap();
    let rows = data.xs[..16].to_vec();
    let want = h.infer(rows.clone()).unwrap();

    // Offered load is client count over replica count: 2× = 8 clients
    // on 4 replicas, 10× = 40.  Three quarters of the storm is Low bulk
    // traffic, one quarter is Critical control traffic.
    for mult in [2usize, 10] {
        let before = h.admission_stats().classes;
        // Wedge half the pool briefly so the storm actually saturates
        // (and the stall arm of FaultPlan sees storm conditions).
        h.inject_fault(FaultPlan::stall(0, Duration::from_millis(100)));
        h.inject_fault(FaultPlan::stall(1, Duration::from_millis(100)));
        let low_clients = 3 * mult;
        let crit_clients = mult;
        let low = {
            let h = h.clone();
            let rows = rows.clone();
            std::thread::spawn(move || classed_load(&h, &rows, Priority::Low, low_clients, 8))
        };
        let crit = {
            let h = h.clone();
            let rows = rows.clone();
            std::thread::spawn(move || {
                classed_load(&h, &rows, Priority::Critical, crit_clients, 8)
            })
        };
        let low: LoadOutcome = low.join().unwrap();
        let crit: LoadOutcome = crit.join().unwrap();
        let deltas = class_deltas(&before, &h.admission_stats().classes);

        // Critical is NEVER refused or shed, at either load.
        assert_eq!(crit.ok, (crit_clients * 8) as u64, "{mult}x: critical lost work");
        assert_eq!(deltas[Priority::Critical.index()].rejected, 0);
        assert_eq!(deltas[Priority::Critical.index()].shed, 0);

        // Client-side tallies reconcile with the pool's counters:
        // every submission is admitted or rejected, every admitted
        // request is served or shed, and the queues drained.
        let dl = &deltas[Priority::Low.index()];
        assert_eq!(dl.admitted + dl.rejected, low.submitted(), "{mult}x: low front door");
        assert_eq!(dl.admitted, dl.served + dl.shed, "{mult}x: low back door");
        assert_eq!(dl.served, low.ok, "{mult}x: low served");
        assert_eq!(dl.shed + dl.rejected, low.overloaded, "{mult}x: low losses");
        assert_eq!(dl.depth, 0, "{mult}x: low queue drained");
        let dc = &deltas[Priority::Critical.index()];
        assert_eq!(dc.admitted, crit.submitted());
        assert_eq!(dc.served, crit.ok);
        assert_eq!(dc.depth, 0);

        if mult == 10 {
            // ISSUE acceptance: under 10x load Low sheds nonzero while
            // Critical (asserted zero above) never does.
            assert!(dl.shed > 0, "10x storm must shed Low traffic (shed {})", dl.shed);
            assert!(low.overloaded > 0);
        }
        assert_eq!(low.other + crit.other, 0, "{mult}x: unexpected error flavours");
    }

    // The pool survived both storms: everyone alive, nothing wedged,
    // answers still byte-identical.
    assert_eq!(h.infer(rows).unwrap(), want);
    let stats = h.pool_stats();
    assert!(stats.replicas.iter().all(|r| r.alive));
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 0);
    pool.shutdown();
}

#[test]
fn light_mixed_class_load_is_lossless_and_byte_identical() {
    let (model, data) = trained(72);
    // Reference: a single service — the pre-sharding single-queue pool
    // was proven byte-identical to this in serving_pool.rs, so matching
    // it here proves the sharded front-end changed nothing.
    let mut single = InferenceService::new(EngineSpec::base().build());
    single.reprogram(&model).unwrap();
    let want = single.infer_all(&data.xs).unwrap();

    let pool = spawn_harness(EngineSpec::base(), 4);
    let h = pool.handle.clone();
    h.program(model).unwrap();

    // One client per class on a 4-replica pool (≤1× offered load);
    // every reply must be byte-identical to the reference.
    let clients: Vec<_> = Priority::ALL
        .iter()
        .map(|&class| {
            let h = h.clone();
            let xs = data.xs.clone();
            let want = want.clone();
            std::thread::spawn(move || {
                for _ in 0..4 {
                    assert_eq!(h.infer_class(xs.clone(), class).unwrap(), want);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    // Zero losses at light load: everything admitted, everything served.
    let stats = h.admission_stats();
    for class in Priority::ALL {
        let c = stats.class(class);
        assert_eq!(c.admitted, 4, "class {class}");
        assert_eq!(c.served, 4, "class {class}");
        assert_eq!(c.rejected + c.shed + c.depth, 0, "class {class}");
    }
    pool.shutdown();
}

#[test]
fn fault_storm_recovers_without_permanent_stalls() {
    let (model, data) = trained(73);
    let pool = spawn_harness(EngineSpec::base(), 4);
    let h = pool.handle.clone();
    h.program(model).unwrap();
    let rows = data.xs[..16].to_vec();
    let want = h.infer(rows.clone()).unwrap();

    // All three fault flavours armed at once, then a storm on top: the
    // stall must clear, the panic must respawn its replica, the dropped
    // reply must surface as a typed error — and nothing may wedge.
    h.inject_fault(FaultPlan::stall(0, Duration::from_millis(150)));
    h.inject_fault(FaultPlan::panic_on_job(1, 3));
    h.inject_fault(FaultPlan::drop_reply(2));
    let out = classed_load(&h, &rows, Priority::Normal, 16, 6);
    assert_eq!(out.submitted(), 96);
    // Exactly two requests may fail: the panic victim and the dropped
    // reply (both are `other`); admission itself refuses nothing.
    assert_eq!(out.overloaded + out.deadline, 0);
    assert!(out.other <= 2, "at most the two fault victims fail, got {}", out.other);
    assert!(out.ok >= 94);

    // Recovery: the panicked replica respawned, everyone alive, the
    // same handle keeps serving byte-identical answers immediately.
    assert_eq!(h.infer(rows).unwrap(), want);
    let stats = h.pool_stats();
    assert!(stats.replicas.iter().all(|r| r.alive));
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 1);
    pool.shutdown();
}

#[test]
fn deadline_storm_sheds_unexecuted_and_counts_misses() {
    let (model, data) = trained(74);
    let pool = spawn_harness(EngineSpec::base(), 1);
    let h = pool.handle.clone();
    h.program(model).unwrap();
    let rows = data.xs[..16].to_vec();
    // Warm the service-time estimator so feasibility has authority.
    h.infer(rows.clone()).unwrap();

    // Wedge the lone replica, then pour deadline traffic behind it:
    // every request resolves quickly as the typed error (feasibility
    // reject at submit or expiry shed at pop), nothing blocks out the
    // stall, and misses are counted.
    let stall = h.inject_stall(Duration::from_millis(400)).unwrap();
    let t0 = std::time::Instant::now();
    let mut deadline_errors = 0u64;
    for _ in 0..16 {
        match h.infer_deadline(rows.clone(), Duration::from_millis(10)) {
            Err(rttm::coordinator::ServeError::DeadlineExceeded) => deadline_errors += 1,
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    assert!(
        t0.elapsed() < Duration::from_millis(350),
        "deadline traffic must not wait out the stall"
    );
    assert_eq!(deadline_errors, 16);
    stall.recv().unwrap().unwrap();

    // All 16 are recorded as deadline misses (rejected at submit or
    // shed at pop — both count), and none of them executed.
    let wait_until = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let normal = h.admission_stats().classes[Priority::Normal.index()].clone();
        if normal.depth == 0 && normal.deadline_misses >= 16 {
            assert_eq!(normal.admitted + normal.rejected, 18); // warmup + stall + 16
            assert_eq!(normal.admitted, normal.served + normal.shed);
            break;
        }
        assert!(std::time::Instant::now() < wait_until, "misses never reconciled");
        std::thread::yield_now();
    }
    // The pool is healthy afterwards.
    assert_eq!(h.infer(rows.clone()).unwrap().len(), 16);
    pool.shutdown();
}
