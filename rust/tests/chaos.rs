//! Composable chaos proof for the self-healing integrity layer: a
//! seeded storm of model-memory bit flips, worker stalls, panics and
//! dropped replies against a scrubbed replica pool must never produce a
//! single divergent reply (every `Ok` answer is byte-identical to a
//! golden single-service reference), must reconcile the admission
//! counters exactly, and must leave the pool fully healed — every
//! detected corruption healed or accounted, every quarantined replica
//! readmitted.
//!
//! The storm schedule is a pure function of its seed
//! ([`ChaosPlan::schedule`]): a failing run replays bit-for-bit.

#[path = "common/pool_harness.rs"]
mod pool_harness;

use std::time::{Duration, Instant};

use pool_harness::{spawn_harness_cfg, trained, ChaosPlan, LoadOutcome, Traffic};
use rttm::coordinator::server::ServeError;
use rttm::coordinator::{
    AdmissionConfig, EngineSpec, Fault, FaultPlan, InferenceService, IntegrityConfig, PoolConfig,
    Priority, ShedPolicy,
};

/// Scrubbed 3-replica pool with a fast, test-scale breaker: 2 strikes
/// in the window quarantine, holds are tens of milliseconds so rejoin
/// happens inside the test.
fn chaos_cfg() -> PoolConfig {
    PoolConfig {
        replicas: 3,
        admission: AdmissionConfig::uniform(16, ShedPolicy::Reject),
        autoscale: None,
        integrity: IntegrityConfig {
            scrub_interval: Some(Duration::from_millis(3)),
            breaker_trips: 2,
            breaker_window: Duration::from_secs(10),
            quarantine_base: Duration::from_millis(20),
            quarantine_max: Duration::from_millis(100),
        },
    }
}

/// Poll `ok` every 5ms until it holds or `timeout` elapses; returns the
/// final verdict (one last check after the deadline, so a slow machine
/// that settles late still passes).
fn poll_until(timeout: Duration, mut ok: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if ok() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    ok()
}

/// The tentpole proof: flips + stalls + panics + dropped replies,
/// composed and seeded, against 2x classed load on a 3-replica pool.
/// Zero reply divergence, exact counter reconciliation, full heal.
#[test]
fn composed_chaos_storm_serves_golden_bytes_and_fully_heals() {
    let (model, data) = trained(7);

    // Golden reference: what a clean, un-attacked single service says.
    let mut golden = InferenceService::new(EngineSpec::base().build());
    golden.reprogram(&model).unwrap();
    let want = golden.infer_all(&data.xs).unwrap();

    let pool = spawn_harness_cfg(EngineSpec::base(), chaos_cfg());
    let h = pool.handle.clone();
    h.program(model).unwrap();

    // Two deterministic strikes against replica 0 on top of the storm:
    // with `breaker_trips: 2` the quarantine -> half-open -> rejoin arc
    // is exercised on every run, independent of the storm's
    // pseudo-random panic rolls.
    h.inject_fault(FaultPlan::panic_on_job(0, 1));
    h.inject_fault(FaultPlan::panic_on_job(0, 1));

    // 2x classed load: 6 clients against 3 replicas, split across the
    // data classes.  Every Ok reply is checked byte-for-byte against
    // the golden reference — a single divergent answer fails the run.
    let rows = data.xs[..48].to_vec();
    let expect = want[..48].to_vec();
    let clients: Vec<_> = (0..6)
        .map(|i| {
            let h = h.clone();
            let rows = rows.clone();
            let expect = expect.clone();
            std::thread::spawn(move || {
                let class = if i % 2 == 0 { Priority::Normal } else { Priority::Low };
                let mut out = LoadOutcome::default();
                for _ in 0..30 {
                    match h.infer_class(rows.clone(), class) {
                        Ok(preds) => {
                            assert_eq!(preds, expect, "reply divergence under chaos");
                            out.ok += 1;
                        }
                        Err(ServeError::Overloaded) => out.overloaded += 1,
                        Err(ServeError::DeadlineExceeded) => out.deadline += 1,
                        // WorkerPanicked / WorkerGone: the storm's
                        // visible (and retryable) casualties.
                        Err(_) => out.other += 1,
                    }
                }
                out
            })
        })
        .collect();

    // The storm runs on the main thread while the clients hammer.
    let report = ChaosPlan::new(0x00C4_A05E, 3)
        .rounds(24)
        .flip_bits(6)
        .with_stalls()
        .with_panics()
        .with_drops()
        .storm(&h, Duration::from_millis(2));
    assert_eq!(report.flips, 24, "every round must flip model bits");

    let mut total = LoadOutcome::default();
    for c in clients {
        total.absorb(&c.join().expect("chaos client panicked (reply divergence?)"));
    }
    assert!(total.ok > 0, "nothing served through the storm: {total:?}");

    // Clean sweep: the healed pool must answer the full dataset golden
    // SIX CONSECUTIVE times (round-robin coverage across the replicas).
    // Retry-on-error also drains any fault still armed from the storm —
    // each retry pops it through the real supervision path.
    let t0 = Instant::now();
    let mut clean = 0;
    while clean < 6 {
        match h.infer(data.xs.clone()) {
            Ok(preds) => {
                assert_eq!(preds, want, "post-heal divergence");
                clean += 1;
            }
            Err(e) => {
                assert!(
                    t0.elapsed() < Duration::from_secs(20),
                    "pool still failing long after the storm: {e}"
                );
                clean = 0;
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }

    // Full heal: every detected corruption healed or accounted as a
    // failed heal, every quarantined replica readmitted (the scrubber
    // re-visits every replica every few ms, so stragglers converge).
    let settled = poll_until(Duration::from_secs(20), || {
        let s = h.pool_stats().integrity;
        s.quarantines >= 1
            && s.quarantines == s.rejoins
            && s.corruptions_detected == s.heals + s.failed_heals
    });
    let s = h.pool_stats().integrity;
    assert!(settled, "pool never settled clean after the storm: {s:?}");
    assert!(s.corruptions_detected >= 1, "no flip was ever detected: {s:?}");
    assert!(s.heals >= 1, "no corruption was ever healed: {s:?}");
    assert!(s.scrubs > s.corruptions_detected, "scrub accounting inverted: {s:?}");

    pool.shutdown();

    // Exact reconciliation after teardown: every admitted request —
    // client traffic and background scrubs alike — is accounted served
    // or shed, nothing lost, nothing queued.
    let stats = h.admission_stats();
    for p in Priority::ALL {
        let c = stats.class(p);
        assert_eq!(
            c.admitted,
            c.served + c.shed,
            "class {p}: admitted != served + shed after teardown ({c:?})"
        );
        assert_eq!(c.depth, 0, "class {p}: queue not drained ({c:?})");
    }
}

/// Bit flips alone are fully invisible to clients: the pre-serve verify
/// heals in place before any answer is computed, so a flip-only storm
/// produces zero request errors, zero quarantines, zero failed heals —
/// and the heal counter accounts every detection.
#[test]
fn bit_flip_only_storm_heals_in_place_without_client_visible_errors() {
    let (model, data) = trained(11);
    let mut golden = InferenceService::new(EngineSpec::base().build());
    golden.reprogram(&model).unwrap();
    let want = golden.infer_all(&data.xs).unwrap();

    let pool = spawn_harness_cfg(EngineSpec::base(), chaos_cfg());
    let h = pool.handle.clone();
    h.program(model).unwrap();

    // Traffic::stop_assert_clean is the whole point: not one request
    // may fail while model memory is being corrupted under it.
    let traffic = Traffic::start(h.clone(), data.xs.clone());
    let report = ChaosPlan::new(0xF11B, 3)
        .rounds(16)
        .flip_bits(4)
        .storm(&h, Duration::from_millis(2));
    assert_eq!(report.armed(), report.flips, "flip-only storm armed extra fault kinds");

    let settled = poll_until(Duration::from_secs(20), || {
        let s = h.pool_stats().integrity;
        s.corruptions_detected >= 1 && s.corruptions_detected == s.heals
    });
    traffic.stop_assert_clean();
    let s = h.pool_stats().integrity;
    assert!(settled, "flip storm never detected+healed: {s:?}");
    assert_eq!(s.failed_heals, 0, "in-place heal failed: {s:?}");
    assert_eq!(s.quarantines, 0, "a healed flip must not trip the breaker: {s:?}");

    assert_eq!(h.infer(data.xs.clone()).unwrap(), want, "post-heal divergence");
    pool.shutdown();
}

/// The storm schedule is a pure function of its seed: same seed, same
/// fault sequence, bit for bit; a different seed diverges.  Every fault
/// targets a replica inside the pool, and every round contributes its
/// bit flip.
#[test]
fn chaos_schedule_is_a_pure_function_of_its_seed() {
    let mk = |seed: u64| {
        ChaosPlan::new(seed, 3)
            .rounds(32)
            .flip_bits(5)
            .with_stalls()
            .with_panics()
            .with_drops()
            .schedule()
    };
    let a = mk(42);
    assert_eq!(
        format!("{a:?}"),
        format!("{:?}", mk(42)),
        "same seed must replay the same storm"
    );
    assert_ne!(format!("{a:?}"), format!("{:?}", mk(43)), "different seeds must differ");

    for p in &a {
        assert!(p.replica < 3, "fault routed past the pool: {p:?}");
    }
    let flips = a.iter().filter(|p| matches!(p.fault, Fault::FlipModelBits { .. })).count();
    assert_eq!(flips, 32, "every round contributes exactly one bit-flip fault");
    assert!(a.len() >= 32, "extras may ride along but never replace the flips");
}
