//! Engine equivalence properties: the SoA execution engine
//! (predecoded branch-free walk, zero-alloc batches, threaded
//! multi-core) must be byte-identical — `preds`, `class_sums` AND
//! `CycleStats` — to the ISA software walk (`isa::decode_infer_packed`),
//! to the dense reference, and to an independent reimplementation of
//! the pre-SoA per-batch loop (`DecodeWalk` + `apply_commit`).
//!
//! The cycle model simulates the eFPGA; the SoA rebuild may only change
//! host wall-clock, never a single simulated cycle.

use rttm::accel::core::{AccelConfig, BatchResult, Core, CycleStats, PipelineMode};
use rttm::accel::multicore::{MultiCore, ParallelMode};
use rttm::accel::stream::StreamCodec;
use rttm::datasets::synth::XorShift64Star;
use rttm::isa::{self, DecodeWalk, Instr};
use rttm::tm::{model::TMModel, reference};
use rttm::TMShape;

/// Random dense model; classes listed in `empty` stay include-free so
/// the encoder's tautology-killer clauses are exercised.
fn random_model(rng: &mut XorShift64Star, shape: &TMShape, density: f64, empty: &[usize]) -> TMModel {
    let mut m = TMModel::empty(shape.clone());
    for class in 0..shape.classes {
        if empty.contains(&class) {
            continue;
        }
        for clause in 0..shape.clauses {
            for lit in 0..shape.literals() {
                if rng.next_f64() < density {
                    m.set_include(class, clause, lit, true);
                }
            }
        }
    }
    m
}

fn random_rows(rng: &mut XorShift64Star, features: usize) -> Vec<Vec<u8>> {
    (0..32)
        .map(|_| (0..features).map(|_| u8::from(rng.next_f64() < 0.5)).collect())
        .collect()
}

/// Independent oracle: the pre-SoA per-batch hot loop, reimplemented
/// from `DecodeWalk` exactly as the seed `Core::run_batch` executed it
/// (branchy commit Option, literal-select branch).  Returns per-class
/// sums and the clause-commit count.
fn legacy_walk(instrs: &[Instr], packed: &[u32], classes: usize) -> (Vec<[i32; 32]>, u64) {
    let mut sums = vec![[0i32; 32]; classes];
    let mut clause_count = 0u64;
    let mut walk = DecodeWalk::new(classes.max(1));
    let mut cur = u32::MAX;
    for (i, &ins) in instrs.iter().enumerate() {
        let (ta, commit) = walk.step(i, ins, isa::MAX_LITERALS).unwrap();
        if let Some((cls, pol, _)) = commit {
            isa::apply_commit(&mut sums, (cls, pol, cur));
            clause_count += 1;
            cur = u32::MAX;
        }
        let w = packed[ta >> 1];
        cur &= if ins.complement() { !w } else { w };
    }
    if let Some((cls, pol, _)) = walk.finish() {
        isa::apply_commit(&mut sums, (cls, pol, cur));
        clause_count += 1;
    }
    (sums, clause_count)
}

/// The Fig 5 cycle model computed independently of the Core.
fn expected_cycles(
    codec: &StreamCodec,
    mode: PipelineMode,
    n_instrs: usize,
    n_feature_words: usize,
    classes: usize,
    clause_count: u64,
) -> CycleStats {
    CycleStats {
        program: 0,
        feature_load: 2 + codec.feature_payload_len(n_feature_words) as u64,
        execute: match mode {
            PipelineMode::Pipelined => {
                if n_instrs == 0 {
                    0
                } else {
                    3 + n_instrs as u64
                }
            }
            PipelineMode::Iterative => 4 * n_instrs as u64,
        },
        commit: clause_count,
        argmax: classes as u64,
        fifo: 8,
    }
}

#[test]
fn soa_core_matches_isa_walk_dense_reference_and_legacy_loop() {
    for seed in 0..60u64 {
        let mut rng = XorShift64Star::new(40_000 + seed);
        let shape = TMShape::synthetic(
            1 + rng.below(24) as usize,
            1 + rng.below(5) as usize,
            1 + rng.below(12) as usize,
        );
        // Roughly every third model gets an include-free class 0
        // (runtime re-tuning can produce these; the encoder emits the
        // tautology-killer pair for them).
        let empty: Vec<usize> = if seed % 3 == 0 { vec![0] } else { vec![] };
        let density = rng.next_f64() * 0.3;
        let model = random_model(&mut rng, &shape, density, &empty);
        let instrs = isa::encode(&model);
        let rows = random_rows(&mut rng, shape.features);
        let packed = isa::pack_features(&rows);

        // Oracles.
        let isa_sums = isa::decode_infer_packed(&instrs, &packed, shape.classes).unwrap();
        let (legacy_sums, legacy_clauses) = legacy_walk(&instrs, &packed, shape.classes);
        assert_eq!(isa_sums, legacy_sums, "seed {seed}: oracles disagree");

        for mode in [PipelineMode::Pipelined, PipelineMode::Iterative] {
            let mut core = Core::new(AccelConfig::base().with_pipeline(mode));
            core.program(shape.classes, shape.clauses, &instrs).unwrap();
            let r = core.run_batch(&packed).unwrap();

            assert_eq!(r.class_sums, isa_sums, "seed {seed} {mode:?}: class_sums");
            let want = expected_cycles(
                &core.codec,
                mode,
                instrs.len(),
                packed.len(),
                shape.classes,
                legacy_clauses,
            );
            assert_eq!(r.cycles, want, "seed {seed} {mode:?}: CycleStats");

            // Predictions match the dense reference lane by lane.
            for (b, row) in rows.iter().enumerate() {
                let lits = reference::literals_from_features(row);
                assert_eq!(
                    r.preds[b] as usize,
                    reference::predict_dense(&model, &lits),
                    "seed {seed} {mode:?} dp {b}"
                );
            }
        }
    }
}

#[test]
fn run_batches_and_run_batch_into_are_byte_identical() {
    for seed in 0..20u64 {
        let mut rng = XorShift64Star::new(50_000 + seed);
        let shape = TMShape::synthetic(
            2 + rng.below(16) as usize,
            1 + rng.below(4) as usize,
            1 + rng.below(8) as usize,
        );
        let model = random_model(&mut rng, &shape, 0.2, &[]);
        let batches: Vec<Vec<u32>> = (0..4)
            .map(|_| isa::pack_features(&random_rows(&mut rng, shape.features)))
            .collect();
        let refs: Vec<&[u32]> = batches.iter().map(|b| b.as_slice()).collect();

        let mut a = Core::new(AccelConfig::base());
        a.program_model(&model).unwrap();
        let singles: Vec<BatchResult> = refs.iter().map(|&b| a.run_batch(b).unwrap()).collect();

        let mut b = Core::new(AccelConfig::base());
        b.program_model(&model).unwrap();
        let streamed = b.run_batches(&refs).unwrap();
        assert_eq!(streamed, singles, "seed {seed}: run_batches");
        assert_eq!(a.stats, b.stats, "seed {seed}: lifetime stats");

        // Reusing one result buffer across the stream changes nothing.
        let mut c = Core::new(AccelConfig::base());
        c.program_model(&model).unwrap();
        let mut reused = BatchResult::default();
        for (i, &batch) in refs.iter().enumerate() {
            c.run_batch_into(batch, &mut reused).unwrap();
            assert_eq!(reused, singles[i], "seed {seed} batch {i}: run_batch_into");
        }
    }
}

#[test]
fn multicore_threaded_serial_and_single_core_agree() {
    for seed in 0..12u64 {
        let mut rng = XorShift64Star::new(60_000 + seed);
        let classes = 2 + rng.below(9) as usize;
        let shape = TMShape::synthetic(2 + rng.below(16) as usize, classes, 1 + rng.below(8) as usize);
        let empty: Vec<usize> = if seed % 4 == 0 { vec![classes - 1] } else { vec![] };
        let model = random_model(&mut rng, &shape, 0.15, &empty);
        let rows = random_rows(&mut rng, shape.features);
        let packed = isa::pack_features(&rows);

        let mut single = Core::new(AccelConfig::single_core());
        single.program_model(&model).unwrap();
        let rs = single.run_batch(&packed).unwrap();

        let mut serial = MultiCore::five_core().with_parallel(ParallelMode::Serial);
        serial.program_model(&model).unwrap();
        let mut threaded = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        threaded.program_model(&model).unwrap();

        let a = serial.run_batch(&packed).unwrap();
        let b = threaded.run_batch(&packed).unwrap();
        assert_eq!(a.class_sums, b.class_sums, "seed {seed}");
        assert_eq!(a.preds, b.preds, "seed {seed}");
        assert_eq!(a.batch_cycles, b.batch_cycles, "seed {seed}");
        assert_eq!(a.per_core, b.per_core, "seed {seed}");

        assert_eq!(a.class_sums, rs.class_sums, "seed {seed}: vs single core");
        assert_eq!(a.preds, rs.preds, "seed {seed}: vs single core");

        // Stream path agrees with the one-batch path.
        let mut stream = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        stream.program_model(&model).unwrap();
        let rs2 = stream.run_batches(&[&packed[..], &packed[..]]).unwrap();
        for r in &rs2 {
            assert_eq!(r.class_sums, a.class_sums, "seed {seed}: run_batches");
            assert_eq!(r.batch_cycles, a.batch_cycles, "seed {seed}: run_batches");
        }
    }
}

// ---------------------------------------------------------------------
// §sliced — the 64-lane bit-sliced kernel must be byte-identical to the
// 32-lane SoA walk and the dense reference: preds, per-row class sums
// AND margins, for random models (tautology-killer classes and
// exclude-only clauses included) over ragged row counts.
// ---------------------------------------------------------------------

/// Rows of a random batch of arbitrary size.
fn random_rows_n(rng: &mut XorShift64Star, features: usize, n: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|_| (0..features).map(|_| u8::from(rng.next_f64() < 0.5)).collect())
        .collect()
}

/// Clear some clauses entirely (exclude-only clauses: no includes —
/// the encoder skips them, so every path must agree they contribute
/// nothing).
fn clear_clause(m: &mut TMModel, class: usize, clause: usize) {
    for lit in 0..m.shape.literals() {
        m.set_include(class, clause, lit, false);
    }
}

#[test]
fn sliced_kernel_matches_soa_and_dense_reference_over_ragged_row_counts() {
    for seed in 0..12u64 {
        let mut rng = XorShift64Star::new(70_000 + seed);
        let shape = TMShape::synthetic(
            2 + rng.below(20) as usize,
            1 + rng.below(5) as usize,
            1 + rng.below(10) as usize,
        );
        // Tautology-killer coverage: every third model gets an
        // include-free class; exclude-only coverage: every fourth
        // model gets clause 0 of class 0 cleared after fill.
        let empty: Vec<usize> = if seed % 3 == 0 { vec![0] } else { vec![] };
        let mut model = random_model(&mut rng, &shape, rng.next_f64() * 0.3, &empty);
        if seed % 4 == 0 && !empty.contains(&0) {
            clear_clause(&mut model, 0, 0);
        }
        let instrs = isa::encode(&model);

        for n in [1usize, 63, 64, 65, 1000] {
            // Keep the big case to a few seeds so tier-1 stays fast.
            if n == 1000 && seed >= 4 {
                continue;
            }
            let rows = random_rows_n(&mut rng, shape.features, n);

            // 32-lane oracle: per-batch SoA walk.
            let mut soa = Core::new(AccelConfig::base());
            soa.program(shape.classes, shape.clauses, &instrs).unwrap();
            let mut soa_preds: Vec<u8> = Vec::new();
            let mut soa_sums: Vec<Vec<i32>> = Vec::new(); // per row, per class
            let mut soa_margins: Vec<i32> = Vec::new();
            for chunk in rows.chunks(32) {
                let r = soa.run_batch(&isa::pack_features(chunk)).unwrap();
                for lane in 0..chunk.len() {
                    soa_preds.push(r.preds[lane]);
                    soa_sums.push(r.class_sums.iter().map(|s| s[lane]).collect());
                }
                soa_margins
                    .extend(rttm::accel::engine::margins_from_sums(&r.class_sums, chunk.len()));
            }

            // Sliced path, via the core-level kernel (cloned out of
            // the scratch so the core is free for the stats asserts).
            let mut sliced = Core::new(AccelConfig::base());
            sliced.program(shape.classes, shape.clauses, &instrs).unwrap();
            let r = sliced.run_rows_sliced_ref(&rows).unwrap().clone();
            assert_eq!(r.rows, n, "seed {seed} n {n}");
            for row in 0..n {
                assert_eq!(r.preds[row], soa_preds[row], "seed {seed} n {n} row {row}: preds");
                for class in 0..shape.classes {
                    assert_eq!(
                        r.class_sum(class, row),
                        soa_sums[row][class],
                        "seed {seed} n {n} row {row} class {class}: sums"
                    );
                }
            }
            // Lifetime accounting keeps parity with the per-batch walk.
            assert_eq!(sliced.stats, soa.stats, "seed {seed} n {n}: stats");
            assert_eq!(sliced.batches_run, soa.batches_run, "seed {seed} n {n}");

            // Dense reference per row.
            for (row, x) in rows.iter().enumerate() {
                let lits = reference::literals_from_features(x);
                assert_eq!(
                    r.preds[row] as usize,
                    reference::predict_dense(&model, &lits),
                    "seed {seed} n {n} row {row}: dense preds"
                );
            }

            // Engine-level margins path (pinned kernels on fresh cores
            // so StreamStats and scratch reuse are exercised too).
            let mut a = Core::new(AccelConfig::base());
            a.program(shape.classes, shape.clauses, &instrs).unwrap();
            let (p_soa, m_soa, s_soa) =
                rttm::accel::engine::classify_rows_margins_core_soa(&mut a, &rows).unwrap();
            let mut b = Core::new(AccelConfig::base());
            b.program(shape.classes, shape.clauses, &instrs).unwrap();
            let (p_sl, m_sl, s_sl) =
                rttm::accel::engine::classify_rows_margins_core(&mut b, &rows).unwrap();
            if n >= rttm::accel::engine::SLICED_MIN_ROWS {
                // Above the threshold the auto path really is sliced —
                // same answers, same simulated accounting.
                assert_eq!(s_sl.simulated_cycles, s_soa.simulated_cycles, "seed {seed} n {n}");
                assert_eq!(s_sl.batches, s_soa.batches, "seed {seed} n {n}");
            }
            assert_eq!(p_sl, p_soa, "seed {seed} n {n}: engine preds");
            assert_eq!(m_sl, m_soa, "seed {seed} n {n}: engine margins");
            assert_eq!(m_sl, soa_margins, "seed {seed} n {n}: margins vs oracle");
        }
    }
}

#[test]
fn sliced_multicore_matches_sliced_single_core_over_ragged_row_counts() {
    for seed in 0..6u64 {
        let mut rng = XorShift64Star::new(80_000 + seed);
        let classes = 2 + rng.below(7) as usize;
        let features = 2 + rng.below(16) as usize;
        let shape = TMShape::synthetic(features, classes, 1 + rng.below(8) as usize);
        let empty: Vec<usize> = if seed % 2 == 0 { vec![classes - 1] } else { vec![] };
        let model = random_model(&mut rng, &shape, 0.2, &empty);
        let n = [1usize, 65, 300][(seed % 3) as usize];
        let rows = random_rows_n(&mut rng, shape.features, n);

        let mut single = Core::new(AccelConfig::single_core());
        single.program_model(&model).unwrap();
        let sref = single.run_rows_sliced_ref(&rows).unwrap();
        let want: Vec<u8> = sref.preds[..n].to_vec();
        let want_sums: Vec<Vec<i32>> = (0..n)
            .map(|row| (0..classes).map(|c| sref.class_sum(c, row)).collect())
            .collect();

        for mode in [ParallelMode::Serial, ParallelMode::Threads] {
            let mut mc = MultiCore::five_core().with_parallel(mode);
            mc.program_model(&model).unwrap();
            let r = mc.run_rows_sliced_ref(&rows).unwrap();
            assert_eq!(&r.preds[..n], &want[..], "seed {seed} {mode:?} n {n}");
            for row in 0..n {
                for class in 0..classes {
                    assert_eq!(
                        r.class_sum(class, row),
                        want_sums[row][class],
                        "seed {seed} {mode:?} row {row} class {class}"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// §compressed — the sparse include-list gather kernel (pruning off)
// must be byte-identical to BOTH the 32-lane SoA walk and the dense
// 64-lane sliced kernel: preds, per-row class sums, margins AND the
// simulated cycle model, on random SPARSE and DENSE models (tautology
// killers and exclude-only clauses included) over ragged row counts.
// Auto kernel selection is density-driven and must never change a byte.
// ---------------------------------------------------------------------

#[test]
fn compressed_kernel_matches_soa_and_sliced_over_ragged_row_counts() {
    for seed in 0..12u64 {
        let mut rng = XorShift64Star::new(90_000 + seed);
        let shape = TMShape::synthetic(
            2 + rng.below(20) as usize,
            1 + rng.below(5) as usize,
            1 + rng.below(10) as usize,
        );
        // Even seeds: sparse models (the kernel's home turf); odd
        // seeds: dense models (the equivalence still has to hold).
        let density = if seed % 2 == 0 { 0.02 } else { 0.1 + rng.next_f64() * 0.3 };
        let empty: Vec<usize> = if seed % 3 == 0 { vec![0] } else { vec![] };
        let mut model = random_model(&mut rng, &shape, density, &empty);
        if seed % 4 == 0 && !empty.contains(&0) {
            clear_clause(&mut model, 0, 0);
        }
        let instrs = isa::encode(&model);

        for n in [1usize, 63, 64, 65, 1000] {
            if n == 1000 && seed >= 4 {
                continue;
            }
            let rows = random_rows_n(&mut rng, shape.features, n);

            // 32-lane oracle: per-batch SoA walk.
            let mut soa = Core::new(AccelConfig::base());
            soa.program(shape.classes, shape.clauses, &instrs).unwrap();
            let mut soa_preds: Vec<u8> = Vec::new();
            let mut soa_sums: Vec<Vec<i32>> = Vec::new();
            for chunk in rows.chunks(32) {
                let r = soa.run_batch(&isa::pack_features(chunk)).unwrap();
                for lane in 0..chunk.len() {
                    soa_preds.push(r.preds[lane]);
                    soa_sums.push(r.class_sums.iter().map(|s| s[lane]).collect());
                }
            }

            // Pinned sliced and pinned compressed runs on fresh cores:
            // the ENTIRE result struct must match — per-row sums,
            // preds, padding lanes, simulated cycles.
            let mut sl = Core::new(AccelConfig::base());
            sl.program(shape.classes, shape.clauses, &instrs).unwrap();
            let want = sl.run_rows_sliced_ref(&rows).unwrap().clone();
            let mut cp = Core::new(AccelConfig::base());
            cp.program(shape.classes, shape.clauses, &instrs).unwrap();
            let got = cp.run_rows_compressed_ref(&rows).unwrap().clone();
            assert_eq!(got, want, "seed {seed} n {n}: compressed vs sliced result");
            assert_eq!(cp.stats, sl.stats, "seed {seed} n {n}: lifetime stats");
            assert_eq!(cp.stats, soa.stats, "seed {seed} n {n}: stats vs SoA walk");
            assert_eq!(cp.batches_run, soa.batches_run, "seed {seed} n {n}");

            // ... and row by row against the SoA oracle and the dense
            // reference.
            for row in 0..n {
                assert_eq!(got.preds[row], soa_preds[row], "seed {seed} n {n} row {row}: preds");
                for class in 0..shape.classes {
                    assert_eq!(
                        got.class_sum(class, row),
                        soa_sums[row][class],
                        "seed {seed} n {n} row {row} class {class}: sums"
                    );
                }
            }
            for (row, x) in rows.iter().enumerate() {
                let lits = reference::literals_from_features(x);
                assert_eq!(
                    got.preds[row] as usize,
                    reference::predict_dense(&model, &lits),
                    "seed {seed} n {n} row {row}: dense preds"
                );
            }

            // Engine-level pinned paths agree too (StreamStats and the
            // chunked drive included).
            let mut a = Core::new(AccelConfig::base());
            a.program(shape.classes, shape.clauses, &instrs).unwrap();
            let (p_sl, s_sl) =
                rttm::accel::engine::classify_rows_core_sliced(&mut a, &rows).unwrap();
            let mut b = Core::new(AccelConfig::base());
            b.program(shape.classes, shape.clauses, &instrs).unwrap();
            let (p_cp, s_cp) =
                rttm::accel::engine::classify_rows_core_compressed(&mut b, &rows).unwrap();
            assert_eq!(p_cp, p_sl, "seed {seed} n {n}: engine preds");
            assert_eq!(s_cp.simulated_cycles, s_sl.simulated_cycles, "seed {seed} n {n}");
            assert_eq!(s_cp.batches, s_sl.batches, "seed {seed} n {n}");
        }
    }
}

#[test]
fn compressed_multicore_matches_compressed_single_core_over_ragged_row_counts() {
    for seed in 0..6u64 {
        let mut rng = XorShift64Star::new(95_000 + seed);
        let classes = 2 + rng.below(7) as usize;
        let features = 2 + rng.below(16) as usize;
        let shape = TMShape::synthetic(features, classes, 1 + rng.below(8) as usize);
        let empty: Vec<usize> = if seed % 2 == 0 { vec![classes - 1] } else { vec![] };
        let density = if seed % 2 == 0 { 0.03 } else { 0.2 };
        let model = random_model(&mut rng, &shape, density, &empty);
        let n = [1usize, 65, 300][(seed % 3) as usize];
        let rows = random_rows_n(&mut rng, shape.features, n);

        let mut single = Core::new(AccelConfig::single_core());
        single.program_model(&model).unwrap();
        let sref = single.run_rows_compressed_ref(&rows).unwrap();
        let want: Vec<u8> = sref.preds[..n].to_vec();
        let want_sums: Vec<Vec<i32>> = (0..n)
            .map(|row| (0..classes).map(|c| sref.class_sum(c, row)).collect())
            .collect();

        for mode in [ParallelMode::Serial, ParallelMode::Threads] {
            let mut mc = MultiCore::five_core().with_parallel(mode);
            mc.program_model(&model).unwrap();
            let r = mc.run_rows_compressed_ref(&rows).unwrap();
            assert_eq!(&r.preds[..n], &want[..], "seed {seed} {mode:?} n {n}");
            for row in 0..n {
                for class in 0..classes {
                    assert_eq!(
                        r.class_sum(class, row),
                        want_sums[row][class],
                        "seed {seed} {mode:?} row {row} class {class}"
                    );
                }
            }
            // The multicore sliced walk over the same rows is the same
            // merged result, kernel notwithstanding.
            let mut mc2 = MultiCore::five_core().with_parallel(mode);
            mc2.program_model(&model).unwrap();
            let r2 = mc2.run_rows_sliced_ref(&rows).unwrap();
            assert_eq!(&r2.preds[..n], &want[..], "seed {seed} {mode:?} n {n}: vs sliced");
        }
    }
}

#[test]
fn auto_kernel_selection_is_density_driven_and_never_changes_a_byte() {
    // A hand-built high-sparsity tenant: 128 features, one include per
    // clause — measured include density far under the threshold, so
    // Auto resolves to the compressed kernel.
    let mut rng = XorShift64Star::new(4242);
    let shape = TMShape::synthetic(128, 3, 8);
    let mut sparse = TMModel::empty(shape.clone());
    for class in 0..shape.classes {
        for clause in 0..shape.clauses {
            let lit = (rng.below(2 * 128)) as usize;
            sparse.set_include(class, clause, lit, true);
        }
    }
    let mut core = Core::new(AccelConfig::base());
    core.program_model(&sparse).unwrap();
    assert!(
        core.uses_compressed_kernel(),
        "density {} should auto-select the compressed kernel",
        core.compressed_program().density
    );
    assert!(core.compressed_program().density <= rttm::accel::engine::COMPRESSED_MAX_DENSITY);
    assert_eq!(core.compressed_program().pruned, 0, "auto path must never prune");

    // A dense model stays on the sliced kernel.
    let dense_shape = TMShape::synthetic(12, 3, 8);
    let dense = random_model(&mut rng, &dense_shape, 0.4, &[]);
    let mut dense_core = Core::new(AccelConfig::base());
    dense_core.program_model(&dense).unwrap();
    assert!(
        !dense_core.uses_compressed_kernel(),
        "density {} should stay on the sliced kernel",
        dense_core.compressed_program().density
    );

    // The Auto engine paths (bulk + margins) over the sparse tenant are
    // byte-identical to the SoA reference — preds, margins, simulated
    // accounting — while actually riding the compressed kernel.
    let n = rttm::accel::engine::SLICED_MIN_ROWS + 37;
    let rows = random_rows_n(&mut rng, shape.features, n);
    let mut a = Core::new(AccelConfig::base());
    a.program_model(&sparse).unwrap();
    let (p_soa, m_soa, s_soa) =
        rttm::accel::engine::classify_rows_margins_core_soa(&mut a, &rows).unwrap();
    let mut b = Core::new(AccelConfig::base());
    b.program_model(&sparse).unwrap();
    let (p_auto, m_auto, s_auto) =
        rttm::accel::engine::classify_rows_margins_core(&mut b, &rows).unwrap();
    assert!(b.uses_compressed_kernel());
    assert_eq!(p_auto, p_soa, "auto preds");
    assert_eq!(m_auto, m_soa, "auto margins");
    assert_eq!(s_auto.simulated_cycles, s_soa.simulated_cycles);
    assert_eq!(s_auto.batches, s_soa.batches);

    // Multicore Auto agrees as well.
    let mut mc = MultiCore::five_core().with_parallel(ParallelMode::Threads);
    mc.program_model(&sparse).unwrap();
    let (p_mc, s_mc) = rttm::accel::engine::classify_rows_multicore(&mut mc, &rows).unwrap();
    assert_eq!(p_mc, p_soa, "multicore auto preds");
    assert_eq!(s_mc.batches, s_soa.batches);
}

#[test]
fn reprogramming_soa_core_is_idempotent_with_tautology_killers() {
    // Program A (with an empty class), program B, program A again: the
    // SoA buffers are reused in place and must leave no residue.
    let mut rng = XorShift64Star::new(77);
    let shape = TMShape::synthetic(10, 3, 6);
    let model_a = random_model(&mut rng, &shape, 0.2, &[1]);
    let model_b = random_model(&mut rng, &shape, 0.25, &[]);
    let rows = random_rows(&mut rng, shape.features);
    let packed = isa::pack_features(&rows);

    let mut core = Core::new(AccelConfig::base());
    core.program_model(&model_a).unwrap();
    let first = core.run_batch(&packed).unwrap();
    core.program_model(&model_b).unwrap();
    core.run_batch(&packed).unwrap();
    core.program_model(&model_a).unwrap();
    let again = core.run_batch(&packed).unwrap();
    assert_eq!(first, again);
}
