//! Regression coverage for the replica-pool serving front-end
//! (coordinator::server): the hardened request path, byte-identical
//! pool predictions, and the version fence under concurrent
//! program+infer load.  Setup lives in the shared pool harness.

#[path = "common/pool_harness.rs"]
mod pool_harness;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use pool_harness::{spawn_harness, trained, Traffic};
use rttm::accel::core::CoreError;
use rttm::coordinator::server::ServeError;
use rttm::coordinator::{EngineSpec, InferenceService};

#[test]
fn pool_survives_malformed_requests_and_keeps_serving() {
    let (model, data) = trained(3);
    let pool = spawn_harness(EngineSpec::base(), 4);
    let h = pool.handle.clone();
    h.program(model).unwrap();

    let good = h.infer(data.xs.clone()).unwrap();
    assert_eq!(good.len(), data.len());

    // Empty request.
    assert!(matches!(
        h.infer(Vec::new()),
        Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
    ));
    // Ragged widths.
    let mut ragged = data.xs[..8].to_vec();
    ragged[3] = vec![0u8; 5];
    assert!(matches!(
        h.infer(ragged),
        Err(ServeError::Core(CoreError::BadBatch { .. }))
    ));
    // 33-row requests are legal on the bulk path (chunked), and a
    // malformed request must not have poisoned any replica: hit every
    // replica a few times and check the answers are still right.
    for _ in 0..8 {
        assert_eq!(h.infer(data.xs[..33].to_vec()).unwrap(), good[..33]);
    }
    let stats = h.pool_stats();
    assert_eq!(stats.total.errors, 2);
    assert!(stats.replicas.iter().all(|r| r.alive));
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 0);
    pool.shutdown();
}

#[test]
fn pool_predictions_match_single_service_exactly() {
    let (model, data) = trained(11);
    let mut single = InferenceService::new(EngineSpec::base().build());
    single.reprogram(&model).unwrap();
    let want = single.infer_all(&data.xs).unwrap();

    let pool = spawn_harness(EngineSpec::base(), 4);
    pool.handle.program(model.clone()).unwrap();
    // Concurrent clients: every reply must be byte-identical to the
    // single-service answer no matter which replica served it.
    let mut clients = Vec::new();
    for _ in 0..8 {
        let h = pool.handle.clone();
        let xs = data.xs.clone();
        let want = want.clone();
        clients.push(std::thread::spawn(move || {
            for _ in 0..4 {
                assert_eq!(h.infer(xs.clone()).unwrap(), want);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    pool.shutdown();

    // Same through the multi-core spec.
    let mut single_mc = InferenceService::new(EngineSpec::five_core().build());
    single_mc.reprogram(&model).unwrap();
    assert_eq!(single_mc.infer_all(&data.xs).unwrap(), want);
    let pool = spawn_harness(EngineSpec::five_core(), 2);
    pool.handle.program(model).unwrap();
    assert_eq!(pool.handle.infer(data.xs.clone()).unwrap(), want);
    pool.shutdown();
}

#[test]
fn model_version_is_monotone_and_uniform_under_load() {
    let (model_a, data) = trained(21);
    let (model_b, _) = trained(22);
    let pool = spawn_harness(EngineSpec::base(), 4);
    let h = pool.handle.clone();
    h.program(model_a.clone()).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    // Inference load on all replicas while the programmer runs.
    let mut load = Vec::new();
    for _ in 0..4 {
        let h = h.clone();
        let xs = data.xs.clone();
        let stop = Arc::clone(&stop);
        load.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                // Either version may answer mid-swap; the request must
                // always succeed and be well-formed.
                let preds = h.infer(xs[..64].to_vec()).unwrap();
                assert_eq!(preds.len(), 64);
            }
        }));
    }
    // Monotone + uniform: after every program() returns, all replicas
    // report exactly the broadcast version.
    let mut last_version = h.pool_stats().version;
    for round in 0..6 {
        let m = if round % 2 == 0 { model_b.clone() } else { model_a.clone() };
        h.program(m).unwrap();
        let stats = h.pool_stats();
        assert!(stats.version > last_version, "version must be monotone");
        last_version = stats.version;
        for r in &stats.replicas {
            assert_eq!(
                r.model_version, stats.version,
                "fence must leave replicas uniform"
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    for t in load {
        t.join().unwrap();
    }
    assert_eq!(h.pool_stats().version, 7); // initial program + 6 rounds
    pool.shutdown();
}

#[test]
fn injected_panic_respawns_and_answers_stay_correct() {
    let (model, data) = trained(31);
    let pool = spawn_harness(EngineSpec::base(), 2);
    let h = pool.handle.clone();
    h.program(model).unwrap();
    let want = h.infer(data.xs.clone()).unwrap();

    // Crash each replica at least once (two injections on a 2-replica
    // pool may land on the same worker; just require >=1 respawn and
    // continued correct service).
    for _ in 0..4 {
        assert!(matches!(
            h.inject_panic(),
            Err(ServeError::WorkerPanicked { .. })
        ));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
    }
    let stats = h.pool_stats();
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 4);
    assert!(stats.replicas.iter().all(|r| r.alive));
    pool.shutdown();
}

#[test]
fn deadline_requests_type_out_on_a_saturated_pool_and_serving_recovers() {
    use std::time::{Duration, Instant};

    let (model, data) = trained(61);
    // One replica so a single stall saturates the whole pool
    // deterministically.
    let pool = spawn_harness(EngineSpec::base(), 1);
    let h = pool.handle.clone();
    h.program(model).unwrap();
    let want = h.infer(data.xs.clone()).unwrap();

    // Deadline requests on an idle pool behave exactly like infer().
    assert_eq!(
        h.infer_deadline(data.xs.clone(), Duration::from_secs(30)).unwrap(),
        want
    );

    // Stall the lone replica, then pile deadline requests behind it:
    // every one must come back as the typed error well before the
    // stall clears, instead of blocking forever.
    let stall = h.inject_stall(Duration::from_millis(500)).unwrap();
    let t0 = Instant::now();
    for _ in 0..3 {
        assert!(matches!(
            h.infer_deadline(data.xs.clone(), Duration::from_millis(30)),
            Err(ServeError::DeadlineExceeded)
        ));
    }
    assert!(
        t0.elapsed() < Duration::from_millis(400),
        "deadline requests must not wait out the stall"
    );

    // The stall ends, the expired jobs are shed unexecuted, and the
    // pool serves correctly again — no respawns, no dead replicas.
    stall.recv().unwrap().unwrap();
    assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
    let stats = h.pool_stats();
    assert!(stats.replicas.iter().all(|r| r.alive));
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 0);
    pool.shutdown();
}

#[test]
fn canary_isolation_holds_under_concurrent_traffic() {
    let (model_a, data) = trained(41);
    let (model_b, _) = trained(42);
    let pool = spawn_harness(EngineSpec::base(), 3);
    let h = pool.handle.clone();
    h.program(model_a).unwrap();
    let want = h.infer(data.xs.clone()).unwrap();

    // Continuous live traffic through the whole canary lifecycle.
    let traffic = Traffic::start(h.clone(), data.xs[..32].to_vec());
    let replica = h.program_canary(model_b).unwrap();
    assert_eq!(h.pool_stats().canary, Some(replica));
    // Pool answers stay byte-identical to the baseline while the
    // canary is up — live traffic never routes to the candidate.
    for _ in 0..8 {
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
    }
    assert!(h.dismiss_canary().unwrap());
    for _ in 0..4 {
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
    }
    traffic.stop_assert_clean();
    pool.shutdown();
}
