//! Regression coverage for the replica-pool serving front-end
//! (coordinator::server): the hardened request path, byte-identical
//! pool predictions, and the version fence under concurrent
//! program+infer load.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rttm::accel::core::CoreError;
use rttm::coordinator::server::{spawn_pool, ServeError};
use rttm::coordinator::{EngineSpec, InferenceService};
use rttm::datasets::synth::{Dataset, SynthSpec};
use rttm::{TMModel, TMShape};

fn trained(seed: u64) -> (TMModel, Dataset) {
    let shape = TMShape::synthetic(16, 4, 8);
    let data = SynthSpec::new(16, 4, 192).noise(0.05).seed(seed).generate();
    let model = rttm::trainer::train_model(&shape, &data, 4, seed + 1);
    (model, data)
}

#[test]
fn pool_survives_malformed_requests_and_keeps_serving() {
    let (model, data) = trained(3);
    let (h, mut join) = spawn_pool(EngineSpec::base(), 4);
    h.program(model).unwrap();

    let good = h.infer(data.xs.clone()).unwrap();
    assert_eq!(good.len(), data.len());

    // Empty request.
    assert!(matches!(
        h.infer(Vec::new()),
        Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
    ));
    // Ragged widths.
    let mut ragged = data.xs[..8].to_vec();
    ragged[3] = vec![0u8; 5];
    assert!(matches!(
        h.infer(ragged),
        Err(ServeError::Core(CoreError::BadBatch { .. }))
    ));
    // 33-row requests are legal on the bulk path (chunked), and a
    // malformed request must not have poisoned any replica: hit every
    // replica a few times and check the answers are still right.
    for _ in 0..8 {
        assert_eq!(h.infer(data.xs[..33].to_vec()).unwrap(), good[..33]);
    }
    let stats = h.pool_stats();
    assert_eq!(stats.total.errors, 2);
    assert!(stats.replicas.iter().all(|r| r.alive));
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 0);
    h.shutdown();
    join.join();
}

#[test]
fn pool_predictions_match_single_service_exactly() {
    let (model, data) = trained(11);
    let mut single = InferenceService::new(EngineSpec::base().build());
    single.reprogram(&model).unwrap();
    let want = single.infer_all(&data.xs).unwrap();

    let (h, mut join) = spawn_pool(EngineSpec::base(), 4);
    h.program(model.clone()).unwrap();
    // Concurrent clients: every reply must be byte-identical to the
    // single-service answer no matter which replica served it.
    let mut clients = Vec::new();
    for _ in 0..8 {
        let h = h.clone();
        let xs = data.xs.clone();
        let want = want.clone();
        clients.push(std::thread::spawn(move || {
            for _ in 0..4 {
                assert_eq!(h.infer(xs.clone()).unwrap(), want);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    h.shutdown();
    join.join();

    // Same through the multi-core spec.
    let mut single_mc = InferenceService::new(EngineSpec::five_core().build());
    single_mc.reprogram(&model).unwrap();
    assert_eq!(single_mc.infer_all(&data.xs).unwrap(), want);
    let (h, mut join) = spawn_pool(EngineSpec::five_core(), 2);
    h.program(model).unwrap();
    assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
    h.shutdown();
    join.join();
}

#[test]
fn model_version_is_monotone_and_uniform_under_load() {
    let (model_a, data) = trained(21);
    let (model_b, _) = trained(22);
    let (h, mut join) = spawn_pool(EngineSpec::base(), 4);
    h.program(model_a.clone()).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    // Inference load on all replicas while the programmer runs.
    let mut load = Vec::new();
    for _ in 0..4 {
        let h = h.clone();
        let xs = data.xs.clone();
        let stop = Arc::clone(&stop);
        load.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                // Either version may answer mid-swap; the request must
                // always succeed and be well-formed.
                let preds = h.infer(xs[..64].to_vec()).unwrap();
                assert_eq!(preds.len(), 64);
            }
        }));
    }
    // Monotone + uniform: after every program() returns, all replicas
    // report exactly the broadcast version.
    let mut last_version = h.pool_stats().version;
    for round in 0..6 {
        let m = if round % 2 == 0 { model_b.clone() } else { model_a.clone() };
        h.program(m).unwrap();
        let stats = h.pool_stats();
        assert!(stats.version > last_version, "version must be monotone");
        last_version = stats.version;
        for r in &stats.replicas {
            assert_eq!(
                r.model_version, stats.version,
                "fence must leave replicas uniform"
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    for t in load {
        t.join().unwrap();
    }
    assert_eq!(h.pool_stats().version, 7); // initial program + 6 rounds
    h.shutdown();
    join.join();
}

#[test]
fn injected_panic_respawns_and_answers_stay_correct() {
    let (model, data) = trained(31);
    let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
    h.program(model).unwrap();
    let want = h.infer(data.xs.clone()).unwrap();

    // Crash each replica at least once (two injections on a 2-replica
    // pool may land on the same worker; just require >=1 respawn and
    // continued correct service).
    for _ in 0..4 {
        assert!(matches!(
            h.inject_panic(),
            Err(ServeError::WorkerPanicked { .. })
        ));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
    }
    let stats = h.pool_stats();
    assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 4);
    assert!(stats.replicas.iter().all(|r| r.alive));
    h.shutdown();
    join.join();
}
