//! Integration: PJRT execution of the AOT artifacts vs the dense rust
//! reference.  This is the three-layer contract test: Pallas kernel (L1)
//! inside the JAX graph (L2) loaded and run from rust (L3) must agree
//! with the pure-rust semantics bit-for-bit.
//!
//! Requires the `pjrt` + `xla` features (the `xla` crate is not in the
//! offline vendor set — `pjrt` alone compiles the stub runtime, which
//! cannot execute artifacts) and the AOT artifacts from
//! `make artifacts`.
#![cfg(all(feature = "pjrt", feature = "xla"))]

use rttm::config::Manifest;
use rttm::datasets::synth::SynthSpec;
use rttm::isa;
use rttm::runtime::Runtime;
use rttm::tm::{model::TMModel, reference};
use rttm::TMShape;

fn runtime_and_manifest() -> (Runtime, Manifest) {
    let m = Manifest::load_default().expect("run `make artifacts` first");
    let rt = Runtime::cpu().expect("PJRT CPU client");
    (rt, m)
}

fn random_model(shape: &TMShape, density: f64, seed: u64) -> TMModel {
    let mut rng = rttm::datasets::synth::XorShift64Star::new(seed);
    let mut m = TMModel::empty(shape.clone());
    for class in 0..shape.classes {
        for clause in 0..shape.clauses {
            for lit in 0..shape.literals() {
                if rng.next_f64() < density {
                    m.set_include(class, clause, lit, true);
                }
            }
        }
    }
    m
}

#[test]
fn infer_artifact_matches_dense_reference() {
    let (rt, man) = runtime_and_manifest();
    let exe = rt.load_infer(&man, "quickstart").unwrap();
    let shape = exe.shape.clone();
    let model = random_model(&shape, 0.1, 42);

    let data = SynthSpec::new(shape.features, shape.classes, 32).seed(9).generate();
    let lits = data.literal_rows();
    let packed = isa::pack_literals(&lits);
    let out = exe.infer_packed(&model.to_packed_mask(), &packed).unwrap();

    for (b, lit) in lits.iter().enumerate() {
        let dense = reference::class_sums_dense(&model, lit);
        for (mcls, &s) in dense.iter().enumerate() {
            assert_eq!(out.class_sums[mcls][b], s, "class {mcls} dp {b}");
        }
        assert_eq!(out.preds[b] as usize, reference::argmax(&dense), "dp {b}");
    }
}

#[test]
fn infer_artifact_matches_isa_walk() {
    let (rt, man) = runtime_and_manifest();
    let exe = rt.load_infer(&man, "quickstart").unwrap();
    let shape = exe.shape.clone();
    let model = random_model(&shape, 0.15, 7);
    let instrs = isa::encode(&model);

    let data = SynthSpec::new(shape.features, shape.classes, 32).seed(3).generate();
    // The accelerator walk reads packed FEATURE words (Feature Memory
    // layout); the PJRT artifact takes packed LITERAL words.
    let packed_feats = isa::pack_features(&data.xs);
    let packed_lits = isa::pack_literals(&data.literal_rows());

    let walked = isa::decode_infer_packed(&instrs, &packed_feats, shape.classes).unwrap();
    let out = exe.infer_packed(&model.to_packed_mask(), &packed_lits).unwrap();
    for m in 0..shape.classes {
        for b in 0..32 {
            assert_eq!(out.class_sums[m][b], walked[m][b], "class {m} dp {b}");
        }
    }
}

#[test]
fn train_artifact_learns_quickstart() {
    let (rt, man) = runtime_and_manifest();
    let exe = rt.load_train(&man, "quickstart").unwrap();
    let shape = exe.shape.clone();
    let data = SynthSpec::new(shape.features, shape.classes, 512)
        .noise(0.08)
        .seed(7)
        .generate();
    let ta = exe.fit(&data.xs, &data.ys, 6, 11).unwrap();
    let model = exe.model_from_states(&ta);
    let acc = reference::accuracy(&model, &data.xs, &data.ys);
    assert!(acc > 0.9, "PJRT-trained model acc={acc}");
    // TA states respect bounds.
    assert!(ta.iter().all(|&s| (0..2 * shape.n_states).contains(&s)));
}

#[test]
fn train_artifact_is_deterministic() {
    let (rt, man) = runtime_and_manifest();
    let exe = rt.load_train(&man, "quickstart").unwrap();
    let shape = exe.shape.clone();
    let data = SynthSpec::new(shape.features, shape.classes, shape.train_batch).generate();
    let mut rng = rttm::datasets::synth::XorShift64Star::new(1);
    let ta0 = rttm::runtime::init_ta_states(&shape, &mut rng);
    let mut x_lit = Vec::new();
    for row in &data.xs {
        x_lit.extend(
            reference::literals_from_features(row)
                .iter()
                .map(|&v| v as i32),
        );
    }
    let ys: Vec<i32> = data.ys.iter().map(|&y| y as i32).collect();
    let a = exe.step(&ta0, &x_lit, &ys, [5, 6]).unwrap();
    let b = exe.step(&ta0, &x_lit, &ys, [5, 6]).unwrap();
    assert_eq!(a, b);
    let c = exe.step(&ta0, &x_lit, &ys, [7, 8]).unwrap();
    assert_ne!(a, c);
}

#[test]
fn infer_shape_validation_errors() {
    let (rt, man) = runtime_and_manifest();
    let exe = rt.load_infer(&man, "quickstart").unwrap();
    let bad_mask = vec![0u32; 3];
    let xs = vec![0u32; exe.shape.literals()];
    assert!(exe.infer_packed(&bad_mask, &xs).is_err());
}
