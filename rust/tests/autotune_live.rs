//! Live autotune acceptance: a drift-schedule workload served through a
//! multi-replica pool with the autotuner on, every swap staged through
//! the canary gate.
//!
//! Asserts the acceptance criteria end to end:
//! * windowed accuracy recovers to within 5 points of pre-drift after
//!   the promoted swap;
//! * a concurrent client hammering the pool sees ZERO request errors,
//!   including through the canary program, the promote broadcast and
//!   every fence in between;
//! * `model_version` is strictly monotone across the deployment;
//! * the swapped shape's fitted `ResourceEstimate` is within the
//!   configured budget.
//!
//! Slow (full drift schedules, real retrains): `#[ignore]`d out of
//! tier-1 and run by the CI `cargo test -- --ignored` job.

#[path = "common/pool_harness.rs"]
mod pool_harness;

use pool_harness::{
    assert_versions_strictly_monotone, drifty_workload, mean_accuracy, spawn_harness,
    train_initial, Traffic,
};
use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner};
use rttm::coordinator::{CanaryVerdict, EngineSpec};
use rttm::datasets::workloads::DriftSchedule;
use rttm::model_cost::energy::EnergyModel;
use rttm::model_cost::resources::{estimate, fitted_config, ResourceBudget};
use rttm::tm::serialize::{load_full, save_named, to_bytes_named, to_model};

#[test]
#[ignore = "slow (live drift schedule + retrains); runs in the CI --ignored job"]
fn autotuner_recovers_from_abrupt_drift_through_the_canary_gate() {
    let w = drifty_workload();
    // 12 windows x 256 labeled samples; drift 0.4 arrives at window 4.
    // The tail is long enough for trigger -> canary (2 paired windows)
    // -> promote -> validate -> recovered windows.
    let sched = DriftSchedule::abrupt(12, 256, 4, 0.4).seed(7);
    let model0 = train_initial(&w, &sched, 512);

    // >= 2 replicas behind one queue (acceptance: 3 — one can canary
    // while two keep serving).
    let pool = spawn_harness(EngineSpec::base(), 3);
    let handle = pool.handle.clone();

    let budget = ResourceBudget::unlimited()
        .with_luts(1340)
        .with_brams(14)
        .with_watts(0.4);
    let mut cfg = AutotuneConfig::new(budget.clone());
    cfg.accuracy_floor = 0.85;
    cfg.patience = 2;
    cfg.validation_windows = 1;
    cfg.min_gain = 0.05;
    cfg.epochs = 3;
    cfg.seed = 17;
    cfg.background = true; // the live mode: search on a background thread
    cfg.retrain_corpus = 512; // exactly the two most recent windows
    cfg.canary_fraction = 0.25; // the gate under test
    cfg.canary_min_windows = 2;

    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), cfg);
    tuner.install(model0).unwrap();

    // Concurrent client traffic for the WHOLE deployment, including
    // through the canary program and the promote fence: every request
    // must succeed.
    let clean = sched.training_set(&w, 64);
    let traffic = Traffic::start(handle.clone(), clean.xs[..32].to_vec());

    // Drive the monitored deployment.
    for win in &sched.stream(&w) {
        tuner.observe_window(&win.xs, &win.ys).unwrap();
        // The shadow search runs on its own thread while the client
        // keeps hammering the pool; block the POLICY thread (only) so
        // the test timeline is deterministic.
        if tuner.is_searching() {
            let served_before = traffic.served();
            tuner.finish_pending_search().unwrap();
            // Traffic flowed during the retrain + canary program.
            assert!(
                traffic.served() >= served_before,
                "client stalled during retune"
            );
        }
    }
    traffic.stop_assert_clean();

    let report = &tuner.report;
    assert_eq!(report.windows.len(), sched.windows);

    // --- the story: drift detected, one canary, promoted, one swap ----
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::DriftDetected { .. })));
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::CanaryStarted { .. })));
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::CanaryPromoted { .. })));
    let swapped: Vec<_> = report
        .events
        .iter()
        .filter(|e| matches!(e, AutotuneEvent::Swapped { .. }))
        .collect();
    assert_eq!(swapped.len(), 1, "exactly one retune: {:?}", report.events);
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::Accepted { .. })));
    assert!(!report.events.iter().any(|e| matches!(e, AutotuneEvent::RolledBack { .. })));
    assert!(!report.events.iter().any(|e| matches!(e, AutotuneEvent::CanaryRejected { .. })));

    // The canary record: one evaluation, promoted, every paired window
    // won by the candidate (it was retrained on the drifted corpus).
    assert_eq!(report.canaries.len(), 1);
    let canary = &report.canaries[0];
    assert_eq!(canary.verdict, CanaryVerdict::Promote);
    assert!(canary.windows.len() >= 2);
    assert!(canary.windows.iter().all(|w| w.candidate_wins));
    // No canary is left active after resolution.
    assert!(handle.canary_replica().is_none());

    // --- accuracy recovers to within 5 points of pre-drift ------------
    let pre_drift = mean_accuracy(report, 0..4);
    assert!(pre_drift > 0.85, "pre-drift accuracy {pre_drift}");
    let drifted = mean_accuracy(report, 4..6);
    assert!(drifted < 0.85, "drift must actually degrade accuracy, got {drifted}");
    let recovered = mean_accuracy(report, 10..12);
    assert!(
        recovered >= pre_drift - 0.05,
        "windowed accuracy did not recover: pre {pre_drift:.3} vs post {recovered:.3}"
    );

    // --- model_version strictly monotone -------------------------------
    assert_versions_strictly_monotone(report);
    // install(1) + canary program(2) + promote broadcast(3).
    assert_eq!(handle.pool_stats().version, 3);
    let AutotuneEvent::Swapped { version, luts, brams, watts, .. } = swapped[0] else {
        unreachable!()
    };
    assert_eq!(*version, 3);

    // --- swapped shape's ResourceEstimate is within the budget ---------
    assert!(*luts <= 1340 && *brams <= 14 && *watts <= 0.4);
    let current = tuner.current_model().expect("a model is deployed");
    let cfg = fitted_config(current);
    let est = estimate(&cfg);
    let wattage = EnergyModel::for_config(&cfg).watts;
    assert!(
        budget.admits(&est, wattage),
        "deployed model exceeds budget: {est:?} @ {wattage} W"
    );

    pool.shutdown();
}

#[test]
#[ignore = "slow (live drift schedule + online feedback); runs in the CI --ignored job"]
fn online_feedback_recovers_drift_with_zero_searches() {
    // The cheap recovery path, live: drift arrives, labeled windows are
    // folded into the serving model through `ServiceHandle::feedback`
    // (one TA-state sweep per window, each broadcast behind the version
    // fence), and the detector clears WITHOUT ever launching a
    // budget_search — zero SearchCompleted / Swapped / canary events.
    let w = drifty_workload();
    // 14 windows x 256 labeled samples; drift 0.4 arrives at window 4.
    let sched = DriftSchedule::abrupt(14, 256, 4, 0.4).seed(7);
    let model0 = train_initial(&w, &sched, 512);

    let pool = spawn_harness(EngineSpec::base(), 3);
    let handle = pool.handle.clone();

    let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
    cfg.accuracy_floor = 0.85;
    cfg.patience = 2;
    cfg.online_feedback = true; // the path under test
    cfg.online_patience = 9; // every remaining window before escalating
    cfg.background = false;
    cfg.seed = 17;
    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), cfg);
    tuner.install(model0.clone()).unwrap();

    // Concurrent client traffic across every feedback mini-fence: every
    // request must succeed.
    let clean = sched.training_set(&w, 64);
    let traffic = Traffic::start(handle.clone(), clean.xs[..32].to_vec());

    for win in &sched.stream(&w) {
        tuner.observe_window(&win.xs, &win.ys).unwrap();
        assert!(!tuner.is_searching(), "online path must not launch a search");
    }
    traffic.stop_assert_clean();

    let report = &tuner.report;
    assert_eq!(report.windows.len(), sched.windows);

    // --- the story: drift, feedback windows, recovery — no search ------
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::DriftDetected { .. })));
    let recovered_after = report
        .events
        .iter()
        .find_map(|e| match e {
            AutotuneEvent::OnlineRecovered { fed_windows, .. } => Some(*fed_windows),
            _ => None,
        })
        .unwrap_or_else(|| panic!("online feedback never recovered: {:?}", report.events));
    assert!((1..=9).contains(&recovered_after), "fed {recovered_after} windows");
    assert!(
        !report.events.iter().any(|e| matches!(
            e,
            AutotuneEvent::OnlineEscalated { .. }
                | AutotuneEvent::SearchCompleted { .. }
                | AutotuneEvent::Swapped { .. }
                | AutotuneEvent::CanaryStarted { .. }
        )),
        "zero budget_search events allowed: {:?}",
        report.events
    );

    // --- every feedback window rode the fence: strictly monotone -------
    let fence_versions: Vec<u64> = report
        .events
        .iter()
        .filter_map(|e| match e {
            AutotuneEvent::OnlineFeedback { version, samples, .. } => {
                assert_eq!(*samples, 256);
                Some(*version)
            }
            _ => None,
        })
        .collect();
    assert!(!fence_versions.is_empty());
    assert!(
        fence_versions.windows(2).all(|p| p[1] > p[0]),
        "feedback fence versions not strictly monotone: {fence_versions:?}"
    );
    // install(1) + one broadcast per feedback window, nothing else.
    assert_eq!(handle.pool_stats().version, 1 + fence_versions.len() as u64);
    assert_versions_strictly_monotone(report);
    // The replica-side trainer folded exactly the fed rows.
    assert_eq!(handle.online_rows_fed(), Some(256 * fence_versions.len() as u64));

    // --- accuracy: dip at the drift, recovered on the drifted dist -----
    let pre_drift = mean_accuracy(report, 0..4);
    assert!(pre_drift > 0.85, "pre-drift accuracy {pre_drift}");
    let dipped = mean_accuracy(report, 4..6);
    assert!(dipped < 0.85, "drift must actually degrade accuracy, got {dipped}");
    let holdout = w.drifted_dataset(256, sched.seed, 0.4);
    let preds = handle.infer(holdout.xs.clone()).unwrap();
    let hits = preds.iter().zip(&holdout.ys).filter(|(p, y)| p == y).count();
    let recovered = hits as f64 / holdout.ys.len() as f64;
    assert!(recovered >= 0.80, "fine-tuned model still drifted: {recovered:.3}");

    // --- the online-updated model is durable: byte-identical round-trip
    let deployed = handle
        .registered_models()
        .into_iter()
        .find(|e| e.id == handle.model_route())
        .expect("the serving model is registered")
        .model;
    assert_ne!(deployed.as_ref(), &model0, "feedback never reached the registry");
    let path = std::env::temp_dir().join("rttm_live_online_tuned.rttm");
    save_named(&deployed, "online-tuned", &path).unwrap();
    let saved = std::fs::read(&path).unwrap();
    let (shape, instrs, tag) = load_full(&path).unwrap();
    assert_eq!(tag.unwrap().name, "online-tuned");
    let reloaded = to_model(shape, &instrs).unwrap();
    assert_eq!(
        to_bytes_named(&reloaded, "online-tuned"),
        saved,
        "online-updated model does not round-trip byte-identically"
    );
    std::fs::remove_file(&path).ok();

    pool.shutdown();
}

#[test]
#[ignore = "slow (live drift schedule + retrains); runs in the CI --ignored job"]
fn recurring_drift_retunes_each_phase_change_without_storms() {
    // Recurring drift: the hysteresis must produce bounded, phase-aligned
    // retunes rather than one per noisy window.  Canary gate off: this
    // test pins the DETECTOR's retune cadence, and direct swaps keep
    // the swap-per-trigger mapping 1:1 (the gate's own behavior is
    // pinned by canary_live.rs and the autotune unit tests).
    let w = drifty_workload();
    let sched = DriftSchedule::recurring(12, 192, 3, 0.4).seed(9);
    let model0 = train_initial(&w, &sched, 512);

    let pool = spawn_harness(EngineSpec::base(), 2);
    let handle = pool.handle.clone();
    let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
    cfg.accuracy_floor = 0.85;
    cfg.patience = 2;
    cfg.validation_windows = 1;
    cfg.min_gain = 0.05;
    cfg.background = false; // inline: deterministic retune timing
    cfg.retrain_corpus = 384;
    cfg.epochs = 3;
    cfg.canary_fraction = 0.0; // direct swaps (see above)
    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), cfg);
    tuner.install(model0).unwrap();

    for win in &sched.stream(&w) {
        tuner.observe_window(&win.xs, &win.ys).unwrap();
    }

    let swaps = tuner
        .report
        .events
        .iter()
        .filter(|e| matches!(e, AutotuneEvent::Swapped { .. }))
        .count();
    // 12 windows in 4 phases of 3: at most one retune per phase change
    // (3 changes), at least one retune overall — never a storm.
    assert!(swaps >= 1, "recurring drift never retuned: {:?}", tuner.report.events);
    assert!(swaps <= 3, "retune storm: {swaps} swaps in 12 windows");
    // Versions strictly monotone here too.
    assert_versions_strictly_monotone(&tuner.report);

    pool.shutdown();
}
