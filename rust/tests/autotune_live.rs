//! Live autotune acceptance: a drift-schedule workload served through a
//! multi-replica pool with the autotuner on.
//!
//! Asserts the PR 3 acceptance criteria end to end:
//! * windowed accuracy recovers to within 5 points of pre-drift after
//!   the swap;
//! * a concurrent client hammering the pool sees ZERO request errors,
//!   including during the reprogram fence;
//! * `model_version` is strictly monotone across the deployment;
//! * the swapped shape's fitted `ResourceEstimate` is within the
//!   configured budget.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner};
use rttm::coordinator::server::spawn_pool;
use rttm::coordinator::EngineSpec;
use rttm::datasets::workloads::{DriftSchedule, Workload};
use rttm::model_cost::energy::EnergyModel;
use rttm::model_cost::resources::{estimate, fitted_config, ResourceBudget};
use rttm::TMShape;

fn test_workload() -> Workload {
    Workload {
        name: "drifty",
        shape: TMShape::synthetic(16, 3, 10),
        noise: 0.05,
        informative: 1.0,
        paper_accuracy: None,
        recalibration: "integration test",
    }
}

#[test]
fn autotuner_recovers_from_abrupt_drift_on_a_live_pool() {
    let w = test_workload();
    // 10 windows x 256 labeled samples; drift 0.4 arrives at window 4.
    let sched = DriftSchedule::abrupt(10, 256, 4, 0.4).seed(7);

    // Initial model trained on the clean universe — on fresh draws
    // PAST the monitored stream, so windowed accuracy measures
    // generalization, never memorized training samples.
    let clean = sched.training_set(&w, 512);
    let model0 = rttm::trainer::train_model(&w.shape, &clean, 4, 2);

    // >= 2 replicas behind one queue (acceptance: 3).
    let (handle, mut join) = spawn_pool(EngineSpec::base(), 3);

    let budget = ResourceBudget::unlimited()
        .with_luts(1340)
        .with_brams(14)
        .with_watts(0.4);
    let mut cfg = AutotuneConfig::new(budget.clone());
    cfg.accuracy_floor = 0.85;
    cfg.patience = 2;
    cfg.validation_windows = 1;
    cfg.min_gain = 0.05;
    cfg.epochs = 3;
    cfg.seed = 17;
    cfg.background = true; // the live mode: search on a background thread
    cfg.retrain_corpus = 512; // exactly the two most recent windows

    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), cfg);
    tuner.install(model0).unwrap();

    // Concurrent client traffic for the WHOLE deployment, including
    // through the reprogram fence: every request must succeed.
    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let client = {
        let h = handle.clone();
        let stop = Arc::clone(&stop);
        let served = Arc::clone(&served);
        let failed = Arc::clone(&failed);
        let rows: Vec<Vec<u8>> = clean.xs[..32].to_vec();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match h.infer(rows.clone()) {
                    Ok(preds) => {
                        assert_eq!(preds.len(), 32);
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                std::thread::yield_now();
            }
        })
    };

    // Drive the monitored deployment.
    for win in &sched.stream(&w) {
        tuner.observe_window(&win.xs, &win.ys).unwrap();
        // The shadow search runs on its own thread while the client
        // keeps hammering the pool; block the POLICY thread (only) so
        // the test timeline is deterministic.
        if tuner.is_searching() {
            let served_before = served.load(Ordering::Relaxed);
            tuner.finish_pending_search().unwrap();
            // Traffic flowed during the retrain + swap.
            assert!(
                served.load(Ordering::Relaxed) >= served_before,
                "client stalled during retune"
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    client.join().unwrap();

    // --- no request errors, traffic actually flowed -------------------
    assert_eq!(failed.load(Ordering::Relaxed), 0, "request errors during deployment");
    assert!(served.load(Ordering::Relaxed) > 0);

    let report = &tuner.report;
    assert_eq!(report.windows.len(), sched.windows);

    // --- the story: drift detected, one swap, accepted, no rollback ---
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::DriftDetected { .. })));
    let swapped: Vec<_> = report
        .events
        .iter()
        .filter(|e| matches!(e, AutotuneEvent::Swapped { .. }))
        .collect();
    assert_eq!(swapped.len(), 1, "exactly one retune: {:?}", report.events);
    assert!(report.events.iter().any(|e| matches!(e, AutotuneEvent::Accepted { .. })));
    assert!(!report.events.iter().any(|e| matches!(e, AutotuneEvent::RolledBack { .. })));

    // --- accuracy recovers to within 5 points of pre-drift ------------
    let acc = |i: usize| report.windows[i].accuracy.unwrap();
    let pre_drift = (0..4).map(acc).sum::<f64>() / 4.0;
    assert!(pre_drift > 0.85, "pre-drift accuracy {pre_drift}");
    let drifted = acc(4).min(acc(5));
    assert!(drifted < 0.85, "drift must actually degrade accuracy, got {drifted}");
    let recovered = (8..10).map(acc).sum::<f64>() / 2.0;
    assert!(
        recovered >= pre_drift - 0.05,
        "windowed accuracy did not recover: pre {pre_drift:.3} vs post {recovered:.3}"
    );

    // --- model_version strictly monotone -------------------------------
    for pair in report.windows.windows(2) {
        assert!(
            pair[1].model_version >= pair[0].model_version,
            "version went backwards"
        );
    }
    let mut distinct: Vec<u64> = report.windows.iter().map(|s| s.model_version).collect();
    distinct.dedup();
    for pair in distinct.windows(2) {
        assert!(pair[0] < pair[1], "versions not strictly monotone: {distinct:?}");
    }
    // install(1) + exactly one swap(2).
    assert_eq!(handle.pool_stats().version, 2);
    let AutotuneEvent::Swapped { version, luts, brams, watts, .. } = swapped[0] else {
        unreachable!()
    };
    assert_eq!(*version, 2);

    // --- swapped shape's ResourceEstimate is within the budget ---------
    assert!(*luts <= 1340 && *brams <= 14 && *watts <= 0.4);
    let current = tuner.current_model().expect("a model is deployed");
    let cfg = fitted_config(current);
    let est = estimate(&cfg);
    let wattage = EnergyModel::for_config(&cfg).watts;
    assert!(
        budget.admits(&est, wattage),
        "deployed model exceeds budget: {est:?} @ {wattage} W"
    );

    handle.shutdown();
    join.join();
}

#[test]
fn recurring_drift_retunes_each_phase_change_without_storms() {
    // Recurring drift: the hysteresis must produce bounded, phase-aligned
    // retunes rather than one per noisy window.
    let w = test_workload();
    let sched = DriftSchedule::recurring(12, 192, 3, 0.4).seed(9);
    let clean = sched.training_set(&w, 512);
    let model0 = rttm::trainer::train_model(&w.shape, &clean, 4, 2);

    let (handle, mut join) = spawn_pool(EngineSpec::base(), 2);
    let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
    cfg.accuracy_floor = 0.85;
    cfg.patience = 2;
    cfg.validation_windows = 1;
    cfg.min_gain = 0.05;
    cfg.background = false; // inline: deterministic retune timing
    cfg.retrain_corpus = 384;
    cfg.epochs = 3;
    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), cfg);
    tuner.install(model0).unwrap();

    for win in &sched.stream(&w) {
        tuner.observe_window(&win.xs, &win.ys).unwrap();
    }

    let swaps = tuner
        .report
        .events
        .iter()
        .filter(|e| matches!(e, AutotuneEvent::Swapped { .. }))
        .count();
    // 12 windows in 4 phases of 3: at most one retune per phase change
    // (3 changes), at least one retune overall — never a storm.
    assert!(swaps >= 1, "recurring drift never retuned: {:?}", tuner.report.events);
    assert!(swaps <= 3, "retune storm: {swaps} swaps in 12 windows");
    // Versions strictly monotone here too.
    let mut versions: Vec<u64> = tuner.report.windows.iter().map(|s| s.model_version).collect();
    versions.dedup();
    for pair in versions.windows(2) {
        assert!(pair[0] < pair[1]);
    }

    handle.shutdown();
    join.join();
}
