//! Golden-fixture pin of the `.rttm` wire formats.
//!
//! `tests/fixtures/golden_v1.rttm` is a committed byte-for-byte
//! artifact of `tm::serialize::to_bytes` for a small hand-built model.
//! Any accidental change to the v1 layout — field order, widths,
//! endianness, the instruction encoding walked into the stream, or the
//! CRC trailer — fails this test loudly.  (The CRC known-answer test in
//! `tm::serialize` pins the checksum algorithm; this pins the whole
//! file.)  A DELIBERATE format change must bump the format version and
//! add a new fixture, never rewrite this one.
//!
//! `tests/fixtures/golden_v2.rttm` pins the version-2 named-model
//! extension the same way: the v1 fields plus a deployment name
//! ("tenant-a") and the payload's FNV-1a-64 content hash, for the same
//! model.  v1 files must keep loading forever.

use rttm::isa;
use rttm::tm::model::TMModel;
use rttm::tm::serialize::{
    content_hash, crc32, from_bytes, from_bytes_full, to_bytes, to_bytes_named, FileError,
};
use rttm::TMShape;

const GOLDEN: &[u8] = include_bytes!("fixtures/golden_v1.rttm");
const GOLDEN_V2: &[u8] = include_bytes!("fixtures/golden_v2.rttm");

// Field boundaries of the golden file (62 bytes total):
// magic 0..4 | version 4..6 | name_len 6..8 | name 8..22 |
// features 22..26 | classes 26..30 | clauses 30..34 | T 34..38 |
// s_milli 38..42 | count 42..46 | instrs 46..58 | crc 58..62.
const COUNT_OFF: usize = 42;
const BODY_END: usize = 58;

/// The fixture's model: shape synthetic(4, 3, 4) — name
/// "synth_4f_3m_4c", T = 1, s = 3.0 — with four includes and one empty
/// class (so the stream also pins the tautology-killer encoding).
fn golden_model() -> TMModel {
    let mut m = TMModel::empty(TMShape::synthetic(4, 3, 4));
    m.set_include(0, 0, 0, true);
    m.set_include(0, 0, 5, true);
    m.set_include(0, 1, 2, true);
    m.set_include(1, 3, 7, true);
    // class 2 stays empty.
    m
}

#[test]
fn to_bytes_reproduces_the_golden_fixture() {
    let bytes = to_bytes(&golden_model());
    assert_eq!(
        bytes,
        GOLDEN.to_vec(),
        "the v1 .rttm layout changed — if deliberate, bump the format \
         version and add golden_v2 instead of rewriting this fixture"
    );
}

#[test]
fn golden_fixture_parses_back_to_the_model() {
    let (shape, instrs) = from_bytes(GOLDEN).expect("golden fixture must stay loadable");
    assert_eq!(shape.name, "synth_4f_3m_4c");
    assert_eq!(shape.features, 4);
    assert_eq!(shape.classes, 3);
    assert_eq!(shape.clauses, 4);
    assert_eq!(shape.t, 1);
    assert!((shape.s - 3.0).abs() < 1e-9);
    assert_eq!(instrs, isa::encode(&golden_model()));
}

#[test]
fn golden_instruction_words_are_pinned() {
    // The exact 16-bit words (P/CC/E/OFFSET/L packing of Fig 3.4),
    // including the empty class 2's tautology-killer pair.
    let (_, instrs) = from_bytes(GOLDEN).unwrap();
    let words: Vec<u16> = instrs.iter().map(|i| i.0).collect();
    assert_eq!(words, vec![0x0000, 0x000B, 0xC004, 0xA00F, 0x4000, 0x4003]);
}

/// What a mutated file must fail with — the EXACT variant, not just
/// "some error".
enum Expect {
    Truncated,
    TrailingBytes(usize),
    BadCrc,
    BadMagic,
    BadVersion(u16),
}

fn assert_expected(name: &str, bytes: &[u8], expect: &Expect) {
    let got = from_bytes(bytes);
    match (expect, got) {
        (Expect::Truncated, Err(FileError::Truncated { .. })) => {}
        (Expect::TrailingBytes(extra), Err(FileError::TrailingBytes { extra: got })) => {
            assert_eq!(got, *extra, "case {name:?}: wrong trailing-byte count")
        }
        (Expect::BadCrc, Err(FileError::BadCrc)) => {}
        (Expect::BadMagic, Err(FileError::BadMagic)) => {}
        (Expect::BadVersion(v), Err(FileError::BadVersion(got))) => {
            assert_eq!(got, *v, "case {name:?}: wrong version surfaced")
        }
        (_, other) => panic!("case {name:?}: got {other:?}"),
    }
}

/// Truncate the golden body at `cut` and re-seal the CRC, so the only
/// remaining defect is the missing payload (what an adversary — or a
/// torn write — controlling the file produces).
fn truncated_resealed(cut: usize) -> Vec<u8> {
    let mut bytes = GOLDEN[..cut].to_vec();
    let crc = crc32(&bytes).to_le_bytes();
    bytes.extend_from_slice(&crc);
    bytes
}

fn resealed(mut bytes: Vec<u8>) -> Vec<u8> {
    let body = bytes.len() - 4;
    let crc = crc32(&bytes[..body]).to_le_bytes();
    bytes[body..].copy_from_slice(&crc);
    bytes
}

#[test]
fn mutated_golden_corpus_fails_with_exact_variants() {
    let mut corpus: Vec<(String, Vec<u8>, Expect)> = Vec::new();

    // 1. CRC-resealed truncation at EVERY field boundary, and inside
    //    every multi-byte field: always Truncated, never BadMagic, a
    //    panic, or an allocation sized by the declared count.
    for cut in [
        0, 4, 5, 6, 7, 8, 15, 22, 24, 26, 28, 30, 32, 34, 36, 38, 40, 42, 44, 46, 47, 48, 52, 57,
    ] {
        corpus.push((
            format!("resealed truncation at byte {cut}"),
            truncated_resealed(cut),
            Expect::Truncated,
        ));
    }

    // 2. Truncation WITHOUT resealing: the CRC check fires first (the
    //    trailer no longer matches the shortened body).
    for cut in [22, 46] {
        corpus.push((
            format!("raw truncation at byte {cut}"),
            GOLDEN[..cut].to_vec(),
            Expect::BadCrc,
        ));
    }

    // 3. Count off-by-one, both directions, CRC-valid.
    let mut over = GOLDEN.to_vec();
    over[COUNT_OFF..COUNT_OFF + 4].copy_from_slice(&7u32.to_le_bytes());
    corpus.push(("count overstated by one".into(), resealed(over), Expect::Truncated));
    let mut under = GOLDEN.to_vec();
    under[COUNT_OFF..COUNT_OFF + 4].copy_from_slice(&5u32.to_le_bytes());
    corpus.push((
        "count understated by one".into(),
        resealed(under),
        Expect::TrailingBytes(2),
    ));

    // 4. Adversarial count = u32::MAX, CRC-valid: must fail Truncated
    //    BEFORE any allocation sized by the claim (~8 GB otherwise).
    let mut huge = GOLDEN.to_vec();
    huge[COUNT_OFF..COUNT_OFF + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    corpus.push(("count u32::MAX".into(), resealed(huge), Expect::Truncated));

    // 5. Flipped CRC bits.
    let mut crc_flip = GOLDEN.to_vec();
    crc_flip[BODY_END] ^= 0x01;
    corpus.push(("flipped CRC low bit".into(), crc_flip, Expect::BadCrc));
    let mut crc_flip_hi = GOLDEN.to_vec();
    crc_flip_hi[BODY_END + 3] ^= 0x80;
    corpus.push(("flipped CRC high bit".into(), crc_flip_hi, Expect::BadCrc));

    // 6. Wrong magic / unsupported version, CRC-valid.
    let mut magic = GOLDEN.to_vec();
    magic[0] = b'X';
    corpus.push(("wrong magic".into(), resealed(magic), Expect::BadMagic));
    // Version 2 became the named-model extension (golden_v2 below), so
    // the unsupported-version probe moved to 3 — exactly the deliberate
    // bump-and-add-a-fixture path this file's header prescribes.
    let mut version = GOLDEN.to_vec();
    version[4..6].copy_from_slice(&3u16.to_le_bytes());
    corpus.push(("version 3".into(), resealed(version), Expect::BadVersion(3)));

    // 7. Body-flip anywhere without resealing: BadCrc.
    let mut flip = GOLDEN.to_vec();
    flip[30] ^= 0x40;
    corpus.push(("unsealed body flip".into(), flip, Expect::BadCrc));

    for (name, bytes, expect) in &corpus {
        assert_expected(name, bytes, expect);
    }
}

#[test]
fn golden_fixture_framing_is_pinned() {
    // Header anatomy, byte-for-byte.
    assert_eq!(GOLDEN.len(), 62);
    assert_eq!(&GOLDEN[..4], b"RTTM");
    assert_eq!(&GOLDEN[4..6], &1u16.to_le_bytes()); // version
    assert_eq!(&GOLDEN[6..8], &14u16.to_le_bytes()); // name length
    assert_eq!(&GOLDEN[8..22], b"synth_4f_3m_4c");
    // CRC trailer over everything above it.
    let stored = u32::from_le_bytes(GOLDEN[58..].try_into().unwrap());
    assert_eq!(stored, rttm::tm::serialize::crc32(&GOLDEN[..58]));
    assert_eq!(stored, 0xD57C_4F69);
}

// ---------------------------------------------------------------------
// v2 named-model extension pins.
//
// Field boundaries of golden_v2.rttm (80 bytes total): the v1 header
// through s_milli unchanged (0..42), then
// deploy_len 42..44 | deploy 44..52 ("tenant-a") | hash 52..60 |
// count 60..64 | instrs 64..76 | crc 76..80.
const V2_HASH_OFF: usize = 52;
const V2_COUNT_OFF: usize = 60;

#[test]
fn to_bytes_named_reproduces_the_golden_v2_fixture() {
    let bytes = to_bytes_named(&golden_model(), "tenant-a");
    assert_eq!(
        bytes,
        GOLDEN_V2.to_vec(),
        "the v2 .rttm layout changed — if deliberate, bump the format \
         version and add golden_v3 instead of rewriting this fixture"
    );
}

#[test]
fn golden_v2_parses_back_with_its_tag() {
    let (shape, instrs, tag) = from_bytes_full(GOLDEN_V2).expect("golden_v2 must stay loadable");
    assert_eq!(shape.name, "synth_4f_3m_4c");
    assert_eq!(instrs, isa::encode(&golden_model()));
    let tag = tag.expect("v2 fixture must carry a tag");
    assert_eq!(tag.name, "tenant-a");
    assert_eq!(tag.content_hash, content_hash(&golden_model()));
    // The tag hash is, by construction, the FNV-1a-64 of the ENTIRE v1
    // fixture file — the two goldens pin each other.
    assert_eq!(tag.content_hash, rttm::tm::serialize::fnv1a64(GOLDEN));
}

#[test]
fn golden_v1_still_loads_and_carries_no_tag() {
    // Backward compat is the contract: v1 files keep loading unchanged
    // after the v2 extension, through both entry points.
    let (shape, instrs, tag) = from_bytes_full(GOLDEN).unwrap();
    assert!(tag.is_none());
    assert_eq!(shape.classes, 3);
    assert_eq!(instrs.len(), 6);
}

#[test]
fn golden_v2_framing_is_pinned() {
    assert_eq!(GOLDEN_V2.len(), 80);
    assert_eq!(&GOLDEN_V2[..4], b"RTTM");
    assert_eq!(&GOLDEN_V2[4..6], &2u16.to_le_bytes()); // version
    // v1 header fields (name through s_milli) are byte-identical.
    assert_eq!(&GOLDEN_V2[6..42], &GOLDEN[6..42]);
    assert_eq!(&GOLDEN_V2[42..44], &8u16.to_le_bytes()); // deploy length
    assert_eq!(&GOLDEN_V2[44..52], b"tenant-a");
    let hash = u64::from_le_bytes(GOLDEN_V2[V2_HASH_OFF..V2_COUNT_OFF].try_into().unwrap());
    assert_eq!(hash, 0x0172_D7DB_9454_5634);
    // count + instrs are byte-identical to the v1 fixture's.
    assert_eq!(&GOLDEN_V2[V2_COUNT_OFF..76], &GOLDEN[COUNT_OFF..BODY_END]);
    let stored = u32::from_le_bytes(GOLDEN_V2[76..].try_into().unwrap());
    assert_eq!(stored, crc32(&GOLDEN_V2[..76]));
    assert_eq!(stored, 0xA74D_CB0A);
}

// ---------------------------------------------------------------------
// Content-digest pins: the integrity layer (scrub-and-repair, registry
// identity, remote verify) keys on these exact FNV-1a-64 values.  A
// drift here silently breaks corruption detection everywhere at once,
// so the constants are pinned byte-for-byte against the committed
// fixtures — never recompute-and-paste on failure; find out what moved.

#[test]
fn golden_fixture_content_digests_are_pinned() {
    use rttm::tm::serialize::fnv1a64;
    // The v1 file's digest IS the model's content hash (content_hash is
    // defined as FNV-1a-64 over the canonical v1 serialization).
    assert_eq!(fnv1a64(GOLDEN), 0x0172_D7DB_9454_5634);
    assert_eq!(content_hash(&golden_model()), 0x0172_D7DB_9454_5634);
    // The v2 file hashes differently (it embeds the name + hash fields)
    // while its TAG still pins the same v1 content hash — a v2 rewrite
    // that preserved the tag but moved bytes would be caught here.
    assert_eq!(fnv1a64(GOLDEN_V2), 0x4D36_B058_9849_5B14);
    let (_, _, tag) = from_bytes_full(GOLDEN_V2).unwrap();
    assert_eq!(tag.unwrap().content_hash, 0x0172_D7DB_9454_5634);
}

/// Flipping ANY single TA include bit — every class, clause and literal
/// of the golden model, set and unset alike — must change the content
/// hash.  This is the property the scrub layer's corruption detection
/// rests on: no single-event upset is invisible to the digest.
#[test]
fn every_single_flipped_include_bit_changes_the_content_hash() {
    let base = golden_model();
    let h0 = content_hash(&base);
    let lits = 2 * base.shape.features;
    for class in 0..base.shape.classes {
        for clause in 0..base.shape.clauses {
            for lit in 0..lits {
                let mut m = golden_model();
                m.set_include(class, clause, lit, !m.include(class, clause, lit));
                assert_ne!(
                    content_hash(&m),
                    h0,
                    "flipped include ({class},{clause},{lit}) left the content hash unchanged"
                );
            }
        }
    }
}

#[test]
fn golden_v2_mutation_corpus() {
    // Count understated: TrailingBytes semantics are preserved in v2.
    let mut under = GOLDEN_V2.to_vec();
    under[V2_COUNT_OFF..V2_COUNT_OFF + 4].copy_from_slice(&5u32.to_le_bytes());
    assert_expected(
        "v2 count understated by one",
        &resealed(under),
        &Expect::TrailingBytes(2),
    );

    // Tampered content hash, CRC resealed: the splice is caught by
    // recomputing the hash from the decoded payload.
    let mut spliced = GOLDEN_V2.to_vec();
    spliced[V2_HASH_OFF] ^= 0xFF;
    assert!(matches!(
        from_bytes_full(&resealed(spliced)),
        Err(FileError::TagMismatch { .. })
    ));

    // Resealed truncation inside the v2 extension fields: Truncated.
    for cut in [43, 48, 56] {
        let mut bytes = GOLDEN_V2[..cut].to_vec();
        let crc = crc32(&bytes).to_le_bytes();
        bytes.extend_from_slice(&crc);
        assert_expected(
            &format!("v2 resealed truncation at byte {cut}"),
            &bytes,
            &Expect::Truncated,
        );
    }
}
