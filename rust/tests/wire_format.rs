//! Golden-fixture pin of the `.rttm` v1 wire format.
//!
//! `tests/fixtures/golden_v1.rttm` is a committed byte-for-byte
//! artifact of `tm::serialize::to_bytes` for a small hand-built model.
//! Any accidental change to the v1 layout — field order, widths,
//! endianness, the instruction encoding walked into the stream, or the
//! CRC trailer — fails this test loudly.  (The CRC known-answer test in
//! `tm::serialize` pins the checksum algorithm; this pins the whole
//! file.)  A DELIBERATE format change must bump the format version and
//! add a new fixture, never rewrite this one.

use rttm::isa;
use rttm::tm::model::TMModel;
use rttm::tm::serialize::{from_bytes, to_bytes};
use rttm::TMShape;

const GOLDEN: &[u8] = include_bytes!("fixtures/golden_v1.rttm");

/// The fixture's model: shape synthetic(4, 3, 4) — name
/// "synth_4f_3m_4c", T = 1, s = 3.0 — with four includes and one empty
/// class (so the stream also pins the tautology-killer encoding).
fn golden_model() -> TMModel {
    let mut m = TMModel::empty(TMShape::synthetic(4, 3, 4));
    m.set_include(0, 0, 0, true);
    m.set_include(0, 0, 5, true);
    m.set_include(0, 1, 2, true);
    m.set_include(1, 3, 7, true);
    // class 2 stays empty.
    m
}

#[test]
fn to_bytes_reproduces_the_golden_fixture() {
    let bytes = to_bytes(&golden_model());
    assert_eq!(
        bytes,
        GOLDEN.to_vec(),
        "the v1 .rttm layout changed — if deliberate, bump the format \
         version and add golden_v2 instead of rewriting this fixture"
    );
}

#[test]
fn golden_fixture_parses_back_to_the_model() {
    let (shape, instrs) = from_bytes(GOLDEN).expect("golden fixture must stay loadable");
    assert_eq!(shape.name, "synth_4f_3m_4c");
    assert_eq!(shape.features, 4);
    assert_eq!(shape.classes, 3);
    assert_eq!(shape.clauses, 4);
    assert_eq!(shape.t, 1);
    assert!((shape.s - 3.0).abs() < 1e-9);
    assert_eq!(instrs, isa::encode(&golden_model()));
}

#[test]
fn golden_instruction_words_are_pinned() {
    // The exact 16-bit words (P/CC/E/OFFSET/L packing of Fig 3.4),
    // including the empty class 2's tautology-killer pair.
    let (_, instrs) = from_bytes(GOLDEN).unwrap();
    let words: Vec<u16> = instrs.iter().map(|i| i.0).collect();
    assert_eq!(words, vec![0x0000, 0x000B, 0xC004, 0xA00F, 0x4000, 0x4003]);
}

#[test]
fn golden_fixture_framing_is_pinned() {
    // Header anatomy, byte-for-byte.
    assert_eq!(GOLDEN.len(), 62);
    assert_eq!(&GOLDEN[..4], b"RTTM");
    assert_eq!(&GOLDEN[4..6], &1u16.to_le_bytes()); // version
    assert_eq!(&GOLDEN[6..8], &14u16.to_le_bytes()); // name length
    assert_eq!(&GOLDEN[8..22], b"synth_4f_3m_4c");
    // CRC trailer over everything above it.
    let stored = u32::from_le_bytes(GOLDEN[58..].try_into().unwrap());
    assert_eq!(stored, rttm::tm::serialize::crc32(&GOLDEN[..58]));
    assert_eq!(stored, 0xD57C_4F69);
}
