//! Fig 1: LUTs vs throughput of accelerator automation flows, all for
//! MNIST, with eFPGA max-LUT verticals.
//!
//! Literature points are the published values the paper plots; our
//! points are measured on the simulator with a trained MNIST model.
//!
//! `cargo bench --bench fig1_lut_throughput`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::AccelConfig;
use rttm::accel::multicore::MultiCore;
use rttm::accel::Core;
use rttm::baselines::Matador;
use rttm::isa;
use rttm::model_cost::{estimate, estimate_multicore};

fn main() {
    println!("=== Fig 1: LUTs vs inference throughput (MNIST) ===\n");

    // Published literature points (as plotted by the paper).
    let literature = [
        ("hls4ml [17]", 260_000u32, 5.0e6f64),
        ("PolyLUT [2]", 70_000, 2.0e7),
        ("FINN [5]", 25_000, 1.0e6),
        ("LogicNets [23]", 15_000, 3.0e8),
    ];

    // Our MNIST model on the three configurations + MATADOR fit.
    let (w, model, data) = common::trained_model("mnist", 384, 2);
    let instrs = isa::encode(&model);
    println!("trained MNIST model: {} includes -> {} instructions", model.include_count(), instrs.len());

    let packed = isa::pack_features(&data.xs[..32].to_vec());

    // Base needs a deeper instruction memory for this model size
    // (Fig 6 customization); keep S/M stock.
    let need = instrs.len().next_power_of_two();
    let base_cfg = AccelConfig::base().with_depths(need.max(8192), 2048);
    let mut base = Core::new(base_cfg.clone());
    base.program_model(&model).unwrap();
    let rb = base.run_batch(&packed).unwrap();
    let base_tput = 32.0 / base.seconds(rb.cycles.total());

    let single_cfg = AccelConfig::single_core().with_depths(need.max(28672), 8192);
    let mut single = Core::new(single_cfg.clone());
    single.program_model(&model).unwrap();
    let rs = single.run_batch(&packed).unwrap();
    let single_tput = 32.0 / single.seconds(rs.cycles.total());

    // Per-core memory must fit the heaviest class partition.
    let per_class: Vec<usize> = model
        .includes_per_class()
        .into_iter()
        .map(|v| if v == 0 { 2 } else { v })
        .collect();
    let heaviest = MultiCore::partition(&per_class, 5)
        .into_iter()
        .map(|(s, e)| per_class[s..e].iter().sum::<usize>())
        .max()
        .unwrap_or(2);
    let mc_cfg =
        AccelConfig::multicore_core().with_depths(heaviest.next_power_of_two().max(4096), 2048);
    let mut multi = MultiCore::new(5, mc_cfg.clone());
    multi.program_model(&model).unwrap();
    let rm = multi.run_batch(&packed).unwrap();
    let multi_tput = 32.0 / multi.seconds(rm.batch_cycles);

    let mtdr = Matador::synthesize(&model);

    println!("\n{:<22} {:>9} {:>14}  note", "flow", "LUTs", "inf/s");
    for (name, luts, tput) in literature {
        println!("{:<22} {:>9} {:>14.2e}  published", name, luts, tput);
    }
    println!(
        "{:<22} {:>9} {:>14.2e}  model-specific, resynthesis",
        "MATADOR [18]",
        mtdr.luts(),
        mtdr.throughput()
    );
    for (name, luts, tput) in [
        ("this work B", estimate(&base_cfg).luts, base_tput),
        ("this work S", estimate(&single_cfg).luts, single_tput),
        ("this work M(5)", estimate_multicore(&mc_cfg, 5).luts, multi_tput),
    ] {
        println!("{:<22} {:>9} {:>14.2e}  runtime tunable", name, luts, tput);
    }

    println!("\neFPGA max-LUT verticals:");
    for (chip, luts) in [("A7012", 8_000u32), ("A7035 (B fits)", 20_800), ("Z7020 (S/M fit)", 53_200)] {
        println!("  {chip:<18} {luts:>7} LUTs");
    }
    println!(
        "\nheadline: S @ {} LUTs vs MATADOR-MNIST {} LUTs -> {:.2}x fewer (paper: 2.5x, 3480-LUT config)",
        estimate(&single_cfg).luts,
        8709,
        8709.0 / estimate(&single_cfg).luts as f64
    );
    let _ = w;
}
