//! Fig 5: the instruction execution cycle — stage occupancy trace and
//! effective CPI for the pipelined vs iterative core.
//!
//! `cargo bench --bench fig5_pipeline`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::{AccelConfig, Core, PipelineMode};
use rttm::isa;

fn trace_for(mode: PipelineMode) -> (Core, Vec<rttm::accel::core::TraceEvent>, u64, usize) {
    let (_, model, data) = common::trained_model("emg", 256, 2);
    let mut core = Core::new(AccelConfig::base().with_pipeline(mode).with_depths(16384, 2048));
    core.trace_enabled = true;
    core.program_model(&model).unwrap();
    let packed = isa::pack_features(&data.xs[..32].to_vec());
    let r = core.run_batch(&packed).unwrap();
    let trace = core.trace.clone();
    let n = core.instruction_count();
    (core, trace, r.cycles.execute, n)
}

fn render(trace: &[rttm::accel::core::TraceEvent], instrs: usize, cycles: u64) {
    let stages = ["FETCH", "DECODE", "LIT-SEL", "CLAUSE-UPD"];
    let base = trace.iter().map(|e| e.cycle).min().unwrap_or(0);
    let width = 24usize;
    println!("{:<11} {}", "stage\\cycle", (0..width).map(|c| format!("{:>2}", c % 100)).collect::<Vec<_>>().join(""));
    for s in stages {
        let mut row = vec!["  ".to_string(); width];
        for e in trace.iter().filter(|e| e.stage == s && e.instr < instrs) {
            let c = (e.cycle - base) as usize;
            if c < width {
                row[c] = format!("{:>2}", e.instr);
            }
        }
        println!("{s:<11} {}", row.join(""));
    }
    println!("(cell = instruction index occupying the stage that cycle)");
    println!("execute cycles = {cycles}");
}

fn main() {
    println!("=== Fig 5: instruction execution cycle ===\n");

    println!("--- Pipelined core (the paper's design; steady state 1 instr/cycle) ---");
    let (_, trace, cycles, n) = trace_for(PipelineMode::Pipelined);
    render(&trace[..trace.len().min(32)], 6, cycles);
    println!("effective CPI = {:.3} over {} instructions (>= 4-cycle latency each, overlapped)\n", cycles as f64 / n as f64, n);

    println!("--- Iterative core (minimum-LUT variant: 4 cycles/instruction) ---");
    let (_, trace, cycles, n) = trace_for(PipelineMode::Iterative);
    render(&trace[..trace.len().min(32)], 6, cycles);
    println!("effective CPI = {:.3} over {} instructions", cycles as f64 / n as f64, n);

    // The paper's statement: "Each instruction takes a minimum of four
    // clock cycles to execute."
    println!("\ncheck: per-instruction latency is 4 cycles in both variants;");
    println!("the pipelined build overlaps them (Fig 5.2 shows the overlap).");
}
