//! Fig 6: memory-depth customization of the base configuration — LUTs,
//! FFs, power and f_max across instruction/feature memory depths, with
//! the per-workload minimum-depth verticals.
//!
//! `cargo bench --bench fig6_memory_depths`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::AccelConfig;
use rttm::model_cost::energy::EnergyModel;
use rttm::model_cost::{estimate, resources::min_depths};

fn main() {
    println!("=== Fig 6: base-config memory customization (A7-35T) ===\n");
    println!(
        "{:>11} {:>11} {:>7} {:>7} {:>7} {:>9} {:>9}",
        "instr_depth", "feat_depth", "LUTs", "FFs", "BRAMs", "P(W)", "f(MHz)"
    );
    for shift in 0..7 {
        let di = 1024usize << shift;
        let df = 256usize << shift;
        let cfg = AccelConfig::base().with_depths(di, df);
        let r = estimate(&cfg);
        let p = EnergyModel::for_config(&cfg);
        println!(
            "{:>11} {:>11} {:>7} {:>7} {:>7} {:>9.3} {:>9.1}",
            di, df, r.luts, r.ffs, r.brams, p.watts, r.freq_mhz
        );
    }

    println!("\nminimum required depths per workload (the Fig 6 verticals):");
    println!(
        "{:<12} {:>13} {:>13}  fits stock base (8192/2048)?",
        "workload", "instr entries", "feature words"
    );
    for name in ["emg", "gesture", "har", "sensorless", "gasdrift", "kws6", "cifar2", "mnist"] {
        let (_, model, _) = common::trained_model(name, 384, 2);
        let (di, df) = min_depths(&model);
        let fits = di <= 8192 && df <= 2048;
        println!(
            "{:<12} {:>13} {:>13}  {}",
            name,
            di,
            df,
            if fits { "yes" } else { "no -> customize" }
        );
    }
    println!("\ntrade-off (paper): deeper memories buy runtime-tunability headroom");
    println!("at more LUT/FF/power and lower f_max — unlike a fixed-memory ASIC.");
}
