//! Table 1: resource usage of the three accelerator configurations vs
//! MATADOR builds for CIFAR-2, KWS-6 and MNIST.
//!
//! `cargo bench --bench table1_resources`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::AccelConfig;
use rttm::baselines::matador::{Matador, TABLE1_MATADOR};
use rttm::model_cost::{estimate, estimate_multicore};

fn main() {
    println!("=== Table 1: resource usage (reproduced) ===\n");
    println!(
        "{:<22} {:>6} {:>9} {:>8} {:>7} {:>10}",
        "Configuration", "chip", "LUTs", "FFs", "BRAMs", "Freq(MHz)"
    );

    let rows = [
        ("Base (B)", estimate(&AccelConfig::base())),
        ("Single Core (S)", estimate(&AccelConfig::single_core())),
        (
            "Multi-Core (M, 5x)",
            estimate_multicore(&AccelConfig::multicore_core(), 5),
        ),
    ];
    for (label, r) in rows {
        println!(
            "{:<22} {:>6} {:>9} {:>8} {:>7} {:>10.0}",
            label, r.chip, r.luts, r.ffs, r.brams, r.freq_mhz
        );
    }

    println!("\n--- MATADOR (model-specific, resynthesis per model) ---");
    println!(
        "{:<22} {:>6} {:>9} {:>8} {:>7} {:>10}   (paper anchors: LUT/FF/BRAM)",
        "Model", "chip", "LUTs", "FFs", "BRAMs", "Freq(MHz)"
    );
    for name in ["cifar2", "kws6", "mnist"] {
        let (w, model, _) = common::trained_model(name, 512, 2);
        let m = Matador::synthesize(&model);
        let anchor = TABLE1_MATADOR.iter().find(|a| a.0 == name).unwrap();
        println!(
            "{:<22} {:>6} {:>9} {:>8} {:>7} {:>10.0}   paper: {}/{}/{}",
            format!("MTDR ({})", w.name),
            "Z7020",
            m.luts(),
            m.ffs(),
            m.brams(),
            m.freq_mhz,
            anchor.1,
            anchor.2,
            anchor.3,
        );
    }

    // The paper's headline resource claim.
    let s = estimate(&AccelConfig::single_core());
    let mnist_anchor = TABLE1_MATADOR.iter().find(|a| a.0 == "mnist").unwrap();
    println!(
        "\nheadline: S uses {:.2}x fewer LUTs and {:.2}x fewer FFs than MATADOR-MNIST (paper: 2.5x / 3.38x)",
        mnist_anchor.1 as f64 / s.luts as f64,
        mnist_anchor.2 as f64 / s.ffs as f64,
    );
}
