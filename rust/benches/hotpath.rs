//! Hot-path wall-clock benchmarks (the §Perf baseline in
//! EXPERIMENTS.md): how fast the *simulator and runtime themselves* run
//! on the host, independent of the modeled eFPGA clock.
//!
//! Targets (DESIGN.md §7): the L3 cycle loop should sustain >100M
//! instruction-slots/s so whole Table 2 sweeps finish in seconds.
//!
//! `cargo bench --bench hotpath`

#[path = "common/mod.rs"]
mod common;

use common::bench_ns;
use rttm::accel::core::{AccelConfig, Core};
use rttm::config::Manifest;
use rttm::isa;
use rttm::runtime::Runtime;

fn main() {
    let (w, model, data) = common::trained_model("emg", 512, 3);
    let instrs = isa::encode(&model);
    let need = instrs.len().next_power_of_two().max(8192);
    let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
    let packed = isa::pack_features(&rows);

    println!("=== hot-path wall-clock (host) — workload {} ({} instrs) ===\n", w.name, instrs.len());

    // 1. Simulator batch walk (the L3 hot loop).
    let mut core = Core::new(AccelConfig::base().with_depths(need, 2048));
    core.program_model(&model).unwrap();
    let ns = bench_ns(100, 1500, || {
        let r = core.run_batch(&packed).unwrap();
        std::hint::black_box(r.preds);
    });
    let mips = instrs.len() as f64 / (ns / 1e9) / 1e6;
    println!(
        "simulator run_batch:       {:>10.1} us/batch  {:>8.1} M instr-slots/s  ({:.1} M inferences/s host)",
        ns / 1e3,
        mips,
        32.0 / (ns / 1e9) / 1e6
    );

    // 2. Software ISA walk, single datapoint (the MCU-interpreter loop).
    let lits = rttm::tm::reference::literals_from_features(&rows[0]);
    let ns = bench_ns(20, 200, || {
        let s = isa::decode_infer(&instrs, &lits, w.shape.classes).unwrap();
        std::hint::black_box(s);
    });
    println!(
        "sw walk (1 datapoint):     {:>10.1} us/dp     {:>8.1} M instr/s",
        ns / 1e3,
        instrs.len() as f64 / (ns / 1e9) / 1e6
    );

    // 3. Model compression (encode) — the retuning path.
    let ns = bench_ns(5, 50, || {
        let i = isa::encode(&model);
        std::hint::black_box(i.len());
    });
    println!(
        "isa::encode:               {:>10.1} us/model  {:>8.1} M TA/s scanned",
        ns / 1e3,
        w.shape.total_tas() as f64 / (ns / 1e9) / 1e6
    );

    // 4. Feature packing.
    let ns = bench_ns(20, 200, || {
        let p = isa::pack_features(&rows);
        std::hint::black_box(p.len());
    });
    println!("pack_features (32 rows):   {:>10.2} us", ns / 1e3);

    // 5. Dense reference (the golden model the simulator is checked
    //    against) for context.
    let ns = bench_ns(5, 50, || {
        let s = rttm::tm::reference::class_sums_dense(&model, &lits);
        std::hint::black_box(s);
    });
    println!("dense reference (1 dp):    {:>10.1} us/dp", ns / 1e3);

    // 6. PJRT artifacts (if built): infer + train step.
    if let Ok(man) = Manifest::load_default() {
        let rt = Runtime::cpu().expect("pjrt");
        let infer = rt.load_infer(&man, "emg").expect("infer artifact");
        let mask = model.to_packed_mask();
        let lit_rows: Vec<Vec<u8>> = rows
            .iter()
            .map(|x| rttm::tm::reference::literals_from_features(x))
            .collect();
        let xs = isa::pack_literals(&lit_rows);
        let ns = bench_ns(5, 50, || {
            let o = infer.infer_packed(&mask, &xs).unwrap();
            std::hint::black_box(o.preds);
        });
        println!("PJRT infer artifact:       {:>10.1} us/batch (32 dp)", ns / 1e3);

        let train = rt.load_train(&man, "emg").expect("train artifact");
        let mut rng = rttm::datasets::synth::XorShift64Star::new(1);
        let ta0 = rttm::runtime::init_ta_states(&train.shape, &mut rng);
        let mut x_lit = Vec::new();
        for row in &data.xs[..train.shape.train_batch] {
            x_lit.extend(
                rttm::tm::reference::literals_from_features(row)
                    .iter()
                    .map(|&v| v as i32),
            );
        }
        let ys: Vec<i32> = data.ys[..train.shape.train_batch].iter().map(|&y| y as i32).collect();
        let ns = bench_ns(3, 20, || {
            let t = train.step(&ta0, &x_lit, &ys, [5, 6]).unwrap();
            std::hint::black_box(t.len());
        });
        println!("PJRT train step:           {:>10.1} us/batch (32 samples)", ns / 1e3);
    } else {
        println!("(artifacts not built; skipping PJRT rows)");
    }
}
