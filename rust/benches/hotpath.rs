//! Hot-path wall-clock benchmarks (the §Perf baseline in
//! EXPERIMENTS.md): how fast the *simulator and runtime themselves* run
//! on the host, independent of the modeled eFPGA clock.
//!
//! Targets (DESIGN.md §7): the L3 cycle loop should sustain >100M
//! instruction-slots/s so whole Table 2 sweeps finish in seconds.
//!
//! Besides the human-readable table this bench emits
//! `BENCH_hotpath.json` (machine-readable, one flat object) so the perf
//! trajectory can be tracked across commits.  `HOTPATH_SMOKE=1` shrinks
//! workloads/iterations for CI smoke runs.
//!
//! `cargo bench --bench hotpath`

#[path = "common/mod.rs"]
mod common;

use common::bench_ns;
use rttm::accel::core::{AccelConfig, BatchResult, Core};
use rttm::accel::engine;
use rttm::accel::multicore::{MultiCore, ParallelMode};
use rttm::config::Manifest;
use rttm::coordinator::server::spawn_pool;
use rttm::coordinator::{EngineSpec, InferenceService};
use rttm::isa::{self, DecodeWalk, Instr};
use rttm::runtime::Runtime;

/// The pre-SoA execution engine, kept verbatim as the before/after
/// baseline: AoS micro-ops with a branchy `Option` commit, per-read
/// literal-select branch, per-batch O(n) `max_feat` rescan and fresh
/// `sums` allocation — exactly what `Core::run_batch` did before the
/// SoA rebuild (EXPERIMENTS.md §Perf).
mod legacy {
    use super::{DecodeWalk, Instr};
    use rttm::isa;

    #[derive(Copy, Clone)]
    struct MicroOp {
        feat: u32,
        complement: bool,
        commit: Option<(u16, i8)>,
    }

    pub struct AosEngine {
        ops: Vec<MicroOp>,
        final_commit: Option<(u16, i8)>,
        classes: usize,
    }

    impl AosEngine {
        pub fn program(classes: usize, instrs: &[Instr]) -> Self {
            let mut ops = Vec::with_capacity(instrs.len());
            let mut walk = DecodeWalk::new(classes.max(1));
            for (i, &ins) in instrs.iter().enumerate() {
                let (ta, commit) = walk.step(i, ins, isa::MAX_LITERALS).unwrap();
                ops.push(MicroOp {
                    feat: (ta >> 1) as u32,
                    complement: ins.complement(),
                    commit: commit.map(|(cls, pol, _)| (cls as u16, pol as i8)),
                });
            }
            let final_commit = walk.finish().map(|(cls, pol, _)| (cls as u16, pol as i8));
            AosEngine { ops, final_commit, classes }
        }

        pub fn run_batch(&self, packed: &[u32]) -> Vec<[i32; 32]> {
            // Per-batch allocation + O(n) rescan, as in the old loop.
            let mut sums = vec![[0i32; 32]; self.classes];
            if let Some(max_feat) = self.ops.iter().map(|o| o.feat).max() {
                assert!((max_feat as usize) < packed.len());
            }
            let mut cur = u32::MAX;
            for op in &self.ops {
                if let Some((cls, pol)) = op.commit {
                    isa::apply_commit(&mut sums, (cls as usize, pol as i32, cur));
                    cur = u32::MAX;
                }
                let w = packed[op.feat as usize];
                cur &= if op.complement { !w } else { w };
            }
            if let Some((cls, pol)) = self.final_commit {
                isa::apply_commit(&mut sums, (cls as usize, pol as i32, cur));
            }
            sums
        }
    }
}

fn main() {
    let smoke = std::env::var("HOTPATH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let (corpus, epochs) = if smoke { (128, 1) } else { (512, 3) };
    let scale = |x: usize| if smoke { (x / 10).max(2) } else { x };

    let (w, model, data) = common::trained_model("emg", corpus, epochs);
    let instrs = isa::encode(&model);
    let need = instrs.len().next_power_of_two().max(8192);
    let rows: Vec<Vec<u8>> = data.xs[..32.min(data.len())].to_vec();
    let packed = isa::pack_features(&rows);
    let mut json: Vec<(String, f64)> = Vec::new();

    println!(
        "=== hot-path wall-clock (host) — workload {} ({} instrs) ===\n",
        w.name,
        instrs.len()
    );

    // 1. Simulator batch walk (the L3 hot loop), SoA engine vs the
    //    pre-change AoS loop.
    let mut core = Core::new(AccelConfig::base().with_depths(need, 2048));
    core.program_model(&model).unwrap();
    let soa_ns = bench_ns(scale(100), scale(1500), || {
        let r = core.run_batch(&packed).unwrap();
        std::hint::black_box(r.preds);
    });
    let mips = instrs.len() as f64 / (soa_ns / 1e9) / 1e6;
    println!(
        "simulator run_batch (SoA): {:>10.1} us/batch  {:>8.1} M instr-slots/s  ({:.1} M inferences/s host)",
        soa_ns / 1e3,
        mips,
        32.0 / (soa_ns / 1e9) / 1e6
    );
    json.push(("run_batch_ns".into(), soa_ns));
    json.push(("run_batch_m_instr_slots_per_s".into(), mips));

    let aos = legacy::AosEngine::program(w.shape.classes, &instrs);
    let aos_ns = bench_ns(scale(100), scale(1500), || {
        let s = aos.run_batch(&packed);
        std::hint::black_box(s.len());
    });
    println!(
        "pre-SoA AoS walk:          {:>10.1} us/batch  {:>8.1} M instr-slots/s  (speedup {:.2}x)",
        aos_ns / 1e3,
        instrs.len() as f64 / (aos_ns / 1e9) / 1e6,
        aos_ns / soa_ns
    );
    json.push(("legacy_aos_ns".into(), aos_ns));
    json.push(("soa_speedup_vs_aos".into(), aos_ns / soa_ns));

    // 1b. Zero-alloc steady state: run_batch_into with a reused result.
    let mut reused = BatchResult::default();
    let into_ns = bench_ns(scale(100), scale(1500), || {
        core.run_batch_into(&packed, &mut reused).unwrap();
        std::hint::black_box(reused.preds);
    });
    println!(
        "run_batch_into (reused):   {:>10.1} us/batch  {:>8.1} M instr-slots/s",
        into_ns / 1e3,
        instrs.len() as f64 / (into_ns / 1e9) / 1e6
    );
    json.push(("run_batch_into_ns".into(), into_ns));

    // 2. Throughput: run_batch loop vs run_batches stream, single core
    //    vs 5-core serial vs 5-core threaded (batches/s on the host).
    println!("\n--- serving throughput (host batches/s) ---");
    let n_stream = scale(256);
    let stream: Vec<Vec<u32>> = (0..n_stream)
        .map(|i| {
            let mut p = packed.clone();
            // Vary the batch so the stream isn't one cached pattern.
            for w in p.iter_mut() {
                *w = w.rotate_left((i % 31) as u32);
            }
            p
        })
        .collect();
    let refs: Vec<&[u32]> = stream.iter().map(|b| b.as_slice()).collect();

    let loop_ns = bench_ns(2, scale(30), || {
        for &b in &refs {
            let r = core.run_batch(b).unwrap();
            std::hint::black_box(r.preds);
        }
    });
    let stream_ns = bench_ns(2, scale(30), || {
        let rs = core.run_batches(&refs).unwrap();
        std::hint::black_box(rs.len());
    });
    let per = |total_ns: f64| n_stream as f64 / (total_ns / 1e9);
    println!(
        "single core, run_batch x{n_stream}:   {:>10.0} batches/s",
        per(loop_ns)
    );
    println!(
        "single core, run_batches:      {:>10.0} batches/s",
        per(stream_ns)
    );
    push_throughput(&mut json, "single_run_batch_loop_batches_per_s", per(loop_ns), 32, 1);
    push_throughput(&mut json, "single_run_batches_batches_per_s", per(stream_ns), 32, 1);

    // 5-core stock memories are shallow; deepen to fit the model.
    let deep = AccelConfig::multicore_core().with_depths(need, 2048);
    let mut mc_serial = MultiCore::new(5, deep.clone()).with_parallel(ParallelMode::Serial);
    let mut mc_threads = MultiCore::new(5, deep).with_parallel(ParallelMode::Threads);
    mc_serial.program_model(&model).unwrap();
    mc_threads.program_model(&model).unwrap();

    let serial_ns = bench_ns(2, scale(20), || {
        let rs = mc_serial.run_batches(&refs).unwrap();
        std::hint::black_box(rs.len());
    });
    let threads_ns = bench_ns(2, scale(20), || {
        let rs = mc_threads.run_batches(&refs).unwrap();
        std::hint::black_box(rs.len());
    });
    println!(
        "5-core serial, run_batches:    {:>10.0} batches/s",
        per(serial_ns)
    );
    println!(
        "5-core threads, run_batches:   {:>10.0} batches/s  (speedup {:.2}x over serial)",
        per(threads_ns),
        serial_ns / threads_ns
    );
    push_throughput(&mut json, "multicore_serial_batches_per_s", per(serial_ns), 32, 1);
    push_throughput(&mut json, "multicore_threads_batches_per_s", per(threads_ns), 32, 5);
    json.push(("multicore_thread_speedup".into(), serial_ns / threads_ns));

    // 2b. Scheduler end-to-end (pack + stream + unpack).
    let many_rows: Vec<Vec<u8>> = (0..32 * scale(64))
        .map(|i| data.xs[i % data.len()].clone())
        .collect();
    let t0 = std::time::Instant::now();
    let (_preds, _stats) = engine::classify_rows_core(&mut core, &many_rows).unwrap();
    let wall = t0.elapsed();
    // End-to-end rate (pack + stream + unpack) — the outer wall, not
    // the scheduler's stream-only StreamStats.
    let e2e_per_s = many_rows.len() as f64 / wall.as_secs_f64();
    println!(
        "scheduler classify_rows:       {:>10.0} inferences/s end-to-end ({} rows in {:.1} ms)",
        e2e_per_s,
        many_rows.len(),
        wall.as_secs_f64() * 1e3
    );
    // classify_rows_core auto-picks the kernel from the row count
    // (sliced at SLICED_MIN_ROWS+; smoke streams can sit below it).
    let scheduler_lanes = if many_rows.len() >= engine::SLICED_MIN_ROWS { 64 } else { 32 };
    push_throughput(&mut json, "scheduler_inferences_per_s", e2e_per_s, scheduler_lanes, 1);

    // 2b'. Bit-sliced row-parallel kernel (the §Bit-sliced tentpole):
    //      64 rows per bitwise op over transposed literal planes vs the
    //      32-lane per-batch walk.  EQUIVALENCE-GATED: predictions must
    //      be byte-identical before anything is timed — a fast wrong
    //      kernel must fail the bench, not set a record.
    println!("\n--- bit-sliced kernel (64 rows per bitwise op, single core) ---");
    let sliced_rows: Vec<Vec<u8>> = (0..32 * scale(256))
        .map(|i| data.xs[i % data.len()].clone())
        .collect();
    assert!(
        sliced_rows.len() >= engine::SLICED_MIN_ROWS,
        "bench batch must clear the sliced threshold ({} rows)",
        sliced_rows.len()
    );
    let (want_preds, _) = engine::classify_rows_core_soa(&mut core, &sliced_rows).unwrap();
    let (got_preds, _) = engine::classify_rows_core_sliced(&mut core, &sliced_rows).unwrap();
    assert_eq!(
        want_preds, got_preds,
        "sliced kernel must be byte-identical to the SoA path before timing"
    );

    let soa_bulk_ns = bench_ns(2, scale(20), || {
        let (p, _) = engine::classify_rows_core_soa(&mut core, &sliced_rows).unwrap();
        std::hint::black_box(p.len());
    });
    let sliced_bulk_ns = bench_ns(2, scale(20), || {
        let (p, _) = engine::classify_rows_core_sliced(&mut core, &sliced_rows).unwrap();
        std::hint::black_box(p.len());
    });
    let n_sliced = sliced_rows.len() as f64;
    let soa_inf_s = n_sliced / (soa_bulk_ns / 1e9);
    let sliced_inf_s = n_sliced / (sliced_bulk_ns / 1e9);
    println!(
        "32-lane SoA bulk walk:         {:>10.0} inferences/s ({} rows)",
        soa_inf_s,
        sliced_rows.len()
    );
    println!(
        "64-lane sliced kernel:         {:>10.0} inferences/s (speedup {:.2}x)",
        sliced_inf_s,
        sliced_inf_s / soa_inf_s
    );
    push_throughput(&mut json, "soa_single_core_inf_per_s", soa_inf_s, 32, 1);
    push_throughput(&mut json, "sliced_single_core_inf_per_s", sliced_inf_s, 64, 1);
    json.push(("sliced_speedup_vs_soa".into(), sliced_inf_s / soa_inf_s));

    // 5-core threaded sliced path (equivalence-gated like the rest).
    let (mc_preds, _) = engine::classify_rows_multicore(&mut mc_threads, &sliced_rows).unwrap();
    assert_eq!(mc_preds, want_preds, "multicore sliced path must match");
    let mc_sliced_ns = bench_ns(2, scale(20), || {
        let (p, _) = engine::classify_rows_multicore(&mut mc_threads, &sliced_rows).unwrap();
        std::hint::black_box(p.len());
    });
    let mc_sliced_inf_s = n_sliced / (mc_sliced_ns / 1e9);
    println!(
        "64-lane sliced, 5-core:        {:>10.0} inferences/s",
        mc_sliced_inf_s
    );
    push_throughput(&mut json, "sliced_multicore_inf_per_s", mc_sliced_inf_s, 64, 5);

    // 2b''. Compressed include-list kernel (the §Compressed tentpole):
    //       sparse gather-AND over only each clause's OWN includes vs
    //       the dense sliced plane walk, on a high-sparsity fixture —
    //       128 features, one include per clause, the regime ETHEREAL
    //       targets and trained edge models actually occupy.
    //       EQUIVALENCE-GATED like everything else: byte-identical
    //       preds before a single measurement.
    println!("\n--- compressed kernel (sparse include-list gather, single core) ---");
    let sparse_shape = rttm::TMShape::synthetic(128, 4, 32);
    let mut sparse_model = rttm::TMModel::empty(sparse_shape.clone());
    for class in 0..sparse_shape.classes {
        for clause in 0..sparse_shape.clauses {
            let lit = (class * sparse_shape.clauses + clause) * 7 % sparse_shape.literals();
            sparse_model.set_include(class, clause, lit, true);
        }
    }
    let mut rng = rttm::datasets::synth::XorShift64Star::new(2024);
    let sparse_rows: Vec<Vec<u8>> = (0..32 * scale(256))
        .map(|_| {
            (0..sparse_shape.features)
                .map(|_| u8::from(rng.next_f64() < 0.5))
                .collect()
        })
        .collect();
    let mut sparse_core = Core::new(AccelConfig::base());
    sparse_core.program_model(&sparse_model).unwrap();
    let density = sparse_core.compressed_program().density;
    let avg_includes = sparse_core.compressed_program().avg_includes();
    assert!(
        sparse_core.uses_compressed_kernel(),
        "sparse fixture (density {density:.4}) must auto-select the compressed kernel"
    );
    let (want_sparse, _) =
        engine::classify_rows_core_soa(&mut sparse_core, &sparse_rows).unwrap();
    let (sliced_sparse, _) =
        engine::classify_rows_core_sliced(&mut sparse_core, &sparse_rows).unwrap();
    let (comp_sparse, _) =
        engine::classify_rows_core_compressed(&mut sparse_core, &sparse_rows).unwrap();
    assert_eq!(comp_sparse, want_sparse, "compressed kernel must match the SoA path");
    assert_eq!(comp_sparse, sliced_sparse, "compressed kernel must match the sliced path");

    let sliced_sparse_ns = bench_ns(2, scale(20), || {
        let (p, _) = engine::classify_rows_core_sliced(&mut sparse_core, &sparse_rows).unwrap();
        std::hint::black_box(p.len());
    });
    let comp_sparse_ns = bench_ns(2, scale(20), || {
        let (p, _) =
            engine::classify_rows_core_compressed(&mut sparse_core, &sparse_rows).unwrap();
        std::hint::black_box(p.len());
    });
    let n_sparse = sparse_rows.len() as f64;
    let sliced_sparse_inf_s = n_sparse / (sliced_sparse_ns / 1e9);
    let comp_sparse_inf_s = n_sparse / (comp_sparse_ns / 1e9);
    println!(
        "64-lane sliced on sparse:      {:>10.0} inferences/s (density {:.4}, {:.1} includes/clause)",
        sliced_sparse_inf_s, density, avg_includes
    );
    println!(
        "compressed gather on sparse:   {:>10.0} inferences/s (speedup {:.2}x over sliced)",
        comp_sparse_inf_s,
        comp_sparse_inf_s / sliced_sparse_inf_s
    );
    push_throughput(&mut json, "compressed_sparse_inf_per_s", comp_sparse_inf_s, 64, 1);
    json.push(("compressed_speedup_vs_sliced".into(), comp_sparse_inf_s / sliced_sparse_inf_s));
    json.push(("compressed_include_density".into(), density));
    json.push(("compressed_avg_includes_per_clause".into(), avg_includes));

    // 2c. Serving front-end: single-worker vs replica pool (the
    //     coordinator::server request path, queue + reply channels
    //     included).  Requests are 1024-row bulk inferences so compute
    //     dominates the per-request RPC overhead; the pool multiplies
    //     host throughput while per-request simulated latency (the
    //     hardware's) is unchanged.
    println!("\n--- serving front-end (host inferences/s through the pool) ---");
    let spec = EngineSpec::custom(AccelConfig::base().with_depths(need, 2048));
    let n_requests = scale(64);
    let req_rows = 1024usize;
    let serving_reqs: Vec<Vec<Vec<u8>>> = (0..n_requests)
        .map(|i| {
            (0..req_rows)
                .map(|j| data.xs[(i * req_rows + j) % data.len()].clone())
                .collect()
        })
        .collect();
    // Predictions through the pool must be byte-identical to a single
    // InferenceService.
    let mut reference_svc = InferenceService::new(spec.build());
    reference_svc.reprogram(&model).unwrap();
    {
        let (h, mut join) = spawn_pool(spec.clone(), 4);
        h.program(model.clone()).unwrap();
        for r in &serving_reqs {
            assert_eq!(
                h.infer(r.clone()).unwrap(),
                reference_svc.infer_all(r).unwrap(),
                "pool must match the single-service path"
            );
        }
        h.shutdown();
        join.join();
    }
    let pool_replicas = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(4, 16);
    let mut measured: Vec<(String, f64)> = Vec::new();
    for (label, replicas) in [("single_worker", 1usize), ("pool", pool_replicas)] {
        let (h, mut join) = spawn_pool(spec.clone(), replicas);
        h.program(model.clone()).unwrap();
        // Warm-up pass, then the timed pass.
        for pass in 0..2 {
            let t0 = std::time::Instant::now();
            std::thread::scope(|s| {
                for c in 0..pool_replicas {
                    let h = h.clone();
                    let reqs = &serving_reqs;
                    s.spawn(move || {
                        for (i, r) in reqs.iter().enumerate() {
                            if i % pool_replicas == c {
                                let p = h.infer(r.clone()).unwrap();
                                std::hint::black_box(p.len());
                            }
                        }
                    });
                }
            });
            if pass == 1 {
                let wall = t0.elapsed();
                let inf_per_s =
                    (n_requests * req_rows) as f64 / wall.as_secs_f64().max(1e-12);
                println!(
                    "{label:<14} ({replicas:>2} replicas): {inf_per_s:>12.0} inferences/s host"
                );
                measured.push((format!("serving_{label}_inferences_per_s"), inf_per_s));
            }
        }
        h.shutdown();
        join.join();
    }
    let single = measured[0].1;
    let pool = measured[1].1;
    // 1024-row requests ride the 64-lane sliced kernel inside each
    // replica; host threads = replicas serving.
    for (i, (k, v)) in measured.into_iter().enumerate() {
        push_throughput(&mut json, &k, v, 64, if i == 0 { 1 } else { pool_replicas });
    }
    json.push(("serving_pool_replicas".into(), pool_replicas as f64));
    json.push(("serving_pool_speedup".into(), pool / single));
    println!(
        "pool speedup over single worker: {:.2}x ({} replicas)",
        pool / single,
        pool_replicas
    );

    // Direct-retrain recovery latency, measured in §2d and compared
    // against the online feedback path in §2f (the CI ratio gate).
    let mut detect_to_recover_ms = -1.0f64;

    // 2d. Live autotune: detection-to-recovery latency and served
    //     throughput WHILE the shadow retrain + swap runs.  A client
    //     hammers the pool throughout; the drift windows arrive, the
    //     tuner detects (hysteresis = 2 windows), shadow-searches on a
    //     background thread, and hot-swaps behind the version fence.
    {
        use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner};
        use rttm::datasets::workloads::DriftSchedule;
        use rttm::model_cost::resources::ResourceBudget;
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use std::sync::Arc;

        println!("\n--- live autotune (detection -> recovery, serving throughout) ---");
        let windows = 8usize;
        let window_n = scale(256).max(64);
        let drift_sched = DriftSchedule::abrupt(windows, window_n, 4, 0.4).seed(7);
        // Fresh draws past the monitored stream (the bench's shared
        // `model` was trained on the stream prefix itself).
        let tune_model =
            rttm::trainer::train_model(&w.shape, &drift_sched.training_set(&w, corpus), epochs, 3);
        // 4x instruction-memory headroom: retrained candidates may
        // carry more includes, and a failed swap would abort the bench.
        let tune_spec = EngineSpec::custom(rttm::model_cost::resources::provisioned_config(
            &tune_model,
            4,
        ));
        let (h, mut join) = spawn_pool(tune_spec, 4);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.accuracy_floor = 0.85;
        cfg.epochs = if smoke { 1 } else { 2 };
        cfg.retrain_corpus = 2 * window_n;
        // Direct swap here: this section times detect->swap; the canary
        // lifecycle is measured on its own in the §canary section below
        // (with a candidate that promotes deterministically).
        cfg.canary_fraction = 0.0;
        let mut tuner = Autotuner::new(h.clone(), w.shape.clone(), cfg);
        tuner.install(tune_model).unwrap();

        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let client = {
            let h = h.clone();
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            let rows: Vec<Vec<u8>> = data.xs[..32.min(data.len())].to_vec();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    h.infer(rows.clone()).unwrap();
                    served.fetch_add(32, Ordering::Relaxed);
                }
            })
        };

        let mut rps_during_retune = -1.0f64;
        for win in &drift_sched.stream(&w) {
            tuner.observe_window(&win.xs, &win.ys).unwrap();
            if tuner.is_searching() {
                // Drift just got confirmed: time the whole
                // detect -> shadow-retrain -> swap path while the client
                // keeps getting answers.
                let t0 = std::time::Instant::now();
                let before = served.load(Ordering::Relaxed);
                tuner.finish_pending_search().unwrap();
                let dt = t0.elapsed();
                let during = served.load(Ordering::Relaxed) - before;
                detect_to_recover_ms = dt.as_secs_f64() * 1e3;
                rps_during_retune = during as f64 / dt.as_secs_f64().max(1e-12);
            }
        }
        stop.store(true, Ordering::Relaxed);
        client.join().unwrap();
        let swapped = tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. }));
        assert!(swapped, "autotune bench must actually retune");
        println!(
            "detect->swap:            {detect_to_recover_ms:>10.1} ms (shadow retrain + fence swap)"
        );
        println!(
            "served during retune:    {rps_during_retune:>10.0} inferences/s (pool stays live)"
        );
        json.push(("autotune_detect_to_recover_ms".into(), detect_to_recover_ms));
        // 32-row client requests (below the sliced threshold), 4 replicas.
        push_throughput(
            &mut json,
            "autotune_served_during_retune_inf_per_s",
            rps_during_retune,
            32,
            4,
        );
        json.push((
            "autotune_swaps".into(),
            tuner
                .report
                .events
                .iter()
                .filter(|e| matches!(e, AutotuneEvent::Swapped { .. }))
                .count() as f64,
        ));
        h.shutdown();
        join.join();
    }

    // 2e. Canary swap lifecycle: stage a candidate on ONE replica
    //     (program_canary), mirror paired windows until the sequential
    //     verdict promotes, broadcast (promote_canary) — measuring the
    //     stage->promote wall latency and the client throughput WHILE
    //     the evaluation runs on the pool-minus-canary.  The candidate
    //     is the serving model itself: paired accuracies tie exactly,
    //     so the verdict promotes at min_windows deterministically and
    //     the numbers measure the MECHANISM, not model quality.
    {
        use rttm::coordinator::canary::{CanaryConfig, CanaryController, CanaryVerdict};
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use std::sync::Arc;

        println!("\n--- canary swap (stage -> paired eval -> promote, serving throughout) ---");
        let (h, mut join) = spawn_pool(spec.clone(), 4);
        h.program(model.clone()).unwrap();

        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let client = {
            let h = h.clone();
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            let rows: Vec<Vec<u8>> = data.xs[..32.min(data.len())].to_vec();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    h.infer(rows.clone()).unwrap();
                    served.fetch_add(32, Ordering::Relaxed);
                }
            })
        };

        let wn = 64.min(data.len());
        let win_xs = data.xs[..wn].to_vec();
        let win_ys = &data.ys[..wn];
        let t0 = std::time::Instant::now();
        let before = served.load(Ordering::Relaxed);
        h.program_canary(model.clone()).unwrap();
        let mut ctl = CanaryController::new(
            h.clone(),
            CanaryConfig {
                mirror_fraction: 0.5,
                min_windows: 2,
                max_windows: 4,
                baseline_t: w.shape.t,
                candidate_t: w.shape.t,
                ..Default::default()
            },
        );
        let mut eval_windows = 0usize;
        let verdict = loop {
            let (_paired, v) = ctl.observe(&win_xs, Some(win_ys)).unwrap();
            eval_windows += 1;
            if v != CanaryVerdict::Extend {
                break v;
            }
        };
        assert_eq!(verdict, CanaryVerdict::Promote, "identical candidate must promote");
        h.promote_canary().unwrap();
        let dt = t0.elapsed();
        let during = served.load(Ordering::Relaxed) - before;
        stop.store(true, Ordering::Relaxed);
        client.join().unwrap();
        assert!(h.canary_replica().is_none());

        let promote_ms = dt.as_secs_f64() * 1e3;
        let eval_rps = during as f64 / dt.as_secs_f64().max(1e-12);
        println!(
            "stage->promote:          {promote_ms:>10.1} ms ({eval_windows} paired windows, \
             fence swaps included)"
        );
        println!(
            "served during eval:      {eval_rps:>10.0} inferences/s (pool minus canary stays live)"
        );
        json.push(("canary_promote_latency_ms".into(), promote_ms));
        // 32-row client requests, 4 replicas (minus the canary).
        push_throughput(
            &mut json,
            "canary_served_during_eval_inf_per_s",
            eval_rps,
            32,
            4,
        );
        json.push(("canary_eval_windows".into(), eval_windows as f64));
        h.shutdown();
        join.join();
    }

    // 2f. §online — incremental TA feedback as the cheap recovery path.
    //     Two measurements, both against the SAME drift family §2d
    //     retrains on:
    //     * the raw feedback kernel rate (rows/s through
    //       `OnlineTrainer::feedback_batch`, 64-row sliced clause
    //       evaluation gating scalar TA updates);
    //     * the live recovery episode — drift detected, labeled windows
    //       folded into the serving model through the version fence,
    //       detector clears — timed end to end.  The CI gate holds this
    //       at <= half the §2d direct-retrain recovery from the SAME
    //       run: the cheap path must actually be cheap.
    {
        use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner};
        use rttm::datasets::workloads::DriftSchedule;
        use rttm::model_cost::resources::ResourceBudget;
        use rttm::trainer::online::OnlineTrainer;

        println!("\n--- online feedback (TA fine-tune, detection -> recovery) ---");
        let fb_n = 256.min(data.len());
        let fb_xs = &data.xs[..fb_n];
        let fb_ys = &data.ys[..fb_n];
        let mut online = OnlineTrainer::from_model(&model, 5);
        let fb_ns = bench_ns(scale(20), scale(200), || {
            let n = online.feedback_batch(fb_xs, fb_ys).unwrap();
            std::hint::black_box(n);
        });
        let fb_rows_per_s = fb_n as f64 / (fb_ns / 1e9);
        println!(
            "feedback_batch kernel:   {:>10.0} rows/s ({} rows, {:.1} us/window)",
            fb_rows_per_s,
            fb_n,
            fb_ns / 1e3
        );
        push_throughput(&mut json, "online_feedback_rows_per_s", fb_rows_per_s, 64, 1);

        let windows = 12usize;
        let window_n = scale(256).max(128);
        let fb_sched = DriftSchedule::abrupt(windows, window_n, 4, 0.4).seed(7);
        let fb_model =
            rttm::trainer::train_model(&w.shape, &fb_sched.training_set(&w, corpus), epochs, 3);
        // Same 4x headroom as §2d: fine-tuned models may carry more
        // includes than the seed they started from.
        let fb_spec = EngineSpec::custom(rttm::model_cost::resources::provisioned_config(
            &fb_model,
            4,
        ));
        let (h, mut join) = spawn_pool(fb_spec, 4);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.accuracy_floor = 0.85;
        cfg.online_feedback = true;
        cfg.online_patience = 7; // every drifted window before escalating
        cfg.epochs = if smoke { 1 } else { 2 };
        cfg.retrain_corpus = 2 * window_n;
        cfg.canary_fraction = 0.0;
        let mut tuner = Autotuner::new(h.clone(), w.shape.clone(), cfg);
        tuner.install(fb_model).unwrap();

        let mut episode_ns = 0u128;
        let mut online_recover_ms = -1.0f64;
        let mut online_recover_windows = -1.0f64;
        for win in &fb_sched.stream(&w) {
            let t0 = std::time::Instant::now();
            tuner.observe_window(&win.xs, &win.ys).unwrap();
            let dt = t0.elapsed().as_nanos();
            let detected = tuner
                .report
                .events
                .iter()
                .any(|e| matches!(e, AutotuneEvent::DriftDetected { .. }));
            if detected && online_recover_ms < 0.0 {
                // The episode: the trigger window's feedback through the
                // window whose healthy accuracy cleared the detector.
                episode_ns += dt;
                if let Some(fed) = tuner.report.events.iter().find_map(|e| match e {
                    AutotuneEvent::OnlineRecovered { fed_windows, .. } => Some(*fed_windows),
                    _ => None,
                }) {
                    online_recover_ms = episode_ns as f64 / 1e6;
                    online_recover_windows = fed as f64;
                }
            }
        }
        assert!(
            online_recover_ms >= 0.0,
            "online bench must actually recover: {:?}",
            tuner.report.events
        );
        assert!(
            !tuner
                .report
                .events
                .iter()
                .any(|e| matches!(e, AutotuneEvent::SearchCompleted { .. })),
            "online bench must recover without a budget_search"
        );
        println!(
            "detect->recover (online):{online_recover_ms:>10.1} ms ({online_recover_windows:.0} \
             feedback windows, fence swaps included)"
        );
        println!(
            "vs direct retrain (§2d): {detect_to_recover_ms:>10.1} ms (CI gates online <= 0.5x)"
        );
        json.push(("online_recover_ms".into(), online_recover_ms));
        json.push(("online_recover_windows".into(), online_recover_windows));
        h.shutdown();
        join.join();
    }

    // 3. Software ISA walk, single datapoint (the MCU-interpreter loop).
    let lits = rttm::tm::reference::literals_from_features(&rows[0]);
    let ns = bench_ns(scale(20), scale(200), || {
        let s = isa::decode_infer(&instrs, &lits, w.shape.classes).unwrap();
        std::hint::black_box(s);
    });
    println!(
        "\nsw walk (1 datapoint):     {:>10.1} us/dp     {:>8.1} M instr/s",
        ns / 1e3,
        instrs.len() as f64 / (ns / 1e9) / 1e6
    );

    // 4. Model compression (encode) — the retuning path.
    let ns = bench_ns(scale(5), scale(50), || {
        let i = isa::encode(&model);
        std::hint::black_box(i.len());
    });
    println!(
        "isa::encode:               {:>10.1} us/model  {:>8.1} M TA/s scanned",
        ns / 1e3,
        w.shape.total_tas() as f64 / (ns / 1e9) / 1e6
    );

    // 5. Feature packing.
    let ns = bench_ns(scale(20), scale(200), || {
        let p = isa::pack_features(&rows);
        std::hint::black_box(p.len());
    });
    println!("pack_features (32 rows):   {:>10.2} us", ns / 1e3);

    // 6. Dense reference (the golden model the simulator is checked
    //    against) for context.
    let ns = bench_ns(scale(5), scale(50), || {
        let s = rttm::tm::reference::class_sums_dense(&model, &lits);
        std::hint::black_box(s);
    });
    println!("dense reference (1 dp):    {:>10.1} us/dp", ns / 1e3);

    // 7. PJRT artifacts (if built AND the pjrt feature is on): infer +
    //    train step.
    match (Manifest::load_default(), Runtime::cpu()) {
        (Ok(man), Ok(rt)) => {
            let infer = rt.load_infer(&man, "emg").expect("infer artifact");
            let mask = model.to_packed_mask();
            let lit_rows: Vec<Vec<u8>> = rows
                .iter()
                .map(|x| rttm::tm::reference::literals_from_features(x))
                .collect();
            let xs = isa::pack_literals(&lit_rows);
            let ns = bench_ns(5, 50, || {
                let o = infer.infer_packed(&mask, &xs).unwrap();
                std::hint::black_box(o.preds);
            });
            println!("PJRT infer artifact:       {:>10.1} us/batch (32 dp)", ns / 1e3);

            let train = rt.load_train(&man, "emg").expect("train artifact");
            let mut rng = rttm::datasets::synth::XorShift64Star::new(1);
            let ta0 = rttm::runtime::init_ta_states(&train.shape, &mut rng);
            let mut x_lit = Vec::new();
            for row in &data.xs[..train.shape.train_batch] {
                x_lit.extend(
                    rttm::tm::reference::literals_from_features(row)
                        .iter()
                        .map(|&v| v as i32),
                );
            }
            let ys: Vec<i32> = data.ys[..train.shape.train_batch].iter().map(|&y| y as i32).collect();
            let ns = bench_ns(3, 20, || {
                let t = train.step(&ta0, &x_lit, &ys, [5, 6]).unwrap();
                std::hint::black_box(t.len());
            });
            println!("PJRT train step:           {:>10.1} us/batch (32 samples)", ns / 1e3);
        }
        _ => println!("(artifacts not built or pjrt feature off; skipping PJRT rows)"),
    }

    // 8. §saturation — admission front-end under classed overload.
    //    A 4-replica pool with tight data-class queues; one Critical
    //    client is timed per request while background Low/Normal
    //    clients push the offered load (client count over replica
    //    count) to 1x, 2x and 10x.  Emits the Critical p99 at each
    //    load plus the shed fraction at 10x — the CI gate requires the
    //    keys to exist and the 2x p99 to stay within 2x of uncontended
    //    (control traffic must ride through data-plane storms).
    {
        use rttm::coordinator::server::spawn_pool_cfg;
        use rttm::coordinator::{AdmissionConfig, IntegrityConfig, PoolConfig, Priority, ShedPolicy};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        println!("\n--- admission saturation (4 replicas, classed storms) ---");
        let sat_replicas = 4usize;
        let cfg = PoolConfig {
            replicas: sat_replicas,
            admission: AdmissionConfig {
                // Data classes small enough that 10x load visibly
                // sheds; control classes deep and blocking.
                queue_cap: [4, 4, 256, 256],
                policy: [
                    ShedPolicy::ShedOldest,
                    ShedPolicy::Reject,
                    ShedPolicy::Block,
                    ShedPolicy::Block,
                ],
            },
            autoscale: None,
            integrity: IntegrityConfig::default(),
        };
        let (h, mut join) = spawn_pool_cfg(spec.clone(), cfg);
        h.program(model.clone()).unwrap();
        let sat_rows: Vec<Vec<u8>> = (0..64).map(|j| data.xs[j % data.len()].clone()).collect();
        let n_timed = scale(200).max(40);

        // One storm at `bg_clients` background clients; returns the
        // timed Critical client's p99 (ms) and the shed fraction over
        // every class, both from this storm only (counter deltas).
        let storm = |bg_clients: usize| -> (f64, f64) {
            let before = h.admission_stats();
            let stop = Arc::new(AtomicBool::new(false));
            let bg: Vec<_> = (0..bg_clients)
                .map(|i| {
                    let h = h.clone();
                    let rows = sat_rows.clone();
                    let stop = Arc::clone(&stop);
                    let class = if i % 3 == 0 { Priority::Normal } else { Priority::Low };
                    std::thread::spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            // Overload refusals are the point at 10x.
                            let _ = h.infer_class(rows.clone(), class);
                        }
                    })
                })
                .collect();
            let mut lat_ms = Vec::with_capacity(n_timed);
            for _ in 0..n_timed {
                let t0 = std::time::Instant::now();
                h.infer_class(sat_rows.clone(), Priority::Critical).unwrap();
                lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            stop.store(true, Ordering::Relaxed);
            for t in bg {
                t.join().unwrap();
            }
            let after = h.admission_stats();
            let submitted: u64 = after
                .classes
                .iter()
                .zip(before.classes.iter())
                .map(|(a, b)| (a.admitted + a.rejected) - (b.admitted + b.rejected))
                .sum();
            let lost = after.lost_total() - before.lost_total();
            lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
            let p99 = lat_ms[(lat_ms.len() * 99 / 100).min(lat_ms.len() - 1)];
            (p99, lost as f64 / submitted.max(1) as f64)
        };

        let (p99_unc, _) = storm(0);
        let (p99_2x, shed_2x) = storm(2 * sat_replicas - 1);
        let (p99_10x, shed_10x) = storm(10 * sat_replicas - 1);
        println!(
            "critical p99 uncontended:  {p99_unc:>10.3} ms   (64-row requests, 1 client)"
        );
        println!(
            "critical p99 at 2x load:   {p99_2x:>10.3} ms   (shed frac {shed_2x:.3})"
        );
        println!(
            "critical p99 at 10x load:  {p99_10x:>10.3} ms   (shed frac {shed_10x:.3})"
        );
        json.push(("admission_p99_ms_uncontended".into(), p99_unc));
        json.push(("admission_p99_ms_2x".into(), p99_2x));
        json.push(("admission_p99_ms_10x".into(), p99_10x));
        json.push(("admission_shed_frac_10x".into(), shed_10x));
        h.shutdown();
        join.join();
    }

    // 9. §multi-model — two tenants through ONE pool under a 50/50
    //    request mix, Dedicated (replicas pinned per tenant, zero
    //    reprogram jitter) vs TimeShared (affinity-aware with the dwell
    //    thrash guard).  Both runs are equivalence-gated per tenant
    //    before timing, and the TimeShared run also reports the
    //    reprogram-thrash fraction (model switches per admitted job) —
    //    the number the dwell guard exists to keep near zero.  The CI
    //    gate requires TimeShared to hold >= 0.5x Dedicated here.
    {
        use rttm::coordinator::server::spawn_pool_sharded;
        use rttm::coordinator::{PoolConfig, ShardingPolicy};

        println!("\n--- multi-model serving (two tenants, 50/50 mix, 4 replicas) ---");
        // Tenant B: same shape, different prototype draw (a drifted
        // re-train), so cross-tenant contamination would show up as a
        // byte-level mismatch in the equivalence gate.
        let drifted = w.drifted_dataset(corpus, 9, 0.4);
        let model_b = rttm::trainer::train_model(&w.shape, &drifted, epochs, 11);
        let mut ref_b = InferenceService::new(spec.build());
        ref_b.reprogram(&model_b).unwrap();

        let mut mm_inf_per_s: Vec<f64> = Vec::new();
        let mut thrash_frac = 0.0f64;
        for sharding in [ShardingPolicy::Dedicated, ShardingPolicy::time_shared()] {
            let (h, mut join) = spawn_pool_sharded(spec.clone(), PoolConfig::fixed(4), sharding);
            let ida = h.register_model("tenant-a", model.clone()).unwrap();
            let idb = h.register_model("tenant-b", model_b.clone()).unwrap();
            let ha = h.with_model(ida);
            let hb = h.with_model(idb);
            // Per-tenant equivalence gate: a wrong route is a failure,
            // not a data point.
            assert_eq!(
                ha.infer(serving_reqs[0].clone()).unwrap(),
                reference_svc.infer_all(&serving_reqs[0]).unwrap(),
                "tenant A through the {} pool must match its own model",
                sharding.name()
            );
            assert_eq!(
                hb.infer(serving_reqs[0].clone()).unwrap(),
                ref_b.infer_all(&serving_reqs[0]).unwrap(),
                "tenant B through the {} pool must match its own model",
                sharding.name()
            );
            // Two clients per tenant, interleaved over the shared
            // request corpus: warm-up pass, then the timed pass.
            for pass in 0..2 {
                let t0 = std::time::Instant::now();
                std::thread::scope(|s| {
                    for (ci, handle) in
                        [ha.clone(), hb.clone(), ha.clone(), hb.clone()].into_iter().enumerate()
                    {
                        let reqs = &serving_reqs;
                        s.spawn(move || {
                            for (i, r) in reqs.iter().enumerate() {
                                if i % 4 == ci {
                                    let p = handle.infer(r.clone()).unwrap();
                                    std::hint::black_box(p.len());
                                }
                            }
                        });
                    }
                });
                if pass == 1 {
                    let wall = t0.elapsed();
                    let inf_per_s =
                        (n_requests * req_rows) as f64 / wall.as_secs_f64().max(1e-12);
                    println!(
                        "{:<14} (2 tenants):   {inf_per_s:>12.0} inferences/s host",
                        sharding.name()
                    );
                    mm_inf_per_s.push(inf_per_s);
                }
            }
            let stats = h.pool_stats();
            let admitted: u64 = stats.models.iter().map(|m| m.admitted()).sum();
            match sharding {
                ShardingPolicy::Dedicated => assert_eq!(
                    stats.sharding_switches, 0,
                    "dedicated pools must never reprogram for traffic"
                ),
                ShardingPolicy::TimeShared { .. } => {
                    thrash_frac = stats.sharding_switches as f64 / admitted.max(1) as f64;
                }
            }
            h.shutdown();
            join.join();
        }
        println!(
            "time-shared vs dedicated:       {:>10.2}x  (reprogram thrash frac {thrash_frac:.4})",
            mm_inf_per_s[1] / mm_inf_per_s[0]
        );
        // 1024-row requests ride the 64-lane sliced kernel; 4 replicas.
        push_throughput(&mut json, "multimodel_dedicated_inf_per_s", mm_inf_per_s[0], 64, 4);
        push_throughput(&mut json, "multimodel_timeshared_inf_per_s", mm_inf_per_s[1], 64, 4);
        json.push(("multimodel_reprogram_thrash_frac".into(), thrash_frac));
    }

    // 10. §integrity — what self-healing costs and how fast it heals.
    //     scrub_overhead_frac: pool throughput with a tight (1 ms)
    //     background scrub cadence vs scrubbing off, same workload,
    //     same process — the fractional cost of digest verification on
    //     every served batch plus the background scrub ticks.  The CI
    //     gate requires <= 0.05.  corrupt_to_heal_ms: median wall time
    //     from arming a FlipModelBits fault against an idle scrubbed
    //     pool to the integrity counters recording the heal — fault
    //     pop, detection, re-derive from the golden Arc and re-verify,
    //     end to end.
    {
        use rttm::coordinator::server::spawn_pool_cfg;
        use rttm::coordinator::{FaultPlan, IntegrityConfig, PoolConfig};
        use std::time::{Duration, Instant};

        println!("\n--- integrity (scrub overhead + corrupt->heal, 4 replicas) ---");
        let ipool = 4usize;
        let scrub_iv = Duration::from_millis(1);

        // Warm-up pass then timed pass, 4 clients interleaved over the
        // serving corpus — the same shape as the §serving measurement,
        // so on/off differ only in the integrity layer.
        let run = |integrity: IntegrityConfig| -> (f64, u64) {
            let mut cfg = PoolConfig::fixed(ipool);
            cfg.integrity = integrity;
            let (h, mut join) = spawn_pool_cfg(spec.clone(), cfg);
            h.program(model.clone()).unwrap();
            let mut inf_per_s = 0.0;
            for pass in 0..2 {
                let t0 = Instant::now();
                std::thread::scope(|s| {
                    for ci in 0..4 {
                        let h = h.clone();
                        let reqs = &serving_reqs;
                        s.spawn(move || {
                            for (i, r) in reqs.iter().enumerate() {
                                if i % 4 == ci {
                                    let p = h.infer(r.clone()).unwrap();
                                    std::hint::black_box(p.len());
                                }
                            }
                        });
                    }
                });
                if pass == 1 {
                    inf_per_s =
                        (n_requests * req_rows) as f64 / t0.elapsed().as_secs_f64().max(1e-12);
                }
            }
            let scrubs = h.pool_stats().integrity.scrubs;
            h.shutdown();
            join.join();
            (inf_per_s, scrubs)
        };

        let (off_ips, _) = run(IntegrityConfig::default());
        let (on_ips, scrubs) = run(IntegrityConfig::scrubbed(scrub_iv));
        assert!(scrubs > 0, "scrubbed run never verified a digest");
        let scrub_overhead = (1.0 - on_ips / off_ips).max(0.0);
        println!("serving, scrubbing off:    {off_ips:>12.0} inferences/s host");
        println!(
            "serving, 1ms scrub:        {on_ips:>12.0} inferences/s host  \
             (overhead frac {scrub_overhead:.4}, {scrubs} scrubs)"
        );

        // Heal latency on an idle pool: the background scrubber is the
        // only detector running, so the number is cadence + heal, not
        // traffic-position luck.
        let mut cfg = PoolConfig::fixed(ipool);
        cfg.integrity = IntegrityConfig::scrubbed(scrub_iv);
        let (h, mut join) = spawn_pool_cfg(spec.clone(), cfg);
        h.program(model.clone()).unwrap();
        let trials: usize = if smoke { 3 } else { 8 };
        let mut heal_ms: Vec<f64> = Vec::new();
        for t in 0..trials {
            let before = h.pool_stats().integrity.heals;
            let t0 = Instant::now();
            h.inject_fault(FaultPlan::flip_model_bits(t % ipool, 0xB17F_11D5 + t as u64, 8));
            while h.pool_stats().integrity.heals <= before
                && t0.elapsed() < Duration::from_secs(10)
            {
                std::thread::sleep(Duration::from_micros(200));
            }
            if h.pool_stats().integrity.heals > before {
                heal_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        assert!(!heal_ms.is_empty(), "no injected corruption was ever healed");
        let s = h.pool_stats().integrity;
        assert_eq!(s.failed_heals, 0, "idle-pool heals must succeed in place: {s:?}");
        h.shutdown();
        join.join();
        heal_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite heal latency"));
        let corrupt_to_heal = heal_ms[heal_ms.len() / 2];
        println!(
            "corrupt -> healed (median):{corrupt_to_heal:>10.3} ms   ({} trials, 1ms cadence)",
            heal_ms.len()
        );
        json.push(("scrub_overhead_frac".into(), scrub_overhead));
        json.push(("corrupt_to_heal_ms".into(), corrupt_to_heal));
    }

    write_json("BENCH_hotpath.json", &json);
}

/// Push one throughput key plus its machine-readable context — the
/// rows-per-batch of the kernel that produced it and the host threads
/// engaged — so BENCH trajectories stay comparable across PRs when
/// either changes (a 64-lane number must never be mistaken for a
/// 32-lane regression or vice versa).
fn push_throughput(
    json: &mut Vec<(String, f64)>,
    key: &str,
    value: f64,
    rows_per_batch: usize,
    threads: usize,
) {
    json.push((key.to_string(), value));
    json.push((format!("{key}_rows_per_batch"), rows_per_batch as f64));
    json.push((format!("{key}_threads"), threads as f64));
}

/// Flat-object JSON writer (no serde in the offline vendor set).
fn write_json(path: &str, entries: &[(String, f64)]) {
    let mut s = String::from("{\n");
    for (i, (k, v)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        let v = if v.is_finite() { *v } else { -1.0 };
        s.push_str(&format!("  \"{k}\": {v:.3}{comma}\n"));
    }
    s.push_str("}\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
