//! Ablations of the design choices DESIGN.md calls out (not a paper
//! table — the "what did each mechanism buy" analysis):
//!
//!  A. Include-only compression: compressed walk vs dense TA walk
//!     (cycles and model-memory traffic).
//!  B. Bit-sliced batching: batch=32 vs batch=1 throughput/energy.
//!  C. Pipelining: pipelined vs iterative core latency.
//!  D. Multi-core scaling: 1..8 cores on an 11-class workload.
//!
//! `cargo bench --bench ablations`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::{AccelConfig, Core, PipelineMode};
use rttm::accel::multicore::MultiCore;
use rttm::isa;
use rttm::model_cost::energy::EnergyModel;

fn main() {
    let (w, model, data) = common::trained_model("sensorless", 768, 3);
    let instrs = isa::encode(&model);
    let need = instrs.len().next_power_of_two().max(8192);
    let packed = isa::pack_features(&data.xs[..32].to_vec());

    println!("=== Ablations (workload {}, {} instructions) ===", w.name, instrs.len());

    // --- A. compression --------------------------------------------------
    let dense_tas = w.shape.total_tas() as u64;
    let compressed = instrs.len() as u64;
    println!("\nA. include-only compression:");
    println!("   dense walk:      {:>10} TA visits/batch, model mem {:>9} bits", dense_tas, dense_tas);
    println!(
        "   compressed walk: {:>10} instr/batch,     model mem {:>9} bits ({:.1}% of dense, {:.0}x fewer cycles)",
        compressed,
        compressed * 16,
        100.0 * (compressed * 16) as f64 / dense_tas as f64,
        dense_tas as f64 / compressed as f64
    );

    // --- B. batching ------------------------------------------------------
    let mut core = Core::new(AccelConfig::base().with_depths(need, 2048));
    core.program_model(&model).unwrap();
    let rb = core.run_batch(&packed).unwrap();
    let batch_us = core.seconds(rb.cycles.total()) * 1e6;
    let single_packed = isa::pack_features(&data.xs[..1].to_vec());
    let rs = core.run_batch(&single_packed).unwrap();
    let single_us = core.seconds(rs.cycles.total()) * 1e6;
    let em = EnergyModel::for_config(&core.cfg);
    println!("\nB. bit-sliced batching (same silicon, same walk):");
    println!(
        "   batch=1:  {:>8.2} us -> {:>10.0} inf/s, {:>8.4} uJ/inf",
        single_us,
        1e6 / single_us,
        em.energy_uj(single_us)
    );
    println!(
        "   batch=32: {:>8.2} us -> {:>10.0} inf/s, {:>8.4} uJ/inf ({:.1}x throughput, {:.1}x energy/inf)",
        batch_us,
        32.0 * 1e6 / batch_us,
        em.energy_uj(batch_us) / 32.0,
        32.0 * single_us / batch_us,
        em.energy_uj(single_us) / (em.energy_uj(batch_us) / 32.0)
    );

    // --- C. pipelining ----------------------------------------------------
    let mut iter = Core::new(
        AccelConfig::base()
            .with_depths(need, 2048)
            .with_pipeline(PipelineMode::Iterative),
    );
    iter.program_model(&model).unwrap();
    let ri = iter.run_batch(&packed).unwrap();
    println!("\nC. pipeline (Fig 5):");
    println!(
        "   iterative: {:>8} exec cycles (CPI 4.0)\n   pipelined: {:>8} exec cycles (CPI {:.3}) -> {:.2}x",
        ri.cycles.execute,
        rb.cycles.execute,
        rb.cycles.execute as f64 / instrs.len() as f64,
        ri.cycles.execute as f64 / rb.cycles.execute as f64
    );

    // --- D. multi-core scaling --------------------------------------------
    println!("\nD. multi-core scaling ({} classes):", w.shape.classes);
    println!("   {:>5} {:>12} {:>10} {:>10}", "cores", "batch cycles", "speedup", "efficiency");
    let per_class: Vec<usize> = model
        .includes_per_class()
        .into_iter()
        .map(|v| if v == 0 { 2 } else { v })
        .collect();
    let mut base_cycles = 0u64;
    for n in [1usize, 2, 3, 5, 8] {
        let heaviest = MultiCore::partition(&per_class, n)
            .into_iter()
            .map(|(s, e)| per_class[s..e].iter().sum::<usize>())
            .max()
            .unwrap_or(2);
        let cfg = AccelConfig::multicore_core()
            .with_depths(heaviest.next_power_of_two().max(4096), 2048);
        let mut mc = MultiCore::new(n, cfg);
        mc.program_model(&model).unwrap();
        let r = mc.run_batch(&packed).unwrap();
        if n == 1 {
            base_cycles = r.batch_cycles;
        }
        let speedup = base_cycles as f64 / r.batch_cycles as f64;
        println!(
            "   {:>5} {:>12} {:>10.2} {:>10.2}",
            n,
            r.batch_cycles,
            speedup,
            speedup / n as f64
        );
    }
    println!("\n(speedup saturates at the heaviest class partition — the paper's");
    println!("class-level parallelism bound; paper reports 1.9x-3.3x at 5 cores)");
}
