//! Table 2: latency / throughput / energy of the proposed accelerators
//! vs the ESP32 software implementation of the same compressed
//! inference, across the five recalibration-suited UCI workloads.
//!
//! `cargo bench --bench table2_mcu_comparison`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::AccelConfig;
use rttm::accel::multicore::MultiCore;
use rttm::accel::Core;
use rttm::baselines::{Mcu, McuKind};
use rttm::coordinator::{Engine, InferenceService};
use rttm::isa;
use rttm::model_cost::energy::EnergyModel;

struct Row {
    design: String,
    batch_us: f64,
    single_us: f64,
    throughput: f64,
    batch_uj: f64,
    single_uj: f64,
}

fn main() {
    println!("=== Table 2: proposed accelerators vs ESP32 software ===");
    // Paper accuracies for the comparison column.
    for name in ["emg", "har", "gesture", "sensorless", "gasdrift"] {
        let (w, model, data) = common::trained_model(name, 768, 3);
        let instrs = isa::encode(&model);
        let need = instrs.len().next_power_of_two().max(8192);
        let packed = isa::pack_features(&data.xs[..32].to_vec());

        // Accuracy on the accelerator itself.
        let mut svc = InferenceService::new(Engine::custom(
            AccelConfig::base().with_depths(need, 2048),
        ));
        svc.reprogram(&model).unwrap();
        let acc = svc.measure_accuracy(&data.xs, &data.ys).unwrap();

        let mut rows: Vec<Row> = Vec::new();

        let base_cfg = AccelConfig::base().with_depths(need, 2048);
        let mut b = Core::new(base_cfg.clone());
        b.program_model(&model).unwrap();
        let rb = b.run_batch(&packed).unwrap();
        let us = b.seconds(rb.cycles.total()) * 1e6;
        let e = EnergyModel::for_config(&base_cfg).energy_uj(us);
        rows.push(row("Base (B)", us, e));

        let s_cfg = AccelConfig::single_core().with_depths(need.max(28672), 8192);
        let mut s = Core::new(s_cfg.clone());
        s.program_model(&model).unwrap();
        let rs = s.run_batch(&packed).unwrap();
        let us = s.seconds(rs.cycles.total()) * 1e6;
        let e = EnergyModel::for_config(&s_cfg).energy_uj(us);
        rows.push(row("Single Core (S)", us, e));

        // Per-core memory must fit the heaviest class *partition* (a
        // core may own several classes; cifar2 has one class per active
        // core, mnist two).
        let per_class: Vec<usize> = model
            .includes_per_class()
            .into_iter()
            .map(|n| if n == 0 { 2 } else { n })
            .collect();
        let heaviest = MultiCore::partition(&per_class, 5)
            .into_iter()
            .map(|(s, e)| per_class[s..e].iter().sum::<usize>())
            .max()
            .unwrap_or(2);
        let m_cfg = AccelConfig::multicore_core()
            .with_depths(heaviest.next_power_of_two().max(4096), 2048);
        let mut mc = MultiCore::new(5, m_cfg.clone());
        mc.program_model(&model).unwrap();
        let rm = mc.run_batch(&packed).unwrap();
        let us = mc.seconds(rm.batch_cycles) * 1e6;
        let e = EnergyModel::for_multicore(&m_cfg, 5).energy_uj(us);
        rows.push(row("5-Core (M)", us, e));

        let esp = Mcu::program_model(McuKind::Esp32, &model);
        rows.push(Row {
            design: "ESP32".into(),
            batch_us: esp.batch_latency_us(32),
            single_us: esp.single_latency_us(),
            throughput: esp.throughput(),
            batch_uj: esp.batch_energy_uj(32),
            single_uj: esp.kind.power_w() * esp.single_latency_us(),
        });

        let esp_single_us = rows.last().unwrap().single_us;
        let esp_single_uj = rows.last().unwrap().single_uj;

        println!(
            "\n--- {} (measured acc {:.2}, paper acc {}) ---",
            w.name,
            acc,
            w.paper_accuracy.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into())
        );
        println!(
            "{:<16} {:>11} {:>12} {:>12} {:>11} {:>12} {:>10} {:>9}",
            "Design", "L batch(us)", "L single(us)", "inf/s", "E batch(uJ)", "E single(uJ)", "xSpeedup", "xEnergy"
        );
        for r in &rows {
            println!(
                "{:<16} {:>11.2} {:>12.3} {:>12.0} {:>11.3} {:>12.4} {:>10.1} {:>9.1}",
                r.design,
                r.batch_us,
                r.single_us,
                r.throughput,
                r.batch_uj,
                r.single_uj,
                esp_single_us / r.single_us,
                esp_single_uj / r.single_uj,
            );
        }
    }
    println!("\npaper shape: 58x-684x speedups, 1.6x-129x energy reductions vs ESP32;");
    println!("M best on sensorless (most classes); batch = 32x single on the MCU.");
}

fn row(design: &str, batch_us: f64, batch_uj: f64) -> Row {
    Row {
        design: design.into(),
        batch_us,
        single_us: batch_us / 32.0,
        throughput: 32.0 * 1e6 / batch_us,
        batch_uj,
        single_uj: batch_uj / 32.0,
    }
}
