//! Shared helpers for the paper-reproduction benches.
//!
//! Each bench is a standalone `harness = false` binary (criterion is not
//! in the offline vendor set): it trains the workload models it needs,
//! runs the simulator/baselines, and prints the corresponding paper
//! table/figure rows.  Wall-clock measurement helpers live here too.

use rttm::datasets::synth::Dataset;
use rttm::datasets::workloads::{workload, Workload};
use rttm::tm::model::TMModel;

/// Train a workload model quickly (bench-scale corpus).
#[allow(dead_code)]
pub fn trained_model(name: &str, n: usize, epochs: usize) -> (Workload, TMModel, Dataset) {
    let w = workload(name).expect("workload");
    let data = w.dataset(n, 7);
    let model = rttm::trainer::train_model(&w.shape, &data, epochs, 3);
    (w, model, data)
}

/// Median wall-clock nanoseconds of `f` over `iters` runs (after
/// `warmup` runs).
#[allow(dead_code)]
pub fn bench_ns<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Simple aligned table printer.
#[allow(dead_code)]
pub fn print_row(cols: &[String], widths: &[usize]) {
    let line: Vec<String> = cols
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect();
    println!("{}", line.join("  "));
}
