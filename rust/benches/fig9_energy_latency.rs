//! Fig 9: energy (E) and latency (L) of B/S/M vs MATADOR (MTDR) and the
//! STM32Disco software baseline (RDRS), on MNIST / CIFAR-2 / KWS-6.
//! Hatched bars in the paper = single datapoint; solid = batched.
//! MATADOR has no batch mode.
//!
//! `cargo bench --bench fig9_energy_latency`

#[path = "common/mod.rs"]
mod common;

use rttm::accel::core::AccelConfig;
use rttm::accel::multicore::MultiCore;
use rttm::accel::Core;
use rttm::baselines::{Matador, Mcu, McuKind};
use rttm::isa;
use rttm::model_cost::energy::EnergyModel;

fn main() {
    println!("=== Fig 9: energy & latency vs MATADOR and RDRS (STM32) ===");
    for name in ["mnist", "cifar2", "kws6"] {
        let (w, model, data) = common::trained_model(name, 384, 2);
        let instrs = isa::encode(&model);
        let need = instrs.len().next_power_of_two().max(8192);
        let packed = isa::pack_features(&data.xs[..32].to_vec());

        println!(
            "\n--- {} ({} instructions) ---",
            w.name,
            instrs.len()
        );
        println!(
            "{:<18} {:>12} {:>12} {:>12} {:>12}",
            "design", "L batch(us)", "L single(us)", "E batch(uJ)", "E single(uJ)"
        );

        // B / S / M on the simulator.
        let base_cfg = AccelConfig::base().with_depths(need, 2048);
        let mut b = Core::new(base_cfg.clone());
        b.program_model(&model).unwrap();
        let rb = b.run_batch(&packed).unwrap();
        let b_us = b.seconds(rb.cycles.total()) * 1e6;
        let b_e = EnergyModel::for_config(&base_cfg);
        print_row("Base (B)", b_us, b_e.energy_uj(b_us));

        let s_cfg = AccelConfig::single_core().with_depths(need.max(28672), 8192);
        let mut s = Core::new(s_cfg.clone());
        s.program_model(&model).unwrap();
        let rs = s.run_batch(&packed).unwrap();
        let s_us = s.seconds(rs.cycles.total()) * 1e6;
        let s_e = EnergyModel::for_config(&s_cfg);
        print_row("Single Core (S)", s_us, s_e.energy_uj(s_us));

        // Per-core memory must fit the heaviest class *partition* (a
        // core may own several classes; cifar2 has one class per active
        // core, mnist two).
        let per_class: Vec<usize> = model
            .includes_per_class()
            .into_iter()
            .map(|n| if n == 0 { 2 } else { n })
            .collect();
        let heaviest = MultiCore::partition(&per_class, 5)
            .into_iter()
            .map(|(s, e)| per_class[s..e].iter().sum::<usize>())
            .max()
            .unwrap_or(2);
        let m_cfg = AccelConfig::multicore_core()
            .with_depths(heaviest.next_power_of_two().max(4096), 2048);
        let mut m = MultiCore::new(5, m_cfg.clone());
        m.program_model(&model).unwrap();
        let rm = m.run_batch(&packed).unwrap();
        let m_us = m.seconds(rm.batch_cycles) * 1e6;
        let m_e = EnergyModel::for_multicore(&m_cfg, 5);
        print_row("5-Core (M)", m_us, m_e.energy_uj(m_us));

        // MATADOR: single datapoint only.
        let mtdr = Matador::synthesize(&model);
        println!(
            "{:<18} {:>12} {:>12.3} {:>12} {:>12.4}   (no batch mode)",
            "MTDR",
            "-",
            mtdr.single_latency_us(),
            "-",
            mtdr.single_energy_uj()
        );

        // RDRS: the same compressed algorithm in software on STM32Disco.
        let rdrs = Mcu::program_model(McuKind::Stm32Disco, &model);
        println!(
            "{:<18} {:>12.2} {:>12.3} {:>12.3} {:>12.4}",
            "RDRS (STM32)",
            rdrs.batch_latency_us(32),
            rdrs.single_latency_us(),
            rdrs.batch_energy_uj(32),
            rdrs.kind.power_w() * rdrs.single_latency_us()
        );

        // Red annotations in the figure: speedup & energy reduction vs RDRS.
        println!(
            "B vs RDRS: {:.0}x speedup, {:.0}x energy reduction (single dp, amortized)",
            rdrs.single_latency_us() / (b_us / 32.0),
            (rdrs.kind.power_w() * rdrs.single_latency_us()) / (b_e.energy_uj(b_us) / 32.0),
        );
        println!(
            "order-of-magnitude check vs MTDR: B single {:.3} us vs MTDR {:.3} us -> within {:.1}x",
            b_us / 32.0,
            mtdr.single_latency_us(),
            (b_us / 32.0) / mtdr.single_latency_us()
        );
    }
    println!("\npaper shape: all B/S/M within one order of magnitude of MATADOR;");
    println!("B most energy-efficient on CIFAR-2; recalibration needs no resynthesis.");
}

fn print_row(label: &str, batch_us: f64, batch_uj: f64) {
    println!(
        "{:<18} {:>12.2} {:>12.3} {:>12.3} {:>12.4}",
        label,
        batch_us,
        batch_us / 32.0,
        batch_uj,
        batch_uj / 32.0
    );
}
