//! # rttm — Runtime Tunable Tsetlin Machines for Edge Inference on eFPGAs
//!
//! Full-system reproduction of Rahman et al., tinyML Research Symposium 2025.
//!
//! The paper's artifact is an eFPGA inference accelerator for compressed
//! (Include-only) Tsetlin Machine models that can be *re-programmed at
//! runtime* over a data stream — new model, new architecture, new input
//! dimensionality — without resynthesis.  This crate rebuilds that system
//! end to end (see DESIGN.md):
//!
//! * [`tm`] — the Tsetlin Machine substrate: dense models, booleanization,
//!   reference inference.
//! * [`isa`] — the 16-bit Include-instruction encoding (Fig 3.4) and the
//!   model compressor.
//! * [`accel`] — the cycle-accurate accelerator simulator (Fig 4/5):
//!   stream protocol, memories, base core, batching, multi-core.
//! * [`model_cost`] — LUT/FF/BRAM/frequency and power/energy models
//!   calibrated to the paper's Table 1 / Fig 6 / Fig 9.
//! * [`baselines`] — MATADOR and MCU (ESP32, STM32 "RDRS") comparators.
//! * [`datasets`] — synthetic generators for the paper's eight workloads
//!   (UCI data is substituted per DESIGN.md §Substitutions) + drift.
//! * [`trainer`] — the vanilla TM trainer (the Model Training Node's
//!   algorithm) in pure rust, cross-checked against the JAX trainer.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX artifacts
//!   (`artifacts/*.hlo.txt`); Python is never on the request path.
//! * [`coordinator`] — the Fig 8 deployment: inference service, training
//!   node, drift monitor, live reprogramming.

pub mod accel;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod isa;
pub mod model_cost;
pub mod runtime;
pub mod tm;
pub mod trainer;

pub use config::TMShape;
pub use tm::model::TMModel;
