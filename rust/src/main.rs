//! rttm CLI — drive the reproduced system from the shell.
//!
//! Hand-rolled argument parsing (no clap in the offline vendor set).
//!
//! ```text
//! rttm train   --workload emg [--backend pjrt|native] [--epochs N] [--n N]
//! rttm infer   --workload emg [--engine base|single|multi] [--n N]
//! rttm serve   --workload emg [--engine ...] [--requests N] [--replicas N]
//!              [--queue-cap N] [--shed-policy block|reject|shed-oldest]
//! rttm serve   --models a.rttm,b.rttm [--sharding dedicated|time-shared]
//!              [--requests N] [--replicas N] [--report-json PATH]
//! rttm serve   --workload emg --autotune [--schedule abrupt|gradual|recurring]
//!              [--budget LUTS,BRAMS,WATTS] [--windows N] [--drift F]
//! rttm retune  --workload emg [--drift 0.35] [--threshold 0.8]
//! rttm report  --workload emg          # resources + latency + energy card
//! rttm list                            # workloads & artifact status
//! ```

use rttm::accel::core::AccelConfig;
use rttm::baselines::{Matador, Mcu, McuKind};
use rttm::config::Manifest;
use rttm::coordinator::{Engine, InferenceService, RecalibrationLoop, TrainingNode};
use rttm::datasets::workloads::{workload, workload_names};
use rttm::model_cost::{energy::EnergyModel, estimate, estimate_multicore};
use rttm::runtime::Runtime;
use rttm::tm::reference;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let opts = Opts::parse(&args[1..]);
    let result = match cmd {
        "train" => cmd_train(&opts),
        "infer" => cmd_infer(&opts),
        "serve" => cmd_serve(&opts),
        "retune" => cmd_retune(&opts),
        "report" => cmd_report(&opts),
        "save" => cmd_save(&opts),
        "load" => cmd_load(&opts),
        "tune-hyper" => cmd_tune_hyper(&opts),
        "list" => cmd_list(),
        "-h" | "--help" | "help" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command: {other}");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "rttm — Runtime Tunable Tsetlin Machines (paper reproduction)\n\
         commands:\n\
         \x20 train   --workload W [--backend pjrt|native] [--epochs N] [--n N]\n\
         \x20 infer   --workload W [--engine base|single|multi] [--n N]\n\
         \x20 serve   --workload W [--engine ...] [--requests N] [--replicas N]\n\
         \x20         [--queue-cap N] [--shed-policy block|reject|shed-oldest]\n\
         \x20         [--scrub-interval MS] [--report-json PATH]\n\
         \x20         [--models a.rttm,b.rttm [--sharding dedicated|time-shared]]\n\
         \x20         [--autotune [--schedule abrupt|gradual|recurring]\n\
         \x20          [--budget LUTS,BRAMS,WATTS] [--windows N] [--window-n N] [--drift F]\n\
         \x20          [--canary-fraction F] [--label-free [--label-delay N]]\n\
         \x20          [--online-feedback [--online-patience N]]\n\
         \x20          [--report-json PATH]]\n\
         \x20 retune  --workload W [--drift F] [--threshold F]\n\
         \x20 report  --workload W\n\
         \x20 save    --workload W --out model.rttm\n\
         \x20 load    --model model.rttm [--n N]\n\
         \x20 tune-hyper --workload W [--n N]\n\
         \x20 list"
    );
}

/// Minimal --key value parser.
struct Opts(std::collections::BTreeMap<String, String>);

impl Opts {
    fn parse(args: &[String]) -> Self {
        let mut map = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                // A following "--other" means THIS key is a bare flag
                // (e.g. `--autotune`), not a key eating the next token.
                let val = match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        i += 2;
                        v.clone()
                    }
                    _ => {
                        i += 1;
                        String::new()
                    }
                };
                map.insert(key.to_string(), val);
            } else {
                i += 1;
            }
        }
        Opts(map)
    }

    fn has(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.0.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.0.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn engine_for(name: &str) -> anyhow::Result<Engine> {
    Ok(match name {
        "base" => Engine::base(),
        "single" => Engine::single_core(),
        "multi" => Engine::five_core(),
        other => anyhow::bail!("unknown engine {other} (base|single|multi)"),
    })
}

/// Engine with memory depths provisioned for a specific model (the
/// Fig 6 deploy-time customization, applied automatically by the CLI).
fn fitted_engine_for(name: &str, model: &rttm::TMModel) -> anyhow::Result<Engine> {
    let need = rttm::isa::instruction_count(model)
        .next_power_of_two()
        .max(8192);
    let feats = model.shape.features.next_power_of_two().max(2048);
    Ok(match name {
        // Shared depth-fitting policy: model_cost::resources.
        "base" => Engine::custom(rttm::model_cost::resources::provisioned_config(model, 1)),
        "single" => Engine::custom(AccelConfig::single_core().with_depths(need.max(28672), feats.max(8192))),
        "multi" => {
            let per_class: Vec<usize> = model
                .includes_per_class()
                .into_iter()
                .map(|v| if v == 0 { 2 } else { v })
                .collect();
            let heaviest = rttm::accel::MultiCore::partition(&per_class, 5)
                .into_iter()
                .map(|(s, e)| per_class[s..e].iter().sum::<usize>())
                .max()
                .unwrap_or(2);
            let cfg = AccelConfig::multicore_core()
                .with_depths(heaviest.next_power_of_two().max(4096), feats);
            Engine::Multi(rttm::accel::MultiCore::new(5, cfg))
        }
        other => anyhow::bail!("unknown engine {other} (base|single|multi)"),
    })
}

fn cmd_list() -> anyhow::Result<()> {
    let man = Manifest::load_default().ok();
    println!(
        "{:<12} {:>8} {:>7} {:>7} {:>9}  artifacts",
        "workload", "features", "classes", "clauses", "TAs"
    );
    for name in workload_names() {
        let w = workload(name)?;
        let art = man
            .as_ref()
            .map(|m| if m.configs.contains_key(name) { "yes" } else { "no" })
            .unwrap_or("no");
        println!(
            "{:<12} {:>8} {:>7} {:>7} {:>9}  {}",
            w.name,
            w.shape.features,
            w.shape.classes,
            w.shape.clauses,
            w.shape.total_tas(),
            art
        );
    }
    Ok(())
}

fn cmd_train(opts: &Opts) -> anyhow::Result<()> {
    let mut w = workload(&opts.get("workload", "emg"))?;
    let n = opts.get_usize("n", 1024);
    let epochs = opts.get_usize("epochs", 6);
    let backend = opts.get("backend", "native");
    // Generator overrides (used by the accuracy-calibration sweep).
    w.noise = opts.get_f64("noise", w.noise);
    w.informative = opts.get_f64("informative", w.informative);
    let data = w.dataset(n, 7);
    let (train, test) = data.split(0.8);

    let mut node = match backend.as_str() {
        "native" => TrainingNode::native(w.shape.clone()),
        "pjrt" => {
            let man = Manifest::load_default()?;
            let rt = Runtime::cpu()?;
            TrainingNode::pjrt(w.shape.clone(), rt.load_train(&man, w.name)?)
        }
        other => anyhow::bail!("unknown backend {other} (pjrt|native)"),
    };
    node.epochs = epochs;
    let t0 = std::time::Instant::now();
    let model = node.retrain(&train)?;
    let dt = t0.elapsed();
    let acc = reference::accuracy(&model, &test.xs, &test.ys);
    let instrs = rttm::isa::instruction_count(&model);
    println!(
        "workload={} backend={} epochs={} train_n={} test_acc={:.3} includes={} ({:.2}% of {} TAs) instructions={} wall={:.2}s",
        w.name,
        backend,
        epochs,
        train.len(),
        acc,
        model.include_count(),
        100.0 * model.sparsity(),
        w.shape.total_tas(),
        instrs,
        dt.as_secs_f64(),
    );
    Ok(())
}

fn cmd_infer(opts: &Opts) -> anyhow::Result<()> {
    let w = workload(&opts.get("workload", "emg"))?;
    let n = opts.get_usize("n", 512);
    let engine_name = opts.get("engine", "base");
    let data = w.dataset(n, 9);
    let node = TrainingNode::native(w.shape.clone());
    let model = node.retrain(&data)?;

    let mut svc = InferenceService::new(fitted_engine_for(&engine_name, &model)?);
    svc.reprogram(&model)?;
    let t0 = std::time::Instant::now();
    let acc = svc.measure_accuracy(&data.xs, &data.ys)?;
    let wall = t0.elapsed();
    let f = svc.engine.freq_mhz();
    println!(
        "workload={} engine={} n={} acc={:.3} simulated_batch_us={:.2} per_dp_us={:.3} sim_throughput={:.0}/s wall={:.1}ms",
        w.name,
        engine_name,
        n,
        acc,
        svc.metrics.simulated_us(f) / svc.metrics.batches as f64,
        svc.metrics.mean_latency_us(f),
        1e6 / svc.metrics.mean_latency_us(f),
        wall.as_secs_f64() * 1e3,
    );
    Ok(())
}

fn cmd_serve(opts: &Opts) -> anyhow::Result<()> {
    if opts.has("autotune") {
        return cmd_serve_autotune(opts);
    }
    if opts.has("models") {
        return cmd_serve_multi(opts);
    }
    let w = workload(&opts.get("workload", "emg"))?;
    let requests = opts.get_usize("requests", 100);
    let replicas = opts.get_usize("replicas", 1);
    let engine_name = opts.get("engine", "base");
    // Admission front-end: per-class queue cap and the backpressure
    // policy applied to the data classes (Low/Normal); control classes
    // (High/Critical) always block rather than shed.
    let queue_cap = opts.get_usize("queue-cap", 1024);
    anyhow::ensure!(queue_cap >= 1, "--queue-cap must be >= 1");
    let shed_policy: rttm::coordinator::ShedPolicy = opts
        .get("shed-policy", "block")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    // Model-integrity layer: scrub cadence in ms (0 = off, the
    // default): fence-time digests, pre-serve verify + self-heal,
    // background scrubbing and the replica circuit breaker.
    let scrub_ms = opts.get_usize("scrub-interval", 0);
    let data = w.dataset(32 * requests, 11);
    let node = TrainingNode::native(w.shape.clone());
    let model = node.retrain(&w.dataset(1024, 7))?;

    // Replica pool: N workers, each owning one engine replica built
    // from the same spec, fed through sharded per-class queues behind
    // the admission front-end.
    let (handle, mut join) = rttm::coordinator::server::spawn_pool_cfg(
        fitted_engine_for(&engine_name, &model)?.to_spec(),
        rttm::coordinator::PoolConfig {
            replicas,
            admission: rttm::coordinator::AdmissionConfig::uniform(queue_cap, shed_policy),
            autoscale: None,
            integrity: integrity_for(scrub_ms),
        },
    );
    handle.program(model)?;
    let t0 = std::time::Instant::now();
    // One client per replica so the pool actually fans out.
    let mut clients = Vec::new();
    for c in 0..replicas.max(1) {
        let h = handle.clone();
        let chunks: Vec<Vec<Vec<u8>>> = data
            .xs
            .chunks(32)
            .enumerate()
            .filter(|(i, _)| i % replicas.max(1) == c)
            .map(|(_, chunk)| chunk.to_vec())
            .collect();
        clients.push(std::thread::spawn(move || -> anyhow::Result<u64> {
            let mut refused = 0u64;
            for chunk in chunks {
                match h.infer(chunk) {
                    Ok(_) => {}
                    // Under --shed-policy reject the front-end refuses
                    // work instead of queueing it; that is the operator's
                    // choice, not a serving failure.
                    Err(rttm::coordinator::ServeError::Overloaded) => refused += 1,
                    Err(e) => return Err(e.into()),
                }
            }
            Ok(refused)
        }));
    }
    let mut refused = 0u64;
    for c in clients {
        refused += c.join().expect("client thread")?;
    }
    let wall = t0.elapsed();
    let stats = handle.pool_stats();
    handle.shutdown();
    join.join();
    let f = engine_for(&engine_name)?.freq_mhz();
    println!(
        "served {} requests ({} inferences) engine={} replicas={} sim_us_total={:.1} wall_ms={:.1} host_rps={:.0}",
        stats.total.batches,
        stats.total.inferences,
        engine_name,
        replicas,
        stats.total.simulated_us(f),
        wall.as_secs_f64() * 1e3,
        stats.total.batches as f64 / wall.as_secs_f64(),
    );
    println!(
        "admission queue_cap={} shed_policy={} refused={} lost={} deadline_misses={}",
        queue_cap,
        shed_policy,
        refused,
        stats.admission.lost_total(),
        stats.admission.deadline_misses_total(),
    );
    print_integrity_summary(scrub_ms, &stats.integrity);
    print_model_summary(&stats.models);
    let report_json = opts.get("report-json", "");
    if !report_json.is_empty() {
        std::fs::write(&report_json, serve_report_json(&stats, handle.sharding().name()))?;
        println!("wrote serve report to {report_json}");
    }
    Ok(())
}

/// `--scrub-interval MS` → the pool's integrity layer (0 = off).
fn integrity_for(scrub_ms: usize) -> rttm::coordinator::IntegrityConfig {
    if scrub_ms > 0 {
        rttm::coordinator::IntegrityConfig::scrubbed(std::time::Duration::from_millis(
            scrub_ms as u64,
        ))
    } else {
        rttm::coordinator::IntegrityConfig::default()
    }
}

fn print_integrity_summary(scrub_ms: usize, integ: &rttm::coordinator::IntegrityStats) {
    if scrub_ms == 0 {
        return;
    }
    println!(
        "integrity scrub_interval_ms={} scrubs={} corruptions={} heals={} failed_heals={} \
         quarantines={} rejoins={}",
        scrub_ms,
        integ.scrubs,
        integ.corruptions_detected,
        integ.heals,
        integ.failed_heals,
        integ.quarantines,
        integ.rejoins,
    );
}

/// `rttm serve --models a.rttm,b.rttm`: the multi-tenant platform path.
/// Every file is registered on ONE replica pool under the chosen
/// sharding policy and driven with interleaved per-model traffic; the
/// summary reports requests/sheds/deadline-misses per model.
fn cmd_serve_multi(opts: &Opts) -> anyhow::Result<()> {
    use rttm::coordinator::server::ShardingPolicy;

    anyhow::ensure!(
        !opts.has("engine") && !opts.has("workload"),
        "--models serves the listed .rttm files on fitted base-config replicas; \
         --engine/--workload apply to single-model serve"
    );
    let list = opts.get("models", "");
    let paths: Vec<&str> = list.split(',').filter(|p| !p.is_empty()).collect();
    anyhow::ensure!(!paths.is_empty(), "--models needs a comma-separated list of .rttm files");
    let sharding: ShardingPolicy = opts
        .get("sharding", "time-shared")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let requests = opts.get_usize("requests", 100);
    let replicas = opts.get_usize("replicas", paths.len().max(2));
    let queue_cap = opts.get_usize("queue-cap", 1024);
    anyhow::ensure!(queue_cap >= 1, "--queue-cap must be >= 1");
    let shed_policy: rttm::coordinator::ShedPolicy = opts
        .get("shed-policy", "block")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let scrub_ms = opts.get_usize("scrub-interval", 0);

    // Load every model up front: the engine spec must fit the largest
    // stream and the widest feature row across ALL tenants.
    let mut tenants: Vec<(String, rttm::TMModel)> = Vec::new();
    for p in &paths {
        let (model, tag) = rttm::tm::serialize::load_model(p)?;
        let name = tag.map(|t| t.name).unwrap_or_else(|| {
            std::path::Path::new(p)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| p.to_string())
        });
        tenants.push((name, model));
    }
    let need = tenants
        .iter()
        .map(|(_, m)| rttm::isa::instruction_count(m))
        .max()
        .unwrap_or(0)
        .next_power_of_two()
        .max(8192);
    let feats = tenants
        .iter()
        .map(|(_, m)| m.shape.features)
        .max()
        .unwrap_or(0)
        .next_power_of_two()
        .max(2048);
    let spec = Engine::custom(AccelConfig::base().with_depths(need, feats)).to_spec();

    let (handle, mut join) = rttm::coordinator::server::spawn_pool_sharded(
        spec,
        rttm::coordinator::PoolConfig {
            replicas,
            admission: rttm::coordinator::AdmissionConfig::uniform(queue_cap, shed_policy),
            autoscale: None,
            integrity: integrity_for(scrub_ms),
        },
        sharding,
    );
    // Register every tenant, then drive interleaved traffic: one client
    // per model, all concurrent, fresh rows from the model's own
    // workload generator.
    let per_model = (requests / tenants.len()).max(1);
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for (name, model) in tenants {
        let w = workload(&model.shape.name).map_err(|_| {
            anyhow::anyhow!(
                "model '{name}' was trained on unknown workload {:?}; \
                 cannot generate traffic for it",
                model.shape.name
            )
        })?;
        let rows = w.dataset(32 * per_model, 11).xs;
        let outcome = handle.register_model_outcome(&name, std::sync::Arc::new(model))?;
        if outcome.deduped {
            // (name, hash) dedup: this is a TRUE duplicate — the same
            // tenant listed twice with identical bytes — not two
            // tenants sharing bytes (those get distinct ids).
            eprintln!(
                "warning: model '{name}' duplicates already-registered '{}' ({}); \
                 serving the existing registration",
                outcome.name, outcome.id
            );
        }
        let h = handle.with_model(outcome.id);
        clients.push(std::thread::spawn(move || -> anyhow::Result<u64> {
            let mut refused = 0u64;
            for chunk in rows.chunks(32) {
                match h.infer(chunk.to_vec()) {
                    Ok(_) => {}
                    Err(rttm::coordinator::ServeError::Overloaded) => refused += 1,
                    Err(e) => return Err(e.into()),
                }
            }
            Ok(refused)
        }));
    }
    let mut refused = 0u64;
    for c in clients {
        refused += c.join().expect("client thread")?;
    }
    let wall = t0.elapsed();
    let stats = handle.pool_stats();
    handle.shutdown();
    join.join();
    println!(
        "served {} requests ({} inferences) models={} sharding={} replicas={} \
         wall_ms={:.1} host_rps={:.0} switches={}",
        stats.total.batches,
        stats.total.inferences,
        stats.models.len(),
        sharding,
        replicas,
        wall.as_secs_f64() * 1e3,
        stats.total.batches as f64 / wall.as_secs_f64(),
        stats.sharding_switches,
    );
    println!(
        "admission queue_cap={} shed_policy={} refused={} lost={} deadline_misses={}",
        queue_cap,
        shed_policy,
        refused,
        stats.admission.lost_total(),
        stats.admission.deadline_misses_total(),
    );
    print_integrity_summary(scrub_ms, &stats.integrity);
    print_model_summary(&stats.models);
    let report_json = opts.get("report-json", "");
    if !report_json.is_empty() {
        std::fs::write(&report_json, serve_report_json(&stats, sharding.name()))?;
        println!("wrote serve report to {report_json}");
    }
    Ok(())
}

/// One summary line per registered model: the per-tenant view of the
/// pool (requests / sheds / deadline misses per ModelId).
fn print_model_summary(models: &[rttm::coordinator::ModelStats]) {
    for m in models {
        let served: u64 = m.classes.iter().map(|c| c.served).sum();
        let shed: u64 = m.classes.iter().map(|c| c.shed).sum();
        let misses: u64 = m.classes.iter().map(|c| c.deadline_misses).sum();
        println!(
            "model {} name={} requests={} served={} shed={} rejected={} \
             deadline_misses={} switches={}",
            m.id,
            m.name,
            m.submitted(),
            served,
            shed,
            m.rejected(),
            misses,
            m.switches,
        );
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// The per-model rollups as a JSON array (shared by the plain-serve
/// report and the autotune report's `models` field).
fn models_json(models: &[rttm::coordinator::ModelStats]) -> String {
    let items: Vec<String> = models
        .iter()
        .map(|m| {
            let served: u64 = m.classes.iter().map(|c| c.served).sum();
            let shed: u64 = m.classes.iter().map(|c| c.shed).sum();
            let misses: u64 = m.classes.iter().map(|c| c.deadline_misses).sum();
            format!(
                "{{\"id\": \"{}\", \"name\": \"{}\", \"submitted\": {}, \"admitted\": {}, \
                 \"rejected\": {}, \"served\": {}, \"shed\": {}, \"deadline_misses\": {}, \
                 \"switches\": {}}}",
                m.id,
                json_escape(&m.name),
                m.submitted(),
                m.admitted(),
                m.rejected(),
                served,
                shed,
                misses,
                m.switches,
            )
        })
        .collect();
    format!("[{}]", items.join(", "))
}

/// The plain-serve `--report-json` document: pool rollup plus the
/// per-model array.
fn serve_report_json(stats: &rttm::coordinator::PoolStats, sharding: &str) -> String {
    format!(
        "{{\n  \"requests\": {},\n  \"inferences\": {},\n  \"replicas\": {},\n  \
         \"version\": {},\n  \"sharding\": \"{}\",\n  \"sharding_switches\": {},\n  \
         \"models\": {}\n}}\n",
        stats.total.batches,
        stats.total.inferences,
        stats.replicas.len(),
        stats.version,
        sharding,
        stats.sharding_switches,
        models_json(&stats.models),
    )
}

/// `rttm serve --autotune`: the Fig 8 deployment at serving scale — a
/// replica pool fed a drifting window stream while the live autotuner
/// monitors, shadow-retrains under a resource budget, and hot-swaps.
fn cmd_serve_autotune(opts: &Opts) -> anyhow::Result<()> {
    use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner};
    use rttm::datasets::workloads::DriftSchedule;
    use rttm::model_cost::resources::ResourceBudget;

    let w = workload(&opts.get("workload", "emg"))?;
    // Flags from plain `serve` that do not apply here must error, not
    // be silently dropped.
    if opts.has("engine") || opts.has("requests") {
        anyhow::bail!(
            "--autotune serves a drift-schedule stream on fitted base-config replicas; \
             --engine/--requests do not apply (use --replicas/--windows/--window-n/--drift)"
        );
    }
    if opts.has("queue-cap") || opts.has("shed-policy") {
        anyhow::bail!(
            "--autotune drives its own control-class traffic through default (block) \
             admission; --queue-cap/--shed-policy apply to plain `serve` only"
        );
    }
    let replicas = opts.get_usize("replicas", 2).max(1);
    let windows = opts.get_usize("windows", 8);
    let window_n = opts.get_usize("window-n", 256);
    let drift = opts.get_f64("drift", 0.35);
    let threshold = opts.get_f64("threshold", 0.85);
    // Canary gate: fraction of each window mirrored to the staged
    // candidate; 0 disables the gate (direct fence swap).
    let canary_fraction = opts.get_f64("canary-fraction", 0.25);
    anyhow::ensure!(
        (0.0..=1.0).contains(&canary_fraction),
        "--canary-fraction must be in [0, 1]"
    );
    // Fully label-free deployment: windows are observed unlabeled
    // (margin-only drift detection, canary judged on margins), with
    // labels backfilled `--label-delay` windows late.
    let label_free = opts.has("label-free");
    let label_delay = opts.get_usize("label-delay", 2).max(1);
    // Online feedback: labeled (or backfilled) windows fine-tune the
    // serving model through the pool's feedback path first; the full
    // shape search only runs if the detector stays bad for
    // `--online-patience` feedback windows.
    let online_feedback = opts.has("online-feedback");
    let online_patience = opts.get_usize("online-patience", 3).max(1);
    let report_json = opts.get("report-json", "");

    // --budget "<luts>,<brams>,<watts>" or per-axis flags; unset axes
    // stay unconstrained.
    let mut budget = ResourceBudget::unlimited();
    let packed = opts.get("budget", "");
    if !packed.is_empty() {
        let parts: Vec<&str> = packed.split(',').collect();
        anyhow::ensure!(parts.len() == 3, "--budget expects <luts>,<brams>,<watts>");
        budget = budget
            .with_luts(parts[0].trim().parse()?)
            .with_brams(parts[1].trim().parse()?)
            .with_watts(parts[2].trim().parse()?);
    }
    // Per-axis flags parse STRICTLY: a typo or bare flag must error,
    // never silently install an unlimited frontier.
    if opts.has("budget-luts") {
        budget = budget.with_luts(opts.get("budget-luts", "").parse()?);
    }
    if opts.has("budget-brams") {
        budget = budget.with_brams(opts.get("budget-brams", "").parse()?);
    }
    if opts.has("budget-watts") {
        budget = budget.with_watts(opts.get("budget-watts", "").parse()?);
    }

    let sched = match opts.get("schedule", "abrupt").as_str() {
        "abrupt" => DriftSchedule::abrupt(windows, window_n, windows / 2, drift),
        "gradual" => DriftSchedule::gradual(windows, window_n, windows / 4, 3 * windows / 4, drift),
        "recurring" => DriftSchedule::recurring(windows, window_n, (windows / 4).max(1), drift),
        other => anyhow::bail!("unknown schedule {other} (abrupt|gradual|recurring)"),
    };

    let node = TrainingNode::native(w.shape.clone());
    // Train on fresh draws PAST the monitored stream (same prototype
    // universe): the windows below measure generalization, not
    // memorized training samples.
    let model = node.retrain(&sched.training_set(&w, 1024))?;
    // 2x instruction-memory headroom over the first model: retrained
    // candidates may carry more includes, and the whole point is
    // swapping them in without resynthesis.
    let spec = rttm::coordinator::EngineSpec::custom(
        rttm::model_cost::resources::provisioned_config(&model, 2),
    );
    let (handle, mut join) = rttm::coordinator::server::spawn_pool(spec, replicas);

    let mut cfg = AutotuneConfig::new(budget);
    cfg.accuracy_floor = threshold;
    cfg.canary_fraction = canary_fraction;
    // The pending-window horizon must outlast the label delay, or every
    // window would age out right before its labels arrive and no
    // backfill would ever land.
    cfg.label_backfill_horizon = cfg.label_backfill_horizon.max(label_delay + 1);
    cfg.online_feedback = online_feedback;
    cfg.online_patience = online_patience;
    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), cfg);
    tuner.install(model)?;

    println!(
        "autotuned serving: workload={} replicas={replicas} schedule={:?} threshold={threshold} \
         canary_fraction={canary_fraction}{}{}",
        w.name,
        sched.kind,
        if label_free {
            format!(" label_free=true label_delay={label_delay}")
        } else {
            String::new()
        },
        if online_feedback {
            format!(" online_feedback=true online_patience={online_patience}")
        } else {
            String::new()
        }
    );
    let stream = sched.stream(&w);
    for (step, win) in stream.iter().enumerate() {
        let stats = if label_free {
            // Margin-only monitoring; the window's labels arrive
            // `label_delay` windows late and backfill the report (and
            // the retrain corpus) without re-triggering.
            let stats = tuner.observe_unlabeled(&win.xs)?;
            if step >= label_delay {
                tuner.backfill_labels(step - label_delay, &stream[step - label_delay].ys)?;
            }
            stats
        } else {
            tuner.observe_window(&win.xs, &win.ys)?
        };
        println!(
            "window {step:>3}  drift={:.2}  acc={}  margin={:>7.2}  version={}  [{}]",
            sched.drift_at(step),
            stats
                .accuracy
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "  -  ".into()),
            stats.mean_margin,
            stats.model_version,
            tuner.phase_name(),
        );
        if tuner.is_searching() {
            tuner.finish_pending_search()?;
        }
    }
    if label_free {
        // Drain the tail: the last `label_delay` windows' labels arrive
        // after the stream ends, but they are known here — backfill them
        // so the report (and its JSON) is complete.
        for step in windows.saturating_sub(label_delay)..windows {
            tuner.backfill_labels(step, &stream[step].ys)?;
        }
    }
    for e in &tuner.report.events {
        match e {
            AutotuneEvent::Swapped { window, version, instructions, luts, brams, watts, .. } => {
                println!(
                    "SWAPPED at window {window}: v{version}, {instructions} instructions, \
                     {luts} LUTs / {brams} BRAMs / {watts:.3} W (within budget, no resynthesis)"
                )
            }
            other => println!("{other:?}"),
        }
    }
    for c in &tuner.report.canaries {
        println!(
            "canary: staged at window {}, {} at window {} after {} paired windows",
            c.started_window,
            c.verdict.as_str(),
            c.resolved_window,
            c.windows.len()
        );
    }
    let stats = handle.pool_stats();
    println!(
        "served {} inferences across {} replicas, {} reprograms, 0 downtime",
        stats.total.inferences,
        stats.replicas.len(),
        stats.version
    );
    if let Some(rows) = handle.online_rows_fed() {
        println!("online feedback: {rows} labeled rows folded into the serving model");
    }
    if !report_json.is_empty() {
        // Splice the per-model rollups into the tuner's own report so one
        // JSON file carries both the tuning timeline and the tenant view.
        let mut json = tuner.report.to_json();
        let tail = json.rfind('}').expect("autotune report is a JSON object");
        json.truncate(tail);
        json.truncate(json.trim_end().len());
        json.push_str(&format!(",\n  \"models\": {}\n}}\n", models_json(&stats.models)));
        std::fs::write(&report_json, json)?;
        println!("wrote autotune report to {report_json}");
    }
    handle.shutdown();
    join.join();
    Ok(())
}

fn cmd_retune(opts: &Opts) -> anyhow::Result<()> {
    let w = workload(&opts.get("workload", "emg"))?;
    let drift = opts.get_f64("drift", 0.35);
    let threshold = opts.get_f64("threshold", 0.75);
    let clean = w.dataset(768, 7);
    let drifted = w.drifted_dataset(768, 7, drift);

    let node = TrainingNode::native(w.shape.clone());
    let first = node.retrain(&clean)?;
    let mut svc = InferenceService::new(fitted_engine_for("base", &first)?);
    svc.reprogram(&first)?;

    let looped = RecalibrationLoop::new(node, threshold);
    let windows = vec![(clean.clone(), clean.clone()), (drifted.clone(), drifted.clone())];
    let report = looped.run(&mut svc, &windows)?;
    for (step, acc) in &report.probes {
        println!("probe step={step} acc={acc:.3}");
    }
    for ev in &report.recalibrations {
        println!(
            "RECALIBRATED at step {}: {:.3} -> {:.3} (new model: {} instructions, no resynthesis)",
            ev.step, ev.accuracy_before, ev.accuracy_after, ev.instruction_count
        );
    }
    Ok(())
}

fn cmd_save(opts: &Opts) -> anyhow::Result<()> {
    let w = workload(&opts.get("workload", "emg"))?;
    let out = opts.get("out", "model.rttm");
    let node = TrainingNode::native(w.shape.clone());
    let model = node.retrain(&w.dataset(opts.get_usize("n", 1024), 7))?;
    rttm::tm::serialize::save(&model, &out)?;
    println!(
        "saved {} ({} instructions, {} bytes)",
        out,
        rttm::isa::instruction_count(&model),
        std::fs::metadata(&out)?.len()
    );
    Ok(())
}

fn cmd_load(opts: &Opts) -> anyhow::Result<()> {
    let path = opts.get("model", "model.rttm");
    let (shape, instrs) = rttm::tm::serialize::load(&path)?;
    println!(
        "loaded {}: workload={} features={} classes={} clauses={} instructions={}",
        path, shape.name, shape.features, shape.classes, shape.clauses, instrs.len()
    );
    // Program a fitted accelerator straight from the stream and classify
    // fresh data with the matching generator if the workload is known.
    if let Ok(w) = workload(&shape.name) {
        let n = opts.get_usize("n", 256);
        let need = instrs.len().next_power_of_two().max(8192);
        let mut core = rttm::accel::Core::new(AccelConfig::base().with_depths(need, 2048));
        core.program(shape.classes, shape.clauses, &instrs)?;
        // Fresh samples from the SAME generator universe the model was
        // trained in (seed fixes the class prototypes): draw past the
        // training prefix.
        let all = w.dataset(1024 + n, 7);
        let (_, data) = all.split(1024.0 / (1024 + n) as f64);
        let mut correct = 0usize;
        for (chunk_x, chunk_y) in data.xs.chunks(32).zip(data.ys.chunks(32)) {
            let preds = core.run_rows(&chunk_x.to_vec())?;
            correct += preds.iter().zip(chunk_y).filter(|(p, y)| p == y).count();
        }
        println!("accuracy on fresh {} data: {:.3}", w.name, correct as f64 / n as f64);
    }
    Ok(())
}

fn cmd_tune_hyper(opts: &Opts) -> anyhow::Result<()> {
    use rttm::coordinator::hyperparam::{grid_search, SearchSpace};
    let w = workload(&opts.get("workload", "emg"))?;
    let data = w.dataset(opts.get_usize("n", 1024), 7);
    let (train, valid) = data.split(0.75);
    let space = SearchSpace::around(&w.shape);
    let t0 = std::time::Instant::now();
    let (trials, best) = grid_search(&w.shape, &train, &valid, &space);
    println!(
        "{:>5} {:>7} {:>9} {:>9} {:>13} {:>9}",
        "T", "s", "clauses", "acc", "instructions", "score"
    );
    for t in trials.iter().take(8) {
        println!(
            "{:>5} {:>7.2} {:>9} {:>9.3} {:>13} {:>9.3}",
            t.t, t.s, t.clauses, t.accuracy, t.instructions, t.score
        );
    }
    println!(
        "winner: {} instructions, acc {:.3} ({} trials in {:.1}s — TM search space is tiny, paper §3)",
        rttm::isa::instruction_count(&best),
        rttm::tm::reference::accuracy(&best, &valid.xs, &valid.ys),
        trials.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_report(opts: &Opts) -> anyhow::Result<()> {
    let w = workload(&opts.get("workload", "emg"))?;
    let data = w.dataset(1024, 7);
    let node = TrainingNode::native(w.shape.clone());
    let model = node.retrain(&data)?;

    println!("== {} ==", w.name);
    println!(
        "model: {} includes / {} TAs ({:.2}%), {} instructions",
        model.include_count(),
        w.shape.total_tas(),
        100.0 * model.sparsity(),
        rttm::isa::instruction_count(&model)
    );

    println!(
        "\n{:<16} {:>7} {:>7} {:>6} {:>9} {:>12} {:>12}",
        "config", "LUTs", "FFs", "BRAMs", "freq_MHz", "batch_us", "uJ/batch"
    );
    for (label, cfg, cores) in [
        ("Base (B)", AccelConfig::base(), 1usize),
        ("Single Core (S)", AccelConfig::single_core(), 1),
        ("5-Core (M)", AccelConfig::multicore_core(), 5),
    ] {
        let res = if cores == 1 { estimate(&cfg) } else { estimate_multicore(&cfg, cores) };
        let em = if cores == 1 {
            EnergyModel::for_config(&cfg)
        } else {
            EnergyModel::for_multicore(&cfg, cores)
        };
        let engine_name = if cores > 1 { "multi" } else if cfg.name == "base" { "base" } else { "single" };
        let mut svc = InferenceService::new(fitted_engine_for(engine_name, &model)?);
        svc.reprogram(&model)?;
        svc.infer(&data.xs[..32.min(data.len())])?;
        let us = svc.metrics.simulated_us(cfg.freq_mhz);
        println!(
            "{:<16} {:>7} {:>7} {:>6} {:>9.1} {:>12.2} {:>12.3}",
            label, res.luts, res.ffs, res.brams, res.freq_mhz, us, em.energy_uj(us)
        );
    }

    let mtdr = Matador::synthesize(&model);
    println!(
        "{:<16} {:>7} {:>7} {:>6} {:>9.1} {:>12.2} {:>12.3}  (single dp, no batch)",
        "MATADOR",
        mtdr.luts(),
        mtdr.ffs(),
        mtdr.brams(),
        mtdr.freq_mhz,
        mtdr.single_latency_us(),
        mtdr.single_energy_uj()
    );
    let esp = Mcu::program_model(McuKind::Esp32, &model);
    println!(
        "{:<16} {:>7} {:>7} {:>6} {:>9.1} {:>12.2} {:>12.3}  (software, batch=32x single)",
        "ESP32",
        0,
        0,
        0,
        esp.kind.freq_mhz(),
        esp.batch_latency_us(32),
        esp.batch_energy_uj(32)
    );
    Ok(())
}
