//! Pure-rust vanilla TM trainer — the Model Training Node's algorithm.
//!
//! Functionally identical feedback rules to `python/compile/train.py`
//! (Type I with boost-true-positive, Type II, per-clause gating by
//! (T -/+ clamp(sum))/2T), with its own PRNG stream.  The coordinator
//! normally trains through the AOT JAX artifact (`runtime::TrainStep`);
//! this trainer exists to (a) cross-check the JAX semantics statistically
//! and (b) keep the simulator benches self-contained and fast.

use crate::config::TMShape;
use crate::datasets::synth::{Dataset, XorShift64Star};
use crate::tm::model::TMModel;
use crate::tm::reference;

pub mod online;

/// TA-state trainer over a dense state vector `[class][clause][literal]`.
pub struct Trainer {
    pub shape: TMShape,
    pub states: Vec<i32>,
    rng: XorShift64Star,
}

impl Trainer {
    pub fn new(shape: TMShape, seed: u64) -> Self {
        let mut rng = XorShift64Star::new(seed);
        let n = shape.n_states;
        let total = shape.total_tas();
        // Start just below the Include boundary (N-1 or N-2), like the
        // JAX init.
        let states = (0..total)
            .map(|_| n - 1 - i64::from(rng.next_f64() < 0.5) as i32)
            .collect();
        Trainer { shape, states, rng }
    }

    #[inline]
    fn idx(&self, class: usize, clause: usize, lit: usize) -> usize {
        (class * self.shape.clauses + clause) * self.shape.literals() + lit
    }

    #[inline]
    fn include(&self, class: usize, clause: usize, lit: usize) -> bool {
        self.states[self.idx(class, clause, lit)] >= self.shape.n_states
    }

    /// Training-semantics clause output (empty clause -> 1).
    fn clause_output_train(&self, class: usize, clause: usize, lits: &[u8]) -> bool {
        for lit in 0..self.shape.literals() {
            if self.include(class, clause, lit) && lits[lit] == 0 {
                return false;
            }
        }
        true
    }

    fn class_sum_train(&self, class: usize, lits: &[u8]) -> i32 {
        (0..self.shape.clauses)
            .map(|c| {
                if self.clause_output_train(class, c, lits) {
                    TMModel::polarity(c)
                } else {
                    0
                }
            })
            .sum()
    }

    /// Feedback to one class slice; `sign` +1 for the target class, -1
    /// for the sampled negative class.
    fn class_feedback(&mut self, class: usize, lits: &[u8], sign: i32) {
        let t = self.shape.t;
        let votes = self.class_sum_train(class, lits).clamp(-t, t);
        let p = (t as f64 - sign as f64 * votes as f64) / (2.0 * t as f64);
        let inv_s = 1.0 / self.shape.s;
        let literals = self.shape.literals();
        for clause in 0..self.shape.clauses {
            if self.rng.next_f64() >= p {
                continue; // feedback gate
            }
            let out = self.clause_output_train(class, clause, lits);
            let pol = TMModel::polarity(clause);
            if pol == sign {
                // Type I: make the clause fire on this sample.
                for lit in 0..literals {
                    let i = self.idx(class, clause, lit);
                    if out && lits[lit] == 1 {
                        // boost-true-positive: deterministic reward.
                        self.states[i] = (self.states[i] + 1).min(2 * self.shape.n_states - 1);
                    } else if self.rng.next_f64() < inv_s {
                        self.states[i] = (self.states[i] - 1).max(0);
                    }
                }
            } else if out {
                // Type II: include a contradicting literal to kill the
                // false positive.
                for lit in 0..literals {
                    if lits[lit] == 0 {
                        let i = self.idx(class, clause, lit);
                        if self.states[i] < self.shape.n_states {
                            self.states[i] += 1;
                        }
                    }
                }
            }
        }
    }

    /// One sample of vanilla TM feedback.
    pub fn update(&mut self, features: &[u8], y: usize) {
        let lits = reference::literals_from_features(features);
        self.class_feedback(y, &lits, 1);
        if self.shape.classes > 1 {
            let neg = (y + 1 + self.rng.below(self.shape.classes as u64 - 1) as usize)
                % self.shape.classes;
            self.class_feedback(neg, &lits, -1);
        }
    }

    /// Train for `epochs` passes over the dataset, visiting the samples
    /// in a fresh order each epoch.  The shuffle is Fisher–Yates off the
    /// trainer's OWN PRNG stream, so the epoch orders are part of the
    /// seeded training trajectory: same seed, same orders, same model —
    /// but no two epochs replay the identical sample sequence (identical
    /// order every epoch is a sample-order bias that compounds once the
    /// same `update` path runs online).
    pub fn fit(&mut self, data: &Dataset, epochs: usize) {
        let mut order: Vec<usize> = (0..data.len()).collect();
        for _ in 0..epochs {
            for i in (1..order.len()).rev() {
                // i >= 1, so the draw range is never empty.
                let j = self.rng.below(i as u64 + 1) as usize;
                order.swap(i, j);
            }
            for &k in &order {
                self.update(&data.xs[k], data.ys[k]);
            }
        }
    }

    /// Train visiting the samples in raw dataset order every epoch —
    /// the exact per-sample stream [`online::OnlineTrainer`] replays,
    /// and what the bit-identical parity tests compare against.
    pub fn fit_ordered(&mut self, data: &Dataset, epochs: usize) {
        for _ in 0..epochs {
            for (x, &y) in data.xs.iter().zip(&data.ys) {
                self.update(x, y);
            }
        }
    }

    /// Snapshot the include actions as a dense model.
    pub fn model(&self) -> TMModel {
        TMModel::from_ta_states(self.shape.clone(), &self.states)
    }
}

/// Convenience: train a model on a dataset (used by benches/examples).
pub fn train_model(shape: &TMShape, data: &Dataset, epochs: usize, seed: u64) -> TMModel {
    let mut tr = Trainer::new(shape.clone(), seed);
    tr.fit(data, epochs);
    tr.model()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;

    fn quick_shape() -> TMShape {
        TMShape {
            name: "quickstart".into(),
            features: 16,
            classes: 2,
            clauses: 10,
            t: 4,
            s: 3.0,
            train_batch: 32,
            n_states: 128,
        }
    }

    #[test]
    fn learns_separable_data() {
        let shape = quick_shape();
        let data = SynthSpec::new(16, 2, 512).noise(0.05).seed(7).generate();
        let model = train_model(&shape, &data, 8, 3);
        let acc = reference::accuracy(&model, &data.xs, &data.ys);
        assert!(acc > 0.9, "rust trainer failed to learn: acc={acc}");
    }

    #[test]
    fn states_stay_bounded() {
        let shape = quick_shape();
        let data = SynthSpec::new(16, 2, 128).generate();
        let mut tr = Trainer::new(shape.clone(), 1);
        tr.fit(&data, 2);
        assert!(tr.states.iter().all(|&s| (0..2 * shape.n_states).contains(&s)));
    }

    #[test]
    fn trained_model_is_sparse() {
        // The compression premise: includes are a minority of TAs.
        let shape = TMShape {
            name: "emg".into(),
            features: 64,
            classes: 6,
            clauses: 100,
            t: 20,
            s: 3.0,
            train_batch: 32,
            n_states: 128,
        };
        let data = SynthSpec::new(64, 6, 256).noise(0.06).seed(2).generate();
        let model = train_model(&shape, &data, 3, 5);
        assert!(model.sparsity() < 0.35, "sparsity {}", model.sparsity());
    }

    // Re-pinned over the per-epoch shuffle: the epoch orders are drawn
    // from the trainer's own seeded stream, so same-seed runs replay
    // the identical trajectory, shuffle included.
    #[test]
    fn deterministic_given_seed() {
        let shape = quick_shape();
        let data = SynthSpec::new(16, 2, 64).seed(4).generate();
        let a = train_model(&shape, &data, 2, 9);
        let b = train_model(&shape, &data, 2, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn fit_shuffles_while_fit_ordered_replays_raw_order() {
        let shape = quick_shape();
        let data = SynthSpec::new(16, 2, 64).seed(4).generate();
        let mut shuffled = Trainer::new(shape.clone(), 9);
        shuffled.fit(&data, 3);
        let mut ordered = Trainer::new(shape, 9);
        ordered.fit_ordered(&data, 3);
        // The shuffle consumes PRNG draws and reorders every epoch, so
        // the two trajectories must diverge at the TA-state level.
        assert_ne!(
            shuffled.states, ordered.states,
            "fit must not walk the dataset in raw order every epoch"
        );
    }

    #[test]
    fn statistical_parity_with_jax_trainer() {
        // Cross-language invariant (DESIGN.md §6): both trainers reach
        // >90% on the same quickstart-shaped task.  The JAX side of this
        // pairing is python/tests/test_train.py::test_learns_separable_data.
        let shape = quick_shape();
        let data = SynthSpec::new(16, 2, 512).noise(0.10).seed(7).generate();
        let model = train_model(&shape, &data, 8, 3);
        let acc = reference::accuracy(&model, &data.xs, &data.ys);
        assert!(acc > 0.9, "acc={acc}");
    }
}
