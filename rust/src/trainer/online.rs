//! Incremental TA feedback on the live path — the paper's on-field
//! recalibration story, minus the resynthesis.
//!
//! [`OnlineTrainer::feedback_batch`] applies the exact Type I / Type II
//! feedback of [`super::Trainer`] to a labeled sample window, but
//! evaluates clause outputs through the same transposed literal planes
//! the bit-sliced inference kernel walks ([`isa::SlicedBatch`]): one
//! `u64` word per (class, clause) holds the clause's output across 64
//! rows at once.  TA-state updates stay scalar (they are inherently
//! per-sample, per-literal), but the clause walk — the part that is
//! O(clauses x literals) per sample in the scalar trainer — amortizes
//! to one AND-fold per include-set change per 64-row block.
//!
//! ## Bit-identical semantics
//!
//! The kernel is NOT an approximation: fed the same sample stream as
//! [`super::Trainer::fit_ordered`] from the same seed, it produces
//! bit-identical TA states (pinned by the parity tests below).  Two
//! properties make that possible:
//!
//! * clause output depends only on the clause's own *include set* —
//!   feedback to other clauses can never invalidate it, so a cached
//!   64-row output word stays valid until one of the clause's own TA
//!   states crosses the include boundary (tracked by a dirty flag and
//!   recomputed lazily);
//! * every PRNG draw of the scalar trainer is replayed in the same
//!   order: per-clause gate draw, per-literal 1/s penalty draws (only
//!   where the scalar path draws), and the negative-class draw between
//!   the two class-feedback passes.

use crate::config::TMShape;
use crate::datasets::synth::XorShift64Star;
use crate::isa::{self, SlicedBatch, SLICE_LANES};
use crate::tm::model::TMModel;

/// A malformed feedback window.  Validation runs BEFORE any state is
/// touched: a rejected batch leaves the trainer exactly as it was.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum FeedbackError {
    #[error("feedback batch has {xs} rows but {ys} labels")]
    LengthMismatch { xs: usize, ys: usize },
    #[error("feedback row {row} has {got} features; the model expects {want}")]
    WidthMismatch { row: usize, got: usize, want: usize },
    #[error("feedback row {row} labeled {label}, but the model has {classes} classes")]
    BadLabel { row: usize, label: usize, classes: usize },
}

/// Incremental trainer state: the dense TA vector plus the sliced
/// clause-output cache for the block currently being fed.
pub struct OnlineTrainer {
    pub shape: TMShape,
    /// `[class][clause][literal]`, identical layout and boundary
    /// semantics to [`super::Trainer::states`].
    pub states: Vec<i32>,
    rng: XorShift64Star,
    /// Transposed feature planes of the current block (reused buffer).
    batch: SlicedBatch,
    /// 64-row clause-output words, `[class * clauses + clause]`, valid
    /// for the slice currently being walked where `!dirty`.
    masks: Vec<u64>,
    dirty: Vec<bool>,
    rows_fed: u64,
}

impl OnlineTrainer {
    /// Fresh trainer with the same seeded init as
    /// [`super::Trainer::new`] — draw-for-draw identical, so the two
    /// start from bit-identical states.
    pub fn new(shape: TMShape, seed: u64) -> Self {
        let mut rng = XorShift64Star::new(seed);
        let n = shape.n_states;
        let states: Vec<i32> = (0..shape.total_tas())
            .map(|_| n - 1 - i64::from(rng.next_f64() < 0.5) as i32)
            .collect();
        Self::assemble(shape, states, rng)
    }

    /// Warm-start from a deployed model's include set: included TAs sit
    /// just above the boundary (`n_states`), excluded just below
    /// (`n_states - 1`), so early feedback can still flip either way.
    pub fn from_model(model: &TMModel, seed: u64) -> Self {
        let mut s = Self::assemble(model.shape.clone(), Vec::new(), XorShift64Star::new(seed));
        s.reseed_from_model(model);
        s
    }

    fn assemble(shape: TMShape, states: Vec<i32>, rng: XorShift64Star) -> Self {
        let total_clauses = shape.total_clauses();
        OnlineTrainer {
            shape,
            states,
            rng,
            batch: SlicedBatch::default(),
            masks: vec![0; total_clauses],
            dirty: vec![true; total_clauses],
            rows_fed: 0,
        }
    }

    /// Re-warm-start from `model`, keeping the PRNG stream.  Called by
    /// the serving layer whenever an *offline* retrain or canary
    /// promote installs a model this trainer did not produce — its TA
    /// memory is stale for the new include set.  Handles shape changes
    /// (a `budget_search` winner may differ in clauses/t/s/n_states).
    pub fn reseed_from_model(&mut self, model: &TMModel) {
        self.shape = model.shape.clone();
        let n = self.shape.n_states;
        let lits = self.shape.literals();
        self.states.clear();
        self.states.reserve(self.shape.total_tas());
        for class in 0..self.shape.classes {
            for clause in 0..self.shape.clauses {
                for lit in 0..lits {
                    self.states
                        .push(if model.include(class, clause, lit) { n } else { n - 1 });
                }
            }
        }
        self.masks = vec![0; self.shape.total_clauses()];
        self.dirty = vec![true; self.shape.total_clauses()];
    }

    /// Total labeled rows applied over this trainer's lifetime.
    pub fn rows_fed(&self) -> u64 {
        self.rows_fed
    }

    /// Snapshot the include actions as a dense model (same boundary as
    /// [`super::Trainer::model`]).
    pub fn model(&self) -> TMModel {
        TMModel::from_ta_states(self.shape.clone(), &self.states)
    }

    /// Apply one labeled feedback window.  Samples are processed in
    /// order, one full `update` (positive + sampled-negative feedback)
    /// each — the exact stream [`super::Trainer::fit_ordered`] walks.
    /// Returns the number of rows applied.
    pub fn feedback_batch(&mut self, xs: &[Vec<u8>], ys: &[usize]) -> Result<usize, FeedbackError> {
        if xs.len() != ys.len() {
            return Err(FeedbackError::LengthMismatch { xs: xs.len(), ys: ys.len() });
        }
        if xs.is_empty() {
            return Ok(0);
        }
        let want = self.shape.features;
        for (row, (x, &y)) in xs.iter().zip(ys).enumerate() {
            if x.len() != want {
                return Err(FeedbackError::WidthMismatch { row, got: x.len(), want });
            }
            if y >= self.shape.classes {
                return Err(FeedbackError::BadLabel { row, label: y, classes: self.shape.classes });
            }
        }
        isa::pack_literals_sliced_into(xs, &mut self.batch);
        for slice in 0..self.batch.slices {
            // New 64-row block: every cached clause-output word frames
            // the previous block's rows.
            self.dirty.iter_mut().for_each(|d| *d = true);
            let lo = slice * SLICE_LANES;
            let hi = (lo + SLICE_LANES).min(xs.len());
            for r in lo..hi {
                let bit = r - lo;
                let y = ys[r];
                self.class_feedback(y, slice, bit, 1);
                if self.shape.classes > 1 {
                    let neg = (y + 1 + self.rng.below(self.shape.classes as u64 - 1) as usize)
                        % self.shape.classes;
                    self.class_feedback(neg, slice, bit, -1);
                }
            }
        }
        self.rows_fed += xs.len() as u64;
        Ok(xs.len())
    }

    #[inline]
    fn ta_base(&self, class: usize, clause: usize) -> usize {
        (class * self.shape.clauses + clause) * self.shape.literals()
    }

    /// Clause-output word for the current slice, recomputed from the
    /// include set if a boundary crossing dirtied it.  An empty include
    /// set AND-folds nothing: all 64 lanes output 1, matching the
    /// scalar trainer's empty-clause-is-true convention.
    fn ensure_mask(&mut self, class: usize, clause: usize, slice: usize) -> u64 {
        let mi = class * self.shape.clauses + clause;
        if self.dirty[mi] {
            let base = self.ta_base(class, clause);
            let n = self.shape.n_states;
            let mut m = !0u64;
            for lit in 0..self.shape.literals() {
                if self.states[base + lit] >= n {
                    m &= self.batch.literal_word(lit, slice);
                }
            }
            self.masks[mi] = m;
            self.dirty[mi] = false;
        }
        self.masks[mi]
    }

    fn class_sum(&mut self, class: usize, slice: usize, bit: usize) -> i32 {
        let mut sum = 0;
        for clause in 0..self.shape.clauses {
            if (self.ensure_mask(class, clause, slice) >> bit) & 1 == 1 {
                sum += TMModel::polarity(clause);
            }
        }
        sum
    }

    /// One class slice of feedback for the sample at (`slice`, `bit`) —
    /// the sliced twin of [`super::Trainer`]'s `class_feedback`, with
    /// the identical draw order.
    fn class_feedback(&mut self, class: usize, slice: usize, bit: usize, sign: i32) {
        let t = self.shape.t;
        let votes = self.class_sum(class, slice, bit).clamp(-t, t);
        let p = (t as f64 - sign as f64 * votes as f64) / (2.0 * t as f64);
        let inv_s = 1.0 / self.shape.s;
        let literals = self.shape.literals();
        let n = self.shape.n_states;
        for clause in 0..self.shape.clauses {
            if self.rng.next_f64() >= p {
                continue; // feedback gate (one draw per clause, always)
            }
            let out = (self.ensure_mask(class, clause, slice) >> bit) & 1 == 1;
            let pol = TMModel::polarity(clause);
            let base = self.ta_base(class, clause);
            let mut flipped = false;
            if pol == sign {
                // Type I: push the clause toward firing on this sample.
                for lit in 0..literals {
                    let i = base + lit;
                    let lv = (self.batch.literal_word(lit, slice) >> bit) & 1;
                    if out && lv == 1 {
                        // boost-true-positive: deterministic, no draw.
                        let old = self.states[i];
                        self.states[i] = (old + 1).min(2 * n - 1);
                        flipped |= old < n && self.states[i] >= n;
                    } else if self.rng.next_f64() < inv_s {
                        let old = self.states[i];
                        self.states[i] = (old - 1).max(0);
                        flipped |= old >= n && self.states[i] < n;
                    }
                }
            } else if out {
                // Type II: include a contradicting literal (no draws).
                for lit in 0..literals {
                    if (self.batch.literal_word(lit, slice) >> bit) & 1 == 0 {
                        let i = base + lit;
                        if self.states[i] < n {
                            self.states[i] += 1;
                            flipped |= self.states[i] >= n;
                        }
                    }
                }
            }
            if flipped {
                // An include-boundary crossing invalidates this
                // clause's cached output word for later rows.
                self.dirty[class * self.shape.clauses + clause] = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::tm::reference;
    use crate::tm::serialize;
    use crate::trainer::Trainer;

    fn shape2() -> TMShape {
        TMShape {
            name: "online2".into(),
            features: 16,
            classes: 2,
            clauses: 10,
            t: 4,
            s: 3.0,
            train_batch: 32,
            n_states: 128,
        }
    }

    fn shape4() -> TMShape {
        TMShape {
            name: "online4".into(),
            features: 12,
            classes: 4,
            clauses: 8,
            t: 3,
            s: 2.5,
            train_batch: 32,
            n_states: 64,
        }
    }

    // The tentpole invariant: same seed, same sample stream => the
    // sliced online kernel and the scalar offline trainer are the SAME
    // trajectory, bit for bit, regardless of how the stream is chopped
    // into feedback windows.
    #[test]
    fn parity_bit_identical_with_fit_ordered() {
        let data = SynthSpec::new(16, 2, 192).noise(0.08).seed(7).generate();
        let mut offline = Trainer::new(shape2(), 9);
        offline.fit_ordered(&data, 1);
        let mut online = OnlineTrainer::new(shape2(), 9);
        // Uneven windows straddling the 64-row slice boundary.
        for (xs, ys) in data.xs.chunks(50).zip(data.ys.chunks(50)) {
            online.feedback_batch(xs, ys).unwrap();
        }
        assert_eq!(online.states, offline.states, "TA states must be bit-identical");
        assert_eq!(
            serialize::to_bytes(&online.model()),
            serialize::to_bytes(&offline.model()),
            "serialized models must be byte-identical"
        );
        assert_eq!(online.rows_fed(), 192);
    }

    #[test]
    fn parity_holds_multiclass_and_multiple_epochs() {
        // 4 classes exercises the negative-class draw and Type II on
        // every sample; two passes = fit_ordered's epochs == 2.
        let data = SynthSpec::new(12, 4, 150).noise(0.1).seed(3).generate();
        let mut offline = Trainer::new(shape4(), 21);
        offline.fit_ordered(&data, 2);
        let mut online = OnlineTrainer::new(shape4(), 21);
        for _ in 0..2 {
            online.feedback_batch(&data.xs, &data.ys).unwrap();
        }
        assert_eq!(online.states, offline.states);
    }

    #[test]
    fn single_row_windows_match_bulk_window() {
        // Window framing is irrelevant: 1-row batches == one big batch.
        let data = SynthSpec::new(16, 2, 70).noise(0.05).seed(11).generate();
        let mut bulk = OnlineTrainer::new(shape2(), 5);
        bulk.feedback_batch(&data.xs, &data.ys).unwrap();
        let mut dripped = OnlineTrainer::new(shape2(), 5);
        for (x, &y) in data.xs.iter().zip(&data.ys) {
            dripped.feedback_batch(std::slice::from_ref(x), &[y]).unwrap();
        }
        assert_eq!(bulk.states, dripped.states);
    }

    #[test]
    fn rejected_batches_leave_state_untouched() {
        let mut tr = OnlineTrainer::new(shape2(), 1);
        let before = tr.states.clone();
        assert_eq!(
            tr.feedback_batch(&[vec![0; 16]], &[0, 1]),
            Err(FeedbackError::LengthMismatch { xs: 1, ys: 2 })
        );
        assert_eq!(
            tr.feedback_batch(&[vec![0; 15]], &[0]),
            Err(FeedbackError::WidthMismatch { row: 0, got: 15, want: 16 })
        );
        assert_eq!(
            tr.feedback_batch(&[vec![0; 16], vec![0; 16]], &[0, 2]),
            Err(FeedbackError::BadLabel { row: 1, label: 2, classes: 2 })
        );
        assert_eq!(tr.states, before, "validation must precede mutation");
        assert_eq!(tr.rows_fed(), 0);
        assert_eq!(tr.feedback_batch(&[], &[]), Ok(0));
    }

    #[test]
    fn from_model_snapshot_roundtrips() {
        let shape = shape2();
        let data = SynthSpec::new(16, 2, 128).noise(0.05).seed(2).generate();
        let model = crate::trainer::train_model(&shape, &data, 2, 4);
        let tr = OnlineTrainer::from_model(&model, 77);
        // Warm-started states snapshot straight back to the model.
        assert_eq!(tr.model(), model);
    }

    #[test]
    fn reseed_handles_shape_changes() {
        let mut tr = OnlineTrainer::new(shape2(), 1);
        let other = TMModel::empty(TMShape::synthetic(8, 3, 6));
        tr.reseed_from_model(&other);
        assert_eq!(tr.shape.features, 8);
        assert_eq!(tr.states.len(), other.shape.total_tas());
        // And it can immediately accept feedback for the new shape.
        let data = SynthSpec::new(8, 3, 40).seed(6).generate();
        tr.feedback_batch(&data.xs, &data.ys).unwrap();
    }

    #[test]
    fn online_feedback_recovers_a_drifted_model() {
        // The live-path story in miniature: a model trained pre-drift
        // degrades on drifted data; labeled feedback windows pull its
        // accuracy back without a retrain.
        let shape = shape2();
        let clean = SynthSpec::new(16, 2, 384).noise(0.05).seed(8).generate();
        let model = crate::trainer::train_model(&shape, &clean, 4, 3);
        let drifted = SynthSpec::new(16, 2, 384).noise(0.05).seed(8).drift(0.4).generate();
        let before = reference::accuracy(&model, &drifted.xs, &drifted.ys);
        let mut tr = OnlineTrainer::from_model(&model, 13);
        for (xs, ys) in drifted.xs.chunks(64).zip(drifted.ys.chunks(64)) {
            tr.feedback_batch(xs, ys).unwrap();
        }
        let after = reference::accuracy(&tr.model(), &drifted.xs, &drifted.ys);
        assert!(
            after > 0.9 && after > before,
            "online feedback failed to recover: {before:.3} -> {after:.3}"
        );
    }
}
