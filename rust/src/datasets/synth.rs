//! Class-prototype Boolean dataset generator + the shared PRNG.
//!
//! Process (identical to `python/compile/data.py::make_dataset`):
//! 1. draw one random Boolean prototype per class;
//! 2. draw the drifted feature set (each feature flips with prob `drift`
//!    — *always* consuming F draws so clean/drifted sets stay paired);
//! 3. per sample: pick a class uniformly, copy its prototype, flip each
//!    bit with prob `noise`, then apply the drift flips.

/// xorshift64* — tiny, deterministic, reproduced bit-for-bit in python.
#[derive(Debug, Clone)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    pub fn new(seed: u64) -> Self {
        XorShift64Star {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, n)`.  `n` must be nonzero: the modulus has no
    /// meaningful answer at 0, and the raw `% 0` would surface as a
    /// bare division-by-zero panic far from the real bug (an empty
    /// class set or zero-element draw at the call site).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "XorShift64Star::below(0): empty range (n must be > 0)");
        self.next_u64() % n
    }
}

/// Generation parameters for one dataset draw.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub features: usize,
    pub classes: usize,
    pub n: usize,
    pub noise: f64,
    pub seed: u64,
    pub drift: f64,
    /// Fraction of features that actually discriminate between classes;
    /// the rest share a common background (real sensor data is mostly
    /// uninformative channels).  1.0 = fully distinct prototypes.
    pub informative: f64,
}

impl SynthSpec {
    pub fn new(features: usize, classes: usize, n: usize) -> Self {
        SynthSpec {
            features,
            classes,
            n,
            noise: 0.08,
            seed: 1,
            drift: 0.0,
            informative: 1.0,
        }
    }

    pub fn noise(mut self, v: f64) -> Self {
        self.noise = v;
        self
    }
    pub fn seed(mut self, v: u64) -> Self {
        self.seed = v;
        self
    }
    pub fn drift(mut self, v: f64) -> Self {
        self.drift = v;
        self
    }
    pub fn informative(mut self, v: f64) -> Self {
        self.informative = v;
        self
    }

    pub fn generate(&self) -> Dataset {
        let mut rng = XorShift64Star::new(self.seed);
        // Draw order is locked with python/compile/data.py: background
        // (F), informative mask (F), per-class patterns (M x F, always
        // consuming F draws), drift set (F), then samples.
        let background: Vec<u8> = (0..self.features)
            .map(|_| u8::from(rng.next_f64() < 0.5))
            .collect();
        let informative: Vec<bool> = (0..self.features)
            .map(|_| rng.next_f64() < self.informative)
            .collect();
        let mut protos = vec![vec![0u8; self.features]; self.classes];
        for p in protos.iter_mut() {
            for f in 0..self.features {
                let bit = u8::from(rng.next_f64() < 0.5); // always consume
                p[f] = if informative[f] { bit } else { background[f] };
            }
        }
        // Drift flips: always consume exactly F draws (stream pairing).
        let mut flipped = vec![false; self.features];
        for fl in flipped.iter_mut() {
            *fl = rng.next_f64() < self.drift;
        }
        let mut xs = Vec::with_capacity(self.n);
        let mut ys = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let c = rng.below(self.classes as u64) as usize;
            ys.push(c);
            let mut row = vec![0u8; self.features];
            for f in 0..self.features {
                let mut bit = protos[c][f];
                if rng.next_f64() < self.noise {
                    bit ^= 1;
                }
                if flipped[f] {
                    bit ^= 1;
                }
                row[f] = bit;
            }
            xs.push(row);
        }
        Dataset { xs, ys, spec: self.clone() }
    }
}

/// A generated Boolean dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `xs[i][f]` in {0,1}.
    pub xs: Vec<Vec<u8>>,
    pub ys: Vec<usize>,
    pub spec: SynthSpec,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Split into (train, test) at `frac`.  The cut index is clamped to
    /// `[0, len]`: out-of-range fractions yield an empty side instead of
    /// a slice panic (`frac` is routinely computed from CLI input).
    pub fn split(&self, frac: f64) -> (Dataset, Dataset) {
        let cut = ((self.len() as f64 * frac).clamp(0.0, self.len() as f64)) as usize;
        let a = Dataset {
            xs: self.xs[..cut].to_vec(),
            ys: self.ys[..cut].to_vec(),
            spec: self.spec.clone(),
        };
        let b = Dataset {
            xs: self.xs[cut..].to_vec(),
            ys: self.ys[cut..].to_vec(),
            spec: self.spec.clone(),
        };
        (a, b)
    }

    /// Literal rows (2F, interleaved with complements).
    pub fn literal_rows(&self) -> Vec<Vec<u8>> {
        self.xs
            .iter()
            .map(|x| crate::tm::reference::literals_from_features(x))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Known-answer vectors shared with python/tests/test_data.py.
    #[test]
    fn prng_known_answers_u64() {
        let mut r = XorShift64Star::new(42);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                0x56CE_4AB7_719B_A3A0,
                0xC841_EB53_EBBB_2DDA,
                0xCA46_6BE0_C998_0276,
                0xF1AC_C733_4A7B_70DF,
            ]
        );
    }

    #[test]
    fn prng_known_answers_f64() {
        let mut r = XorShift64Star::new(7);
        let got: Vec<f64> = (0..3).map(|_| (r.next_f64() * 1e12).round() / 1e12).collect();
        assert_eq!(got, vec![0.820246666541, 0.928290156504, 0.089349592752]);
    }

    #[test]
    fn prng_zero_seed_not_stuck() {
        let mut r = XorShift64Star::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    // Regression: below(0) used to surface as a bare division-by-zero
    // panic deep in next_u64's caller.  The empty range is a caller bug
    // (classes == 0 in SynthSpec::generate, or an unguarded
    // negative-class draw at classes == 1) and must say so.
    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_is_a_clear_panic() {
        XorShift64Star::new(1).below(0);
    }

    #[test]
    fn below_one_is_always_zero() {
        // The smallest legal range: the classes == 1 edge its callers
        // must themselves guard (Trainer::update skips the negative
        // draw entirely) still behaves when reached with n == 1.
        let mut r = XorShift64Star::new(3);
        for _ in 0..16 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = SynthSpec::new(16, 3, 64).seed(9).generate();
        let b = SynthSpec::new(16, 3, 64).seed(9).generate();
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
        let c = SynthSpec::new(16, 3, 64).seed(10).generate();
        assert_ne!(a.xs, c.xs);
    }

    #[test]
    fn all_classes_present() {
        let d = SynthSpec::new(8, 4, 400).seed(1).generate();
        for c in 0..4 {
            assert!(d.ys.contains(&c), "class {c} missing");
        }
    }

    #[test]
    fn drift_pairs_with_clean_stream() {
        let clean = SynthSpec::new(32, 2, 128).noise(0.0).seed(5).generate();
        let drifted = SynthSpec::new(32, 2, 128).noise(0.0).seed(5).drift(0.5).generate();
        assert_eq!(clean.ys, drifted.ys);
        // With zero noise, per-class XOR patterns are constant = drift set.
        for c in 0..2 {
            let rows: Vec<Vec<u8>> = clean
                .xs
                .iter()
                .zip(&drifted.xs)
                .zip(&clean.ys)
                .filter(|(_, &y)| y == c)
                .map(|((a, b), _)| a.iter().zip(b).map(|(x, y)| x ^ y).collect())
                .collect();
            assert!(rows.windows(2).all(|w| w[0] == w[1]));
        }
    }

    #[test]
    fn cross_language_dataset_lock() {
        // Exact sample bytes shared with python/tests/test_data.py's
        // generator (make_dataset(8, 2, 4, noise=0.1, seed=42,
        // informative=0.5)) — the two implementations can never
        // silently diverge.
        let d = SynthSpec::new(8, 2, 4)
            .noise(0.1)
            .seed(42)
            .informative(0.5)
            .generate();
        let flat: Vec<u8> = d.xs.iter().flatten().copied().collect();
        assert_eq!(
            flat,
            vec![
                1, 0, 0, 1, 0, 0, 1, 0, 1, 0, 0, 1, 0, 0, 1, 1, 1, 0, 1, 0, 0, 0, 1, 0, 1, 0,
                0, 0, 0, 0, 1, 1
            ]
        );
        assert_eq!(d.ys, vec![0, 0, 1, 1]);
    }

    #[test]
    fn informative_zero_shares_background() {
        let d = SynthSpec::new(16, 3, 48).noise(0.0).informative(0.0).seed(5).generate();
        // All classes identical when nothing is informative.
        let first = &d.xs[0];
        assert!(d.xs.iter().all(|x| x == first));
    }

    #[test]
    fn split_partitions() {
        let d = SynthSpec::new(8, 2, 100).generate();
        let (tr, te) = d.split(0.8);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }

    #[test]
    fn split_clamps_out_of_range_fractions() {
        // Regression: frac outside [0, 1] used to panic on the slice.
        let d = SynthSpec::new(8, 2, 100).generate();
        let (tr, te) = d.split(1.5);
        assert_eq!((tr.len(), te.len()), (100, 0));
        let (tr, te) = d.split(-0.1);
        assert_eq!((tr.len(), te.len()), (0, 100));
        let (tr, te) = d.split(0.0);
        assert_eq!((tr.len(), te.len()), (0, 100));
        let (tr, te) = d.split(1.0);
        assert_eq!((tr.len(), te.len()), (100, 0));
    }

    #[test]
    fn literal_rows_interleave() {
        let d = SynthSpec::new(2, 2, 4).generate();
        let lits = d.literal_rows();
        for (x, l) in d.xs.iter().zip(&lits) {
            assert_eq!(l.len(), 4);
            assert_eq!(l[0], x[0]);
            assert_eq!(l[1], 1 - x[0]);
        }
    }
}
