//! The paper's eight evaluation workloads, with the real datasets'
//! dimensionality/class structure and a per-workload noise level chosen
//! so trained accuracy lands near the paper's Table 2 figures.
//!
//! | name        | paper source                      | classes | features |
//! |-------------|-----------------------------------|---------|----------|
//! | emg         | EMG for gestures [10]             | 6       | 64       |
//! | har         | Human Activity (smartphones) [19] | 6       | 256      |
//! | gesture     | Gesture Phase [14]                | 5       | 96       |
//! | sensorless  | Sensorless Drive Diagnosis [4]    | 11      | 96       |
//! | gasdrift    | Gas Sensor Array Drift [24]       | 6       | 256      |
//! | mnist       | MNIST [7]                         | 10      | 784      |
//! | cifar2      | CIFAR-2 (vehicles/animals) [11]   | 2       | 512      |
//! | kws6        | Speech Commands, 6 words [27]     | 6       | 350      |

use super::synth::{Dataset, SynthSpec};
use crate::config::TMShape;

/// A named paper workload: the TM architecture trained for it plus its
/// generator.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub shape: TMShape,
    pub noise: f64,
    /// Fraction of discriminative features (see `SynthSpec::informative`);
    /// tuned together with `noise` so trained accuracy lands near the
    /// paper's Table 2 figures instead of a saturated 1.00.
    pub informative: f64,
    /// Paper-reported accuracy (Table 2 / MATADOR-matched), for
    /// EXPERIMENTS.md comparison rows.
    pub paper_accuracy: Option<f64>,
    /// Recalibration-suitability note from the paper (§4 Q2).
    pub recalibration: &'static str,
}

impl Workload {
    /// Generate `n` samples with this workload's dims.
    pub fn dataset(&self, n: usize, seed: u64) -> Dataset {
        SynthSpec::new(self.shape.features, self.shape.classes, n)
            .noise(self.noise)
            .informative(self.informative)
            .seed(seed)
            .generate()
    }

    /// Drifted variant (same prototypes/seed, drifted feature set).
    pub fn drifted_dataset(&self, n: usize, seed: u64, drift: f64) -> Dataset {
        SynthSpec::new(self.shape.features, self.shape.classes, n)
            .noise(self.noise)
            .informative(self.informative)
            .seed(seed)
            .drift(drift)
            .generate()
    }
}

/// How drift unfolds over a streamed deployment (the paper §4 Q2 field
/// scenarios): abrupt sensor failure, gradual aging, recurring
/// environment shifts (e.g. day/night cycles).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftKind {
    /// Clean until window `at`, then constant `drift`.
    Abrupt { at: usize, drift: f64 },
    /// Linear ramp: 0 at `start`, full `drift` at `end` and after.
    Gradual { start: usize, end: usize, drift: f64 },
    /// Alternating clean / drifted phases of `period` windows each.
    Recurring { period: usize, drift: f64 },
}

/// A streaming drift schedule: `windows` monitoring windows of
/// `window_n` labeled samples each, drawn from one workload's fixed
/// prototype universe with a per-window drift level.
///
/// Each window's samples are FRESH draws (the stream moves on): window
/// `i` is the `i`-th slice of the full-length stream generated at that
/// window's drift level.  The generator's locked draw order
/// ([`SynthSpec`]) pins prototypes and the sample sequence to the seed,
/// so windows at equal drift chain into one continuous stream, and
/// windows at different drift levels stay sample-paired.
#[derive(Debug, Clone)]
pub struct DriftSchedule {
    pub kind: DriftKind,
    pub windows: usize,
    /// Labeled samples per window.
    pub window_n: usize,
    pub seed: u64,
}

impl DriftSchedule {
    pub fn abrupt(windows: usize, window_n: usize, at: usize, drift: f64) -> Self {
        DriftSchedule { kind: DriftKind::Abrupt { at, drift }, windows, window_n, seed: 7 }
    }

    pub fn gradual(windows: usize, window_n: usize, start: usize, end: usize, drift: f64) -> Self {
        DriftSchedule {
            kind: DriftKind::Gradual { start, end, drift },
            windows,
            window_n,
            seed: 7,
        }
    }

    pub fn recurring(windows: usize, window_n: usize, period: usize, drift: f64) -> Self {
        DriftSchedule { kind: DriftKind::Recurring { period, drift }, windows, window_n, seed: 7 }
    }

    pub fn seed(mut self, v: u64) -> Self {
        self.seed = v;
        self
    }

    /// Drift level of window `step`.
    pub fn drift_at(&self, step: usize) -> f64 {
        match self.kind {
            DriftKind::Abrupt { at, drift } => {
                if step >= at {
                    drift
                } else {
                    0.0
                }
            }
            DriftKind::Gradual { start, end, drift } => {
                if step <= start || end <= start {
                    0.0
                } else if step >= end {
                    drift
                } else {
                    drift * (step - start) as f64 / (end - start) as f64
                }
            }
            DriftKind::Recurring { period, drift } => {
                if (step / period.max(1)) % 2 == 1 {
                    drift
                } else {
                    0.0
                }
            }
        }
    }

    /// Window `step`'s labeled samples for workload `w`.
    ///
    /// Each call regenerates the full-length stream at the window's
    /// drift level (O(windows x window_n)); when iterating every
    /// window, use [`Self::stream`], which shares one generation per
    /// distinct drift level.
    pub fn window(&self, w: &Workload, step: usize) -> Dataset {
        assert!(step < self.windows, "window {step} past schedule ({})", self.windows);
        self.slice(&self.full_stream(w, self.drift_at(step)), step)
    }

    /// All windows, in stream order.  The full-length sample stream is
    /// generated once per DISTINCT drift level and sliced (windows at
    /// equal drift share one generation), so abrupt/recurring schedules
    /// cost O(levels x stream) instead of the O(windows x stream) of
    /// repeated [`Self::window`] calls.  (A gradual ramp has one level
    /// per window either way.)
    pub fn stream(&self, w: &Workload) -> Vec<Dataset> {
        let mut cache: Vec<(u64, Dataset)> = Vec::new();
        (0..self.windows)
            .map(|step| {
                let key = self.drift_at(step).to_bits();
                if !cache.iter().any(|(k, _)| *k == key) {
                    cache.push((key, self.full_stream(w, self.drift_at(step))));
                }
                let full = &cache.iter().find(|(k, _)| *k == key).expect("just inserted").1;
                self.slice(full, step)
            })
            .collect()
    }

    /// `n` clean labeled samples drawn BEYOND the monitored stream —
    /// same prototype universe (same seed), fresh draws.  Train the
    /// initially-deployed model on these, so the monitoring windows
    /// measure generalization, not memorization of the training set
    /// (the stream prefix and a same-seed training draw are
    /// byte-identical otherwise).
    pub fn training_set(&self, w: &Workload, n: usize) -> Dataset {
        let total = self.windows * self.window_n;
        let full = SynthSpec::new(w.shape.features, w.shape.classes, total + n)
            .noise(w.noise)
            .informative(w.informative)
            .seed(self.seed)
            .generate();
        Dataset {
            xs: full.xs[total..].to_vec(),
            ys: full.ys[total..].to_vec(),
            spec: full.spec.clone(),
        }
    }

    /// The full-length labeled stream at one drift level.
    fn full_stream(&self, w: &Workload, drift: f64) -> Dataset {
        SynthSpec::new(w.shape.features, w.shape.classes, self.windows * self.window_n)
            .noise(w.noise)
            .informative(w.informative)
            .seed(self.seed)
            .drift(drift)
            .generate()
    }

    fn slice(&self, full: &Dataset, step: usize) -> Dataset {
        let lo = step * self.window_n;
        let hi = lo + self.window_n;
        Dataset {
            xs: full.xs[lo..hi].to_vec(),
            ys: full.ys[lo..hi].to_vec(),
            spec: full.spec.clone(),
        }
    }
}

fn shape(name: &str, features: usize, classes: usize, clauses: usize, t: i32, s: f64) -> TMShape {
    TMShape {
        name: name.to_string(),
        features,
        classes,
        clauses,
        t,
        s,
        train_batch: 32,
        n_states: 128,
    }
}

/// All workloads, Table 2 first, then the MATADOR trio (Fig 9 / Table 1).
pub fn workloads() -> Vec<Workload> {
    vec![
        Workload {
            name: "emg",
            shape: shape("emg", 64, 6, 100, 20, 3.0),
            noise: 0.2,
            informative: 0.2,
            paper_accuracy: Some(0.87),
            recalibration: "user personalization (myographic bracelet)",
        },
        Workload {
            name: "har",
            shape: shape("har", 256, 6, 100, 20, 5.0),
            noise: 0.27,
            informative: 0.2,
            paper_accuracy: Some(0.84),
            recalibration: "user personalization (activity detection)",
        },
        Workload {
            name: "gesture",
            shape: shape("gesture", 96, 5, 80, 15, 3.5),
            noise: 0.2,
            informative: 0.2,
            paper_accuracy: Some(0.89),
            recalibration: "user personalization (gesture segmentation)",
        },
        Workload {
            name: "sensorless",
            shape: shape("sensorless", 96, 11, 100, 20, 4.0),
            noise: 0.2,
            informative: 0.35,
            paper_accuracy: Some(0.86),
            recalibration: "component aging (drive diagnosis)",
        },
        Workload {
            name: "gasdrift",
            shape: shape("gasdrift", 256, 6, 100, 20, 5.0),
            noise: 0.25,
            informative: 0.25,
            paper_accuracy: Some(0.90),
            recalibration: "environmental change + sensor drift",
        },
        Workload {
            name: "mnist",
            shape: shape("mnist", 784, 10, 200, 50, 10.0),
            noise: 0.15,
            informative: 0.25,
            paper_accuracy: None,
            recalibration: "MATADOR comparison (Fig 9)",
        },
        Workload {
            name: "cifar2",
            shape: shape("cifar2", 512, 2, 300, 40, 8.0),
            noise: 0.2,
            informative: 0.15,
            paper_accuracy: None,
            recalibration: "MATADOR comparison (Fig 9)",
        },
        Workload {
            name: "kws6",
            shape: shape("kws6", 350, 6, 150, 30, 6.0),
            noise: 0.18,
            informative: 0.2,
            paper_accuracy: None,
            recalibration: "MATADOR comparison (Fig 9)",
        },
    ]
}

pub fn workload_names() -> Vec<&'static str> {
    workloads().iter().map(|w| w.name).collect()
}

/// Look up a workload by name.
pub fn workload(name: &str) -> anyhow::Result<Workload> {
    workloads()
        .into_iter()
        .find(|w| w.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {name}; known: {:?}", workload_names()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_eight_workloads_defined() {
        let names = workload_names();
        for n in ["emg", "har", "gesture", "sensorless", "gasdrift", "mnist", "cifar2", "kws6"] {
            assert!(names.contains(&n), "missing {n}");
        }
        assert_eq!(names.len(), 8);
    }

    #[test]
    fn mnist_matches_paper_dims() {
        let w = workload("mnist").unwrap();
        assert_eq!(w.shape.features, 784);
        assert_eq!(w.shape.classes, 10);
        assert_eq!(w.shape.clauses, 200);
        assert_eq!(w.shape.total_tas(), 3_136_000);
    }

    #[test]
    fn shapes_have_attainable_t() {
        for w in workloads() {
            assert!(
                w.shape.t < w.shape.clauses as i32 / 2,
                "{}: T={} >= C/2={}",
                w.name,
                w.shape.t,
                w.shape.clauses / 2
            );
        }
    }

    #[test]
    fn shapes_fit_the_isa() {
        for w in workloads() {
            assert!(w.shape.literals() <= crate::isa::MAX_LITERALS, "{}", w.name);
        }
    }

    #[test]
    fn dataset_generation_dims() {
        let w = workload("emg").unwrap();
        let d = w.dataset(64, 3);
        assert_eq!(d.len(), 64);
        assert_eq!(d.xs[0].len(), 64);
        assert!(d.ys.iter().all(|&y| y < 6));
    }

    #[test]
    fn unknown_workload_errors() {
        assert!(workload("nope").is_err());
    }

    #[test]
    fn drift_levels_follow_the_schedule() {
        let a = DriftSchedule::abrupt(8, 16, 4, 0.4);
        assert_eq!(a.drift_at(0), 0.0);
        assert_eq!(a.drift_at(3), 0.0);
        assert_eq!(a.drift_at(4), 0.4);
        assert_eq!(a.drift_at(7), 0.4);

        let g = DriftSchedule::gradual(10, 16, 2, 6, 0.4);
        assert_eq!(g.drift_at(2), 0.0);
        assert!((g.drift_at(4) - 0.2).abs() < 1e-12);
        assert_eq!(g.drift_at(6), 0.4);
        assert_eq!(g.drift_at(9), 0.4);

        let r = DriftSchedule::recurring(8, 16, 2, 0.3);
        assert_eq!(r.drift_at(0), 0.0);
        assert_eq!(r.drift_at(1), 0.0);
        assert_eq!(r.drift_at(2), 0.3);
        assert_eq!(r.drift_at(3), 0.3);
        assert_eq!(r.drift_at(4), 0.0);
    }

    #[test]
    fn windows_are_fresh_but_universe_paired() {
        let w = workload("emg").unwrap();
        let sched = DriftSchedule::abrupt(4, 32, 2, 0.5).seed(11);
        let stream = sched.stream(&w);
        assert_eq!(stream.len(), 4);
        for d in &stream {
            assert_eq!(d.len(), 32);
            assert_eq!(d.xs[0].len(), w.shape.features);
        }
        // Consecutive clean windows are DIFFERENT samples (the stream
        // moves on), not the same window re-issued.
        assert_ne!(stream[0].xs, stream[1].xs);
        // Clean/drifted windows at the same step index stay label-paired
        // (the generator consumes identical draw streams).
        let clean_sched = DriftSchedule::abrupt(4, 32, 4, 0.5).seed(11);
        let clean = clean_sched.window(&w, 2);
        assert_eq!(clean.ys, stream[2].ys);
        assert_ne!(clean.xs, stream[2].xs, "drift must actually move the features");
        // Deterministic by seed.
        let again = DriftSchedule::abrupt(4, 32, 2, 0.5).seed(11);
        assert_eq!(sched.window(&w, 3).xs, again.window(&w, 3).xs);
    }

    #[test]
    fn training_set_is_fresh_draws_past_the_stream() {
        let w = workload("emg").unwrap();
        let sched = DriftSchedule::abrupt(3, 16, 1, 0.4).seed(4);
        let train = sched.training_set(&w, 32);
        assert_eq!(train.len(), 32);
        assert_eq!(train.xs[0].len(), w.shape.features);
        // The training draws continue the stream past the monitored
        // prefix: they are exactly the tail of a longer clean
        // generation, NOT a re-issue of any monitored window.
        let total = sched.windows * sched.window_n;
        let longer = SynthSpec::new(w.shape.features, w.shape.classes, total + 32)
            .noise(w.noise)
            .informative(w.informative)
            .seed(sched.seed)
            .generate();
        assert_eq!(train.xs, longer.xs[total..].to_vec());
        let clean_window0 = {
            let clean = DriftSchedule::abrupt(3, 16, 3, 0.4).seed(4);
            clean.window(&w, 0)
        };
        assert_ne!(train.xs[..16].to_vec(), clean_window0.xs);
        // Deterministic by seed.
        assert_eq!(train.xs, sched.training_set(&w, 32).xs);
    }

    #[test]
    fn stream_cache_matches_per_window_generation() {
        // The per-drift-level generation cache must not change a single
        // sample vs. the naive per-window path — abrupt, gradual AND
        // recurring.
        let w = workload("emg").unwrap();
        for sched in [
            DriftSchedule::abrupt(6, 16, 3, 0.4).seed(3),
            DriftSchedule::gradual(6, 16, 1, 4, 0.4).seed(3),
            DriftSchedule::recurring(6, 16, 2, 0.4).seed(3),
        ] {
            let stream = sched.stream(&w);
            for (step, win) in stream.iter().enumerate() {
                let direct = sched.window(&w, step);
                assert_eq!(win.xs, direct.xs, "step {step}");
                assert_eq!(win.ys, direct.ys, "step {step}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "past schedule")]
    fn window_past_schedule_panics() {
        let w = workload("emg").unwrap();
        DriftSchedule::abrupt(2, 8, 1, 0.3).window(&w, 2);
    }
}
