//! The paper's eight evaluation workloads, with the real datasets'
//! dimensionality/class structure and a per-workload noise level chosen
//! so trained accuracy lands near the paper's Table 2 figures.
//!
//! | name        | paper source                      | classes | features |
//! |-------------|-----------------------------------|---------|----------|
//! | emg         | EMG for gestures [10]             | 6       | 64       |
//! | har         | Human Activity (smartphones) [19] | 6       | 256      |
//! | gesture     | Gesture Phase [14]                | 5       | 96       |
//! | sensorless  | Sensorless Drive Diagnosis [4]    | 11      | 96       |
//! | gasdrift    | Gas Sensor Array Drift [24]       | 6       | 256      |
//! | mnist       | MNIST [7]                         | 10      | 784      |
//! | cifar2      | CIFAR-2 (vehicles/animals) [11]   | 2       | 512      |
//! | kws6        | Speech Commands, 6 words [27]     | 6       | 350      |

use super::synth::{Dataset, SynthSpec};
use crate::config::TMShape;

/// A named paper workload: the TM architecture trained for it plus its
/// generator.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub shape: TMShape,
    pub noise: f64,
    /// Fraction of discriminative features (see `SynthSpec::informative`);
    /// tuned together with `noise` so trained accuracy lands near the
    /// paper's Table 2 figures instead of a saturated 1.00.
    pub informative: f64,
    /// Paper-reported accuracy (Table 2 / MATADOR-matched), for
    /// EXPERIMENTS.md comparison rows.
    pub paper_accuracy: Option<f64>,
    /// Recalibration-suitability note from the paper (§4 Q2).
    pub recalibration: &'static str,
}

impl Workload {
    /// Generate `n` samples with this workload's dims.
    pub fn dataset(&self, n: usize, seed: u64) -> Dataset {
        SynthSpec::new(self.shape.features, self.shape.classes, n)
            .noise(self.noise)
            .informative(self.informative)
            .seed(seed)
            .generate()
    }

    /// Drifted variant (same prototypes/seed, drifted feature set).
    pub fn drifted_dataset(&self, n: usize, seed: u64, drift: f64) -> Dataset {
        SynthSpec::new(self.shape.features, self.shape.classes, n)
            .noise(self.noise)
            .informative(self.informative)
            .seed(seed)
            .drift(drift)
            .generate()
    }
}

fn shape(name: &str, features: usize, classes: usize, clauses: usize, t: i32, s: f64) -> TMShape {
    TMShape {
        name: name.to_string(),
        features,
        classes,
        clauses,
        t,
        s,
        train_batch: 32,
        n_states: 128,
    }
}

/// All workloads, Table 2 first, then the MATADOR trio (Fig 9 / Table 1).
pub fn workloads() -> Vec<Workload> {
    vec![
        Workload {
            name: "emg",
            shape: shape("emg", 64, 6, 100, 20, 3.0),
            noise: 0.2,
            informative: 0.2,
            paper_accuracy: Some(0.87),
            recalibration: "user personalization (myographic bracelet)",
        },
        Workload {
            name: "har",
            shape: shape("har", 256, 6, 100, 20, 5.0),
            noise: 0.27,
            informative: 0.2,
            paper_accuracy: Some(0.84),
            recalibration: "user personalization (activity detection)",
        },
        Workload {
            name: "gesture",
            shape: shape("gesture", 96, 5, 80, 15, 3.5),
            noise: 0.2,
            informative: 0.2,
            paper_accuracy: Some(0.89),
            recalibration: "user personalization (gesture segmentation)",
        },
        Workload {
            name: "sensorless",
            shape: shape("sensorless", 96, 11, 100, 20, 4.0),
            noise: 0.2,
            informative: 0.35,
            paper_accuracy: Some(0.86),
            recalibration: "component aging (drive diagnosis)",
        },
        Workload {
            name: "gasdrift",
            shape: shape("gasdrift", 256, 6, 100, 20, 5.0),
            noise: 0.25,
            informative: 0.25,
            paper_accuracy: Some(0.90),
            recalibration: "environmental change + sensor drift",
        },
        Workload {
            name: "mnist",
            shape: shape("mnist", 784, 10, 200, 50, 10.0),
            noise: 0.15,
            informative: 0.25,
            paper_accuracy: None,
            recalibration: "MATADOR comparison (Fig 9)",
        },
        Workload {
            name: "cifar2",
            shape: shape("cifar2", 512, 2, 300, 40, 8.0),
            noise: 0.2,
            informative: 0.15,
            paper_accuracy: None,
            recalibration: "MATADOR comparison (Fig 9)",
        },
        Workload {
            name: "kws6",
            shape: shape("kws6", 350, 6, 150, 30, 6.0),
            noise: 0.18,
            informative: 0.2,
            paper_accuracy: None,
            recalibration: "MATADOR comparison (Fig 9)",
        },
    ]
}

pub fn workload_names() -> Vec<&'static str> {
    workloads().iter().map(|w| w.name).collect()
}

/// Look up a workload by name.
pub fn workload(name: &str) -> anyhow::Result<Workload> {
    workloads()
        .into_iter()
        .find(|w| w.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {name}; known: {:?}", workload_names()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_eight_workloads_defined() {
        let names = workload_names();
        for n in ["emg", "har", "gesture", "sensorless", "gasdrift", "mnist", "cifar2", "kws6"] {
            assert!(names.contains(&n), "missing {n}");
        }
        assert_eq!(names.len(), 8);
    }

    #[test]
    fn mnist_matches_paper_dims() {
        let w = workload("mnist").unwrap();
        assert_eq!(w.shape.features, 784);
        assert_eq!(w.shape.classes, 10);
        assert_eq!(w.shape.clauses, 200);
        assert_eq!(w.shape.total_tas(), 3_136_000);
    }

    #[test]
    fn shapes_have_attainable_t() {
        for w in workloads() {
            assert!(
                w.shape.t < w.shape.clauses as i32 / 2,
                "{}: T={} >= C/2={}",
                w.name,
                w.shape.t,
                w.shape.clauses / 2
            );
        }
    }

    #[test]
    fn shapes_fit_the_isa() {
        for w in workloads() {
            assert!(w.shape.literals() <= crate::isa::MAX_LITERALS, "{}", w.name);
        }
    }

    #[test]
    fn dataset_generation_dims() {
        let w = workload("emg").unwrap();
        let d = w.dataset(64, 3);
        assert_eq!(d.len(), 64);
        assert_eq!(d.xs[0].len(), 64);
        assert!(d.ys.iter().all(|&y| y < 6));
    }

    #[test]
    fn unknown_workload_errors() {
        assert!(workload("nope").is_err());
    }
}
