//! Synthetic workload generators for the paper's eight datasets.
//!
//! Per DESIGN.md §Substitutions: the UCI/vision/audio data the paper
//! evaluates is not available offline, so each workload is generated with
//! the same dimensionality and class count from class prototypes + bit
//! noise, with a parameterized drift knob for the recalibration
//! experiments (Fig 8).  The generator is bit-for-bit identical to
//! `python/compile/data.py` (locked by shared PRNG test vectors).

pub mod synth;
pub mod workloads;

pub use synth::{Dataset, SynthSpec, XorShift64Star};
pub use workloads::{workload, workload_names, DriftKind, DriftSchedule, Workload};
