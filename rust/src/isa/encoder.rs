//! Model compressor: dense [`TMModel`] -> Include instruction stream.
//!
//! Implements the Fig 3.3 walk: class -> clause -> TA, emitting one
//! 16-bit instruction per Include.  Empty clauses are skipped entirely
//! (they contribute nothing at inference); empty *classes* emit the
//! tautology-killer clause described in the module docs.

use super::{Instr, IsaError, DecodeWalk, MAX_LITERALS};
use crate::tm::model::TMModel;

/// Compress a dense model into its instruction stream.
///
/// Panics if the model has more literals than the 12-bit offset can
/// address (L > 4096) — such models do not fit this ISA (the paper's
/// edge workloads top out at MNIST's 1568).
pub fn encode(model: &TMModel) -> Vec<Instr> {
    let l = model.shape.literals();
    assert!(
        l <= MAX_LITERALS,
        "{l} literals exceed the 12-bit offset range ({MAX_LITERALS})"
    );
    let mut out = Vec::new();
    let mut cc = false;
    let mut e = false;
    let mut first_overall = true;

    for class in 0..model.shape.classes {
        let mut class_emitted = false;
        let mut class_first = true;
        for clause in 0..model.shape.clauses {
            let tas = model.clause_includes(class, clause);
            if tas.is_empty() {
                continue;
            }
            emit_clause(
                &mut out,
                &tas,
                TMModel::polarity(clause) < 0,
                &mut cc,
                &mut e,
                &mut first_overall,
                &mut class_first,
            );
            class_emitted = true;
        }
        if !class_emitted {
            // Tautology-killer: TA0 AND TA1 = f0 AND !f0 = never fires,
            // but advances the decoder's class walk.
            emit_clause(
                &mut out,
                &[0, 1],
                false,
                &mut cc,
                &mut e,
                &mut first_overall,
                &mut class_first,
            );
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn emit_clause(
    out: &mut Vec<Instr>,
    tas: &[usize],
    neg: bool,
    cc: &mut bool,
    e: &mut bool,
    first_overall: &mut bool,
    class_first: &mut bool,
) {
    // Every new clause toggles CC (except the very first instruction of
    // the stream, which *defines* the initial CC value as false).
    if !*first_overall {
        *cc = !*cc;
    }
    // The first clause of classes 1.. toggles E.
    if *class_first && !*first_overall {
        *e = !*e;
    }
    *first_overall = false;
    *class_first = false;

    let mut prev_ta: Option<usize> = None;
    for &ta in tas {
        let offset = match prev_ta {
            None => ta,
            Some(p) => ta - p,
        };
        out.push(Instr::new(neg, *cc, *e, offset as u16, ta & 1 == 1));
        prev_ta = Some(ta);
    }
}

/// Number of instructions `encode` will emit (includes + 2 per empty
/// class) without materializing the stream.
pub fn instruction_count(model: &TMModel) -> usize {
    let per_class = model.includes_per_class();
    per_class
        .iter()
        .map(|&n| if n == 0 { 2 } else { n })
        .sum()
}

/// Structural decode: per class, the ordered list of (polarity, literal
/// indices) of every encoded clause.  Used for round-trip testing and by
/// the coordinator to validate a stream before programming hardware.
pub fn decode_clauses(
    instrs: &[Instr],
    literals: usize,
    classes: usize,
) -> Result<Vec<Vec<(i32, Vec<usize>)>>, IsaError> {
    let mut out: Vec<Vec<(i32, Vec<usize>)>> = vec![Vec::new(); classes];
    let mut walk = DecodeWalk::new(classes);
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_pol = 1;
    let mut started = false;
    for (i, &ins) in instrs.iter().enumerate() {
        let before = walk.class;
        let (ta, commit) = walk.step(i, ins, literals)?;
        if commit.is_some() {
            out[before].push((cur_pol, std::mem::take(&mut cur)));
        }
        started = true;
        cur_pol = ins.polarity();
        cur.push(ta);
    }
    if started {
        out[walk.class].push((cur_pol, cur));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TMShape;
    use crate::tm::reference;

    fn demo_model() -> TMModel {
        let mut m = TMModel::empty(TMShape::synthetic(4, 3, 4));
        // class 0: clause 0 (+) includes TA 0, 5; clause 1 (-) includes TA 2.
        m.set_include(0, 0, 0, true);
        m.set_include(0, 0, 5, true);
        m.set_include(0, 1, 2, true);
        // class 1: only clause 3 (-) includes TA 7.
        m.set_include(1, 3, 7, true);
        // class 2: empty (tests the tautology-killer).
        m
    }

    #[test]
    fn encode_counts() {
        let m = demo_model();
        let instrs = encode(&m);
        // 4 includes + 2 for the empty class.
        assert_eq!(instrs.len(), 6);
        assert_eq!(instruction_count(&m), 6);
    }

    #[test]
    fn structural_roundtrip() {
        let m = demo_model();
        let instrs = encode(&m);
        let decoded = decode_clauses(&instrs, m.shape.literals(), m.shape.classes).unwrap();
        assert_eq!(decoded[0], vec![(1, vec![0, 5]), (-1, vec![2])]);
        assert_eq!(decoded[1], vec![(-1, vec![7])]);
        // Empty class -> the tautology killer.
        assert_eq!(decoded[2], vec![(1, vec![0, 1])]);
    }

    #[test]
    fn first_instruction_has_zero_toggles() {
        let m = demo_model();
        let instrs = encode(&m);
        assert!(!instrs[0].cc());
        assert!(!instrs[0].e());
    }

    #[test]
    fn semantic_equivalence_with_dense_reference() {
        let m = demo_model();
        let instrs = encode(&m);
        // Every input pattern over 4 features.
        for bits in 0..16u8 {
            let feats: Vec<u8> = (0..4).map(|f| bits >> f & 1).collect();
            let lits = reference::literals_from_features(&feats);
            let dense = reference::class_sums_dense(&m, &lits);
            let walked = super::super::decode_infer(&instrs, &lits, 3).unwrap();
            assert_eq!(dense, walked, "input {feats:?}");
        }
    }

    #[test]
    fn tautology_killer_never_fires() {
        let m = TMModel::empty(TMShape::synthetic(2, 1, 2));
        let instrs = encode(&m);
        assert_eq!(instrs.len(), 2);
        for bits in 0..4u8 {
            let feats: Vec<u8> = (0..2).map(|f| bits >> f & 1).collect();
            let lits = reference::literals_from_features(&feats);
            let sums = super::super::decode_infer(&instrs, &lits, 1).unwrap();
            assert_eq!(sums, vec![0]);
        }
    }

    #[test]
    #[should_panic(expected = "exceed the 12-bit offset range")]
    fn oversized_model_rejected() {
        let m = TMModel::empty(TMShape::synthetic(3000, 1, 2));
        encode(&m);
    }

    #[test]
    fn mnist_scale_offsets_fit() {
        // The paper's largest workload must encode without panicking.
        let mut m = TMModel::empty(TMShape::synthetic(784, 2, 4));
        m.set_include(0, 0, 0, true);
        m.set_include(0, 0, 1567, true); // max delta within a clause
        m.set_include(1, 3, 1567, true); // max absolute anchor
        let instrs = encode(&m);
        let decoded = decode_clauses(&instrs, 1568, 2).unwrap();
        assert_eq!(decoded[0][0].1, vec![0, 1567]);
        assert_eq!(decoded[1][0].1, vec![1567]);
    }
}
