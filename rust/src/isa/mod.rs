//! The 16-bit Include Instruction Encoding (paper Fig 3.4).
//!
//! A trained TM is ~99% Excludes; inference needs only the Includes
//! (Fig 3.2), so the model is compressed into a stream of 16-bit
//! instructions, one per Include, walked in class -> clause -> TA order
//! (Fig 3.3).
//!
//! Bit layout (MSB..LSB):
//! ```text
//!   15   14   13   12..1      0
//!   P    CC   E    OFFSET    L
//! ```
//! * `P`  — absolute polarity of the owning clause (0 -> +1, 1 -> -1).
//! * `CC` — toggles value whenever the owning *clause* changes.
//! * `E`  — toggles value whenever the owning *class* changes.
//! * `OFFSET` — 12-bit TA jump: for the first instruction of a clause the
//!   absolute TA index within the clause; otherwise the delta from the
//!   previous instruction's TA.  (The paper's offset is a raw running
//!   delta; anchoring it per clause keeps it <= L <= 4096 and therefore
//!   always representable in 12 bits — same information, bounded field.
//!   Documented in DESIGN.md §Substitutions.)
//! * `L`  — literal select: 0 -> feature `f`, 1 -> complement `f̄`.
//!   Redundant with `OFFSET & 1` in the interleaved TA layout; the
//!   decoder *checks* it, catching corrupted streams.
//!
//! TA order within a clause interleaves feature and complement:
//! TA `2f` -> literal `f`, TA `2f+1` -> literal `f̄`.
//!
//! **Empty classes** (no Includes anywhere — never produced by real
//! training, but reachable via runtime re-tuning) cannot be expressed by
//! an E-toggle alone, so the encoder emits a *tautology-killer* clause
//! for them: TA 0 and TA 1 (a literal AND its complement) in one clause,
//! which can never fire and therefore only advances the class walk.

pub mod encoder;

pub use encoder::{encode, instruction_count};

/// One 16-bit Include instruction.
#[derive(Copy, Clone, PartialEq, Eq)]
pub struct Instr(pub u16);

pub const OFFSET_BITS: u32 = 12;
pub const MAX_OFFSET: u16 = (1 << OFFSET_BITS) - 1;
/// Largest literal count (L = 2F) the 12-bit offset can address.
pub const MAX_LITERALS: usize = 1 << OFFSET_BITS;

impl Instr {
    pub fn new(polarity_neg: bool, cc: bool, e: bool, offset: u16, complement: bool) -> Self {
        debug_assert!(offset <= MAX_OFFSET);
        let mut v = 0u16;
        v |= (polarity_neg as u16) << 15;
        v |= (cc as u16) << 14;
        v |= (e as u16) << 13;
        v |= (offset & MAX_OFFSET) << 1;
        v |= complement as u16;
        Instr(v)
    }

    /// Clause polarity: +1 or -1.
    #[inline]
    pub fn polarity(self) -> i32 {
        if self.0 >> 15 & 1 == 1 {
            -1
        } else {
            1
        }
    }

    /// Clause-change toggle bit value.
    #[inline]
    pub fn cc(self) -> bool {
        self.0 >> 14 & 1 == 1
    }

    /// Class-change toggle bit value.
    #[inline]
    pub fn e(self) -> bool {
        self.0 >> 13 & 1 == 1
    }

    /// 12-bit TA offset.
    #[inline]
    pub fn offset(self) -> u16 {
        (self.0 >> 1) & MAX_OFFSET
    }

    /// Literal select: false -> feature, true -> complement.
    #[inline]
    pub fn complement(self) -> bool {
        self.0 & 1 == 1
    }
}

impl std::fmt::Debug for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Instr[P={} CC={} E={} O={} L={}]",
            if self.polarity() > 0 { '+' } else { '-' },
            self.cc() as u8,
            self.e() as u8,
            self.offset(),
            self.complement() as u8,
        )
    }
}

/// Decoder errors — a corrupted or malformed stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IsaError {
    /// `L` bit disagrees with TA parity.
    LiteralParity { index: usize },
    /// Offset walked past the literal count.
    OffsetOverrun { index: usize, ta: usize, literals: usize },
    /// More class changes than the header promised.
    ClassOverrun { index: usize },
}

impl std::fmt::Display for IsaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IsaError::LiteralParity { index } => {
                write!(f, "instruction {index}: L bit disagrees with TA parity")
            }
            IsaError::OffsetOverrun { index, ta, literals } => {
                write!(f, "instruction {index}: TA {ta} out of range ({literals} literals)")
            }
            IsaError::ClassOverrun { index } => {
                write!(f, "instruction {index}: class walk exceeded header class count")
            }
        }
    }
}
impl std::error::Error for IsaError {}

/// Shared decode state machine: boundary detection via CC/E toggles.
///
/// Used by the software walks below, the cycle-accurate core
/// (`accel::core`), and the MCU interpreter (`baselines::mcu`): one
/// semantics, several timing models.
pub struct DecodeWalk {
    classes: usize,
    /// Current class index of the walk.
    pub class: usize,
    /// AND-accumulator for the current clause (bit-sliced over 32 dp).
    pub clause_word: u32,
    cur_ta: usize,
    prev_cc: Option<bool>,
    prev_e: bool,
    prev_pol: i32,
}

/// A committed clause: (class, polarity, output word).
pub type Commit = (usize, i32, u32);

impl DecodeWalk {
    pub fn new(classes: usize) -> Self {
        DecodeWalk {
            classes,
            class: 0,
            clause_word: u32::MAX,
            cur_ta: 0,
            prev_cc: None,
            prev_e: false,
            prev_pol: 1,
        }
    }

    /// Advance by one instruction.  Returns the absolute TA index within
    /// the current clause and, if this instruction *starts* a new clause,
    /// the commit of the finished one.
    pub fn step(
        &mut self,
        index: usize,
        ins: Instr,
        literals: usize,
    ) -> Result<(usize, Option<Commit>), IsaError> {
        let mut commit = None;
        let clause_boundary = match self.prev_cc {
            None => true, // first instruction starts the first clause
            Some(prev) => prev != ins.cc(),
        };
        if clause_boundary {
            if self.prev_cc.is_some() {
                commit = Some((self.class, self.prev_pol, self.clause_word));
                if self.prev_e != ins.e() {
                    self.class += 1;
                    if self.class >= self.classes {
                        return Err(IsaError::ClassOverrun { index });
                    }
                }
            }
            self.clause_word = u32::MAX;
            self.cur_ta = ins.offset() as usize;
        } else {
            self.cur_ta += ins.offset() as usize;
        }
        self.prev_cc = Some(ins.cc());
        self.prev_e = ins.e();
        self.prev_pol = ins.polarity();
        if self.cur_ta >= literals {
            return Err(IsaError::OffsetOverrun { index, ta: self.cur_ta, literals });
        }
        if (self.cur_ta & 1 == 1) != ins.complement() {
            return Err(IsaError::LiteralParity { index });
        }
        Ok((self.cur_ta, commit))
    }

    /// Commit of the trailing clause at end-of-stream (None if the stream
    /// was empty).
    pub fn finish(&mut self) -> Option<Commit> {
        self.prev_cc
            .map(|_| (self.class, self.prev_pol, self.clause_word))
    }
}

/// Apply one clause commit to the per-class bit-sliced sums.
///
/// Sparse-first: clauses are ANDs of many literals, so most commit words
/// are zero or nearly so — the popcount loop beats a 32-lane branchless
/// unpack on real models (measured in EXPERIMENTS.md §Perf).
#[inline]
pub fn apply_commit(sums: &mut [[i32; 32]], commit: Commit) {
    let (class, pol, word) = commit;
    if word == 0 {
        return;
    }
    let row = &mut sums[class];
    let mut w = word;
    while w != 0 {
        let b = w.trailing_zeros() as usize;
        row[b] += pol;
        w &= w - 1;
    }
}

/// One contiguous clause segment of a predecoded [`SoaProgram`]: ops
/// `start..end` AND together, then commit `pol` into class `class`.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub struct ClauseSeg {
    /// First op index of the clause (inclusive).
    pub start: u32,
    /// One past the last op index (exclusive).
    pub end: u32,
    /// Owning class.
    pub class: u16,
    /// Commit polarity (+1 / -1).
    pub pol: i8,
}

/// Structure-of-arrays predecoded program: the DECODE-stage state machine
/// ([`DecodeWalk`]) resolved once at program time so the per-batch hot
/// loop is a branch-free AND-reduction over contiguous clause segments
/// (§Perf in EXPERIMENTS.md).
///
/// Layout:
/// * `feats[i]` — feature-memory address of op `i` (TA >> 1);
/// * `masks[i]` — XOR mask folding the L (complement) bit into the read:
///   `word ^ mask` replaces the `if complement { !w } else { w }` branch
///   (0 for the feature, `u32::MAX` for its complement);
/// * `clauses` — the commit table: one [`ClauseSeg`] per clause, in walk
///   order (the trailing clause included — no special-cased final
///   commit);
/// * `max_feat` — cached maximum feature address, making the per-batch
///   bounds check O(1) instead of an O(n) rescan.
#[derive(Debug, Clone, Default)]
pub struct SoaProgram {
    pub feats: Vec<u32>,
    pub masks: Vec<u32>,
    pub clauses: Vec<ClauseSeg>,
    pub max_feat: Option<u32>,
}

impl SoaProgram {
    /// Number of predecoded ops (== instruction count).
    pub fn len(&self) -> usize {
        self.feats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.feats.is_empty()
    }

    /// Number of clause commits one batch walk performs.
    pub fn clause_count(&self) -> usize {
        self.clauses.len()
    }

    /// Drop the program, keeping buffers for the next predecode.
    pub fn clear(&mut self) {
        self.feats.clear();
        self.masks.clear();
        self.clauses.clear();
        self.max_feat = None;
    }

    /// Execute one bit-sliced batch over `words` (Feature Memory layout),
    /// accumulating into `sums` (`[classes][32]`, caller-zeroed).
    /// Returns the number of clause commits (the commit-cycle count).
    ///
    /// Callers must bounds-check `max_feat < words.len()` first; the
    /// walk itself then only pays the slice-index check on `words`.
    #[inline]
    pub fn execute_into(&self, words: &[u32], sums: &mut [[i32; 32]]) -> u64 {
        for seg in &self.clauses {
            let (s, e) = (seg.start as usize, seg.end as usize);
            let mut cur = u32::MAX;
            for (&f, &m) in self.feats[s..e].iter().zip(&self.masks[s..e]) {
                cur &= words[f as usize] ^ m;
            }
            apply_commit(sums, (seg.class as usize, seg.pol as i32, cur));
        }
        self.clauses.len() as u64
    }
}

/// Predecode an instruction stream into SoA form, reusing `prog`'s
/// buffers (the zero-alloc reprogram path).  `literals` bounds the TA
/// walk (pass [`MAX_LITERALS`] to validate against the architectural
/// maximum and defer the batch-size check to `max_feat`).
pub fn predecode_into(
    instrs: &[Instr],
    classes: usize,
    literals: usize,
    prog: &mut SoaProgram,
) -> Result<(), IsaError> {
    prog.clear();
    prog.feats.reserve(instrs.len());
    prog.masks.reserve(instrs.len());
    let mut walk = DecodeWalk::new(classes.max(1));
    let mut clause_start = 0u32;
    for (i, &ins) in instrs.iter().enumerate() {
        let (ta, commit) = match walk.step(i, ins, literals) {
            Ok(v) => v,
            Err(e) => {
                // Never hand back a half-predecoded program: a caller
                // that swallows the error must find an empty (safe)
                // program, not a truncated walk with max_feat unset.
                prog.clear();
                return Err(e);
            }
        };
        if let Some((cls, pol, _)) = commit {
            prog.clauses.push(ClauseSeg {
                start: clause_start,
                end: i as u32,
                class: cls as u16,
                pol: pol as i8,
            });
            clause_start = i as u32;
        }
        prog.feats.push((ta >> 1) as u32);
        prog.masks.push(if ins.complement() { u32::MAX } else { 0 });
    }
    if let Some((cls, pol, _)) = walk.finish() {
        prog.clauses.push(ClauseSeg {
            start: clause_start,
            end: instrs.len() as u32,
            class: cls as u16,
            pol: pol as i8,
        });
    }
    prog.max_feat = prog.feats.iter().copied().max();
    Ok(())
}

/// Predecode into a fresh [`SoaProgram`].
pub fn predecode(instrs: &[Instr], classes: usize, literals: usize) -> Result<SoaProgram, IsaError> {
    let mut prog = SoaProgram::default();
    predecode_into(instrs, classes, literals, &mut prog)?;
    Ok(prog)
}

/// Bit-sliced walk for a 32-datapoint batch over packed *feature* words
/// (the accelerator's Feature Memory layout, Fig 4.5): `packed[f]` bit
/// `b` is Boolean feature `f` of datapoint `b`.  The L bit selects the
/// complement via inversion, exactly like the Literal Select stage.
/// Returns per-class `[i32; 32]` sums.
///
/// This is the semantic core of the accelerator (Fig 4.4-4.6); the
/// cycle-accurate simulator produces identical values with timing.
pub fn decode_infer_packed(
    instrs: &[Instr],
    packed_features: &[u32],
    classes: usize,
) -> Result<Vec<[i32; 32]>, IsaError> {
    let literals = 2 * packed_features.len();
    let mut sums = vec![[0i32; 32]; classes];
    let mut walk = DecodeWalk::new(classes);
    for (i, &ins) in instrs.iter().enumerate() {
        let (ta, commit) = walk.step(i, ins, literals)?;
        if let Some(c) = commit {
            apply_commit(&mut sums, c);
        }
        let feat_word = packed_features[ta >> 1];
        let word = if ins.complement() { !feat_word } else { feat_word };
        walk.clause_word &= word;
    }
    if let Some(c) = walk.finish() {
        apply_commit(&mut sums, c);
    }
    Ok(sums)
}

/// Software reference walk for ONE datapoint (literal vector of length
/// 2F, as produced by `reference::literals_from_features`).  This is
/// exactly the inner loop the MCU baselines run (REDRESS-style software
/// inference, paper §4 Q2).
pub fn decode_infer(
    instrs: &[Instr],
    literals: &[u8],
    classes: usize,
) -> Result<Vec<i32>, IsaError> {
    debug_assert!(literals.len() % 2 == 0);
    // Even literals are the features themselves; bit 0 carries the
    // single datapoint.
    let packed: Vec<u32> = literals.iter().step_by(2).map(|&v| v as u32).collect();
    let sums = decode_infer_packed(instrs, &packed, classes)?;
    Ok(sums.iter().map(|s| s[0]).collect())
}

/// Pack per-literal values of up to 32 datapoints into bit-sliced words
/// (`lits[b][l]` -> bit `b` of word `l`) — the layout of the PJRT
/// inference artifact's `xs_packed` argument.  Mirrors
/// `ref.pack_literals_ref`.
pub fn pack_literals(lits: &[Vec<u8>]) -> Vec<u32> {
    assert!(!lits.is_empty() && lits.len() <= 32);
    let l = lits[0].len();
    let mut out = vec![0u32; l];
    for (b, row) in lits.iter().enumerate() {
        assert_eq!(row.len(), l);
        for (w, &v) in out.iter_mut().zip(row) {
            *w |= (v as u32 & 1) << b;
        }
    }
    out
}

/// Pack per-feature values of up to 32 datapoints into bit-sliced words
/// (`rows[b][f]` -> bit `b` of word `f`) — the accelerator's Feature
/// Memory layout.
pub fn pack_features(rows: &[Vec<u8>]) -> Vec<u32> {
    pack_literals(rows) // identical packing, different row semantics
}

/// Row lanes per word of the 64-wide bit-sliced engine.
pub const SLICE_LANES: usize = 64;

/// Transposed literal planes for an arbitrary row count — the input
/// layout of the 64-lane bit-sliced kernel ([`SlicedProgram`]).
///
/// Plane `f` is a contiguous run of `slices` `u64` words; bit `b` of
/// `planes[f * slices + s]` is Boolean feature `f` of row
/// `64*s + b`.  One `u64` therefore holds the SAME literal across 64
/// rows, so every bitwise op of the clause walk does useful work for 64
/// datapoints at once.  Rows past `rows` (the padding lanes of the last
/// slice) read as all-zero feature rows — exactly the semantics of the
/// unused lanes of a ragged 32-row batch in the Feature Memory layout.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SlicedBatch {
    /// Feature-major planes: `features * slices` words.
    pub planes: Vec<u64>,
    /// Real row count (<= `slices * 64`).
    pub rows: usize,
    pub features: usize,
    /// 64-row slices (`rows.div_ceil(64)`).
    pub slices: usize,
}

impl SlicedBatch {
    /// Rows including the padding lanes of the last slice.
    pub fn padded_rows(&self) -> usize {
        self.slices * SLICE_LANES
    }

    /// The contiguous plane of one feature.
    #[inline]
    pub fn plane(&self, feature: usize) -> &[u64] {
        &self.planes[feature * self.slices..(feature + 1) * self.slices]
    }

    /// The 64-row word of one *literal* (interleaved indexing: literal
    /// `2f` is feature `f`, literal `2f + 1` its complement) in slice
    /// `slice` — the training-side read of the same transposed planes
    /// the sliced inference kernel walks.  Note the complement of a
    /// padding lane reads 1 (padding rows are all-zero feature rows);
    /// callers must only interpret bits below [`SlicedBatch::rows`].
    #[inline]
    pub fn literal_word(&self, lit: usize, slice: usize) -> u64 {
        let w = self.planes[(lit >> 1) * self.slices + slice];
        if lit & 1 == 1 {
            !w
        } else {
            w
        }
    }
}

/// Transpose feature rows into 64-row literal planes, reusing `out`'s
/// buffers (the zero-alloc steady state of the sliced bulk path).  The
/// 64-lane, any-row-count mirror of [`pack_features`]; like the 32-lane
/// packers it asserts non-empty input and uniform widths (serving entry
/// points reject both as typed errors before packing).
pub fn pack_literals_sliced_into(rows: &[Vec<u8>], out: &mut SlicedBatch) {
    assert!(!rows.is_empty());
    let features = rows[0].len();
    let slices = rows.len().div_ceil(SLICE_LANES);
    out.rows = rows.len();
    out.features = features;
    out.slices = slices;
    out.planes.clear();
    out.planes.resize(features * slices, 0);
    for (r, row) in rows.iter().enumerate() {
        assert_eq!(row.len(), features);
        let (s, b) = (r / SLICE_LANES, r % SLICE_LANES);
        for (f, &v) in row.iter().enumerate() {
            out.planes[f * slices + s] |= (v as u64 & 1) << b;
        }
    }
}

/// Transpose into a fresh [`SlicedBatch`].
pub fn pack_literals_sliced(rows: &[Vec<u8>]) -> SlicedBatch {
    let mut out = SlicedBatch::default();
    pack_literals_sliced_into(rows, &mut out);
    out
}

/// One clause of a [`SlicedProgram`]: ops `start..end` of the flat
/// arrays AND together; the 64-row output word commits `pol` into
/// `class`.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub struct SlicedClause {
    pub start: u32,
    pub end: u32,
    pub class: u16,
    pub pol: i8,
}

/// The 64-lane transposed twin of [`SoaProgram`], derived from it once
/// at program time (`derive_sliced_into`).  Two things change versus
/// the 32-lane walk:
///
/// * literal planes are `u64` (one word = one literal across 64 rows),
///   read contiguously per clause op — the inner loop is a streaming
///   AND-reduction over whole plane rows, which the compiler
///   auto-vectorizes;
/// * degenerate clauses are resolved at derivation so the inner loop
///   stays branch-free: an *exclude-only* clause (empty op range — an
///   empty AND is true) becomes a per-class constant in `base_sums`,
///   and a *tautology-killer* (a literal ANDed with its own complement,
///   the encoder's empty-class filler) can never fire and is dropped.
#[derive(Debug, Clone, Default)]
pub struct SlicedProgram {
    pub feats: Vec<u32>,
    /// XOR masks folding the L bit: 0 for the feature, `u64::MAX` for
    /// its complement.
    pub masks: Vec<u64>,
    pub clauses: Vec<SlicedClause>,
    /// Per-class constant contribution of the clauses resolved away at
    /// derivation (+pol per exclude-only clause, for every row —
    /// padding lanes included, matching the 32-lane walk where an empty
    /// segment commits a full `u32::MAX` word).
    pub base_sums: Vec<i32>,
    /// Clause commits of the UNDERIVED program: resolved clauses still
    /// cost their commit cycle on the modeled hardware, so cycle
    /// accounting keeps parity with the 32-lane walk.
    pub total_clauses: u64,
    pub classes: usize,
    /// Copied from the source [`SoaProgram`] (the underived bound), so
    /// the O(1) batch bounds check rejects exactly the batches the
    /// 32-lane walk rejects even when derivation dropped the clause
    /// holding the maximum address.
    pub max_feat: Option<u32>,
}

impl SlicedProgram {
    pub fn clause_count(&self) -> usize {
        self.clauses.len()
    }

    /// Drop the program, keeping buffers for the next derivation.
    pub fn clear(&mut self) {
        self.feats.clear();
        self.masks.clear();
        self.clauses.clear();
        self.base_sums.clear();
        self.total_clauses = 0;
        self.classes = 0;
        self.max_feat = None;
    }

    /// Evaluate every clause over `batch`, accumulating per-row class
    /// sums into `sums` (class-major: `sums[class * padded_rows + row]`,
    /// caller-zeroed, length `classes * batch.padded_rows()`).  `cur` is
    /// the reusable clause accumulator (one word per slice).  Returns
    /// the commit count of the equivalent 32-lane walk
    /// (`total_clauses`).
    ///
    /// Callers must bounds-check `max_feat < batch.features` first,
    /// like [`SoaProgram::execute_into`].
    pub fn execute_into(&self, batch: &SlicedBatch, sums: &mut [i32], cur: &mut Vec<u64>) -> u64 {
        let slices = batch.slices;
        let padded = batch.padded_rows();
        debug_assert_eq!(sums.len(), self.classes * padded);
        for (class, &base) in self.base_sums.iter().enumerate() {
            if base != 0 {
                for v in &mut sums[class * padded..(class + 1) * padded] {
                    *v += base;
                }
            }
        }
        cur.clear();
        cur.resize(slices, 0);
        for clause in &self.clauses {
            let (s, e) = (clause.start as usize, clause.end as usize);
            cur.fill(u64::MAX);
            for (&f, &m) in self.feats[s..e].iter().zip(&self.masks[s..e]) {
                let plane = &batch.planes[f as usize * slices..(f as usize + 1) * slices];
                // Split on the mask OUTSIDE the slice loop: both arms
                // are straight-line streaming reductions over contiguous
                // words, which the auto-vectorizer turns into wide SIMD.
                if m == 0 {
                    for (c, &p) in cur.iter_mut().zip(plane) {
                        *c &= p;
                    }
                } else {
                    for (c, &p) in cur.iter_mut().zip(plane) {
                        *c &= !p;
                    }
                }
            }
            // Commit 64 rows at a time: clause outputs are mostly-zero
            // words on real models (see `apply_commit`), so iterating
            // set bits beats a 64-lane branchless unpack.
            let row0 = clause.class as usize * padded;
            let pol = clause.pol as i32;
            for (slice, &word) in cur.iter().enumerate() {
                let mut w = word;
                let base = row0 + slice * SLICE_LANES;
                while w != 0 {
                    let b = w.trailing_zeros() as usize;
                    sums[base + b] += pol;
                    w &= w - 1;
                }
            }
        }
        self.total_clauses
    }
}

/// Derive the 64-lane [`SlicedProgram`] from a predecoded
/// [`SoaProgram`], reusing `out`'s buffers (the zero-alloc reprogram
/// path).  Exclude-only and tautology-killer clauses are resolved here
/// — see the [`SlicedProgram`] docs.
pub fn derive_sliced_into(prog: &SoaProgram, classes: usize, out: &mut SlicedProgram) {
    out.clear();
    out.classes = classes;
    out.base_sums.resize(classes, 0);
    out.total_clauses = prog.clauses.len() as u64;
    out.max_feat = prog.max_feat;
    out.feats.reserve(prog.feats.len());
    out.masks.reserve(prog.feats.len());
    // Scratch: per-clause map feature -> seen-mask bits (1 = plain,
    // 2 = complement); both bits set means f AND !f — a tautology
    // killer that can never fire.
    let mut seen: std::collections::HashMap<u32, u8> = std::collections::HashMap::new();
    for seg in &prog.clauses {
        let (s, e) = (seg.start as usize, seg.end as usize);
        if s == e {
            // Exclude-only clause: the empty AND is true for every row.
            out.base_sums[seg.class as usize] += seg.pol as i32;
            continue;
        }
        seen.clear();
        let mut dead = false;
        for (&f, &m) in prog.feats[s..e].iter().zip(&prog.masks[s..e]) {
            let bit = if m == 0 { 1u8 } else { 2u8 };
            let entry = seen.entry(f).or_insert(0);
            *entry |= bit;
            if *entry == 3 {
                dead = true;
                break;
            }
        }
        if dead {
            continue;
        }
        let start = out.feats.len() as u32;
        for (&f, &m) in prog.feats[s..e].iter().zip(&prog.masks[s..e]) {
            out.feats.push(f);
            out.masks.push(if m == 0 { 0 } else { u64::MAX });
        }
        out.clauses.push(SlicedClause {
            start,
            end: out.feats.len() as u32,
            class: seg.class,
            pol: seg.pol,
        });
    }
}

/// Derive into a fresh [`SlicedProgram`].
pub fn derive_sliced(prog: &SoaProgram, classes: usize) -> SlicedProgram {
    let mut out = SlicedProgram::default();
    derive_sliced_into(prog, classes, &mut out);
    out
}

/// One clause of a [`CompressedProgram`]: include-list entries
/// `start..end` of the flat `lits` array AND together; the 64-row
/// output word commits `pol` into `class`.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub struct CompressedClause {
    pub start: u32,
    pub end: u32,
    pub class: u16,
    pub pol: i8,
}

/// The ETHEREAL-style compressed form of a clause program: per-clause
/// *include lists* instead of per-op plane masks.  Each entry is a
/// 16-bit word `feature << 1 | complement` — 2 bytes per included
/// literal versus the sliced form's 12 (`u32` feat + `u64` mask), which
/// is both the on-device BRAM footprint the resource model charges
/// ([`crate::model_cost::resources::compressed_model_bytes`]) and the
/// reason the sparse kernel wins: on include-sparse trained models a
/// clause touches one or two planes, and the fused gather below turns
/// those into a single streaming pass instead of the dense walk's
/// fill + AND + commit triple pass.
///
/// Degenerate clauses resolve exactly like [`SlicedProgram`]:
/// exclude-only clauses fold into `base_sums`, tautology killers drop.
/// Optional *weak-clause pruning* ([`derive_compressed_pruned_into`])
/// additionally drops clauses whose include list is longer than a cap —
/// those are the most specific, rarest-firing clauses, so dropping them
/// moves class sums the least per byte saved.  Pruning CHANGES class
/// sums, so it is strictly opt-in: nothing on the equivalence-gated
/// auto path ever selects it.
#[derive(Debug, Clone, Default)]
pub struct CompressedProgram {
    /// Flat include lists: `feature << 1 | complement` per entry.
    /// `MAX_LITERALS` bounds feature addresses to 11 bits, so the
    /// packed entry always fits 16.
    pub lits: Vec<u16>,
    pub clauses: Vec<CompressedClause>,
    /// Per-class constant contribution of the exclude-only clauses
    /// resolved at derivation (see [`SlicedProgram::base_sums`]).
    pub base_sums: Vec<i32>,
    /// Clause commits of the UNDERIVED program minus pruned clauses:
    /// with pruning off this equals the underived clause count, so
    /// cycle accounting keeps parity with the 32-lane walk.
    pub total_clauses: u64,
    pub classes: usize,
    /// Copied from the source [`SoaProgram`] (the underived bound) for
    /// identical batch bounds errors — see [`SlicedProgram::max_feat`].
    pub max_feat: Option<u32>,
    /// Measured include density at derivation: kept include entries
    /// over the underived program's full literal space
    /// (`clauses * 2 * (max_feat + 1)`).  The kernel-selection
    /// threshold ([`crate::accel::engine::COMPRESSED_MAX_DENSITY`])
    /// compares against this.
    pub density: f64,
    /// Clauses dropped by opt-in pruning (always 0 on the
    /// equivalence-gated path).
    pub pruned: u64,
}

impl CompressedProgram {
    pub fn clause_count(&self) -> usize {
        self.clauses.len()
    }

    /// Include-list bytes — the compressed model's storage cost (what
    /// `ResourceBudget.max_model_bytes` gates), NOT the dense plane
    /// bytes.
    pub fn include_bytes(&self) -> usize {
        self.lits.len() * std::mem::size_of::<u16>()
    }

    /// Mean include-list length over kept clauses (0 for an empty
    /// program) — the bench's sparsity context key.
    pub fn avg_includes(&self) -> f64 {
        if self.clauses.is_empty() {
            0.0
        } else {
            self.lits.len() as f64 / self.clauses.len() as f64
        }
    }

    /// Drop the program, keeping buffers for the next derivation.
    pub fn clear(&mut self) {
        self.lits.clear();
        self.clauses.clear();
        self.base_sums.clear();
        self.total_clauses = 0;
        self.classes = 0;
        self.max_feat = None;
        self.density = 0.0;
        self.pruned = 0;
    }

    #[inline]
    fn unpack(lit: u16) -> (usize, u64) {
        ((lit >> 1) as usize, if lit & 1 == 1 { u64::MAX } else { 0 })
    }

    /// Evaluate every clause over `batch` with the sparse gather-AND
    /// kernel, accumulating per-row class sums into `sums` — same
    /// contract as [`SlicedProgram::execute_into`] (class-major
    /// caller-zeroed sums, reusable `cur` accumulator, returns the
    /// modeled commit count, caller bounds-checks `max_feat`).
    ///
    /// Three sparsity levers over the dense sliced walk, all
    /// semantics-preserving:
    /// * a 1-include clause commits straight off `plane ^ mask` —
    ///   one fused pass, no accumulator traffic (the common case on
    ///   trained sparse models and the source of the >=2x headroom);
    /// * longer clauses seed `cur` from their first literal instead of
    ///   `fill(u64::MAX)` + AND;
    /// * a clause whose accumulator goes all-zero stops reading planes
    ///   — a zero word commits nothing, so skipping the rest of the
    ///   include list (and the commit scan) is exact.
    pub fn execute_into(&self, batch: &SlicedBatch, sums: &mut [i32], cur: &mut Vec<u64>) -> u64 {
        let slices = batch.slices;
        let padded = batch.padded_rows();
        debug_assert_eq!(sums.len(), self.classes * padded);
        for (class, &base) in self.base_sums.iter().enumerate() {
            if base != 0 {
                for v in &mut sums[class * padded..(class + 1) * padded] {
                    *v += base;
                }
            }
        }
        cur.clear();
        cur.resize(slices, 0);
        for clause in &self.clauses {
            let (s, e) = (clause.start as usize, clause.end as usize);
            let row0 = clause.class as usize * padded;
            let pol = clause.pol as i32;
            let lits = &self.lits[s..e];
            let (f0, m0) = Self::unpack(lits[0]);
            let plane0 = &batch.planes[f0 * slices..(f0 + 1) * slices];
            if lits.len() == 1 {
                for (slice, &p) in plane0.iter().enumerate() {
                    let mut w = p ^ m0;
                    let base = row0 + slice * SLICE_LANES;
                    while w != 0 {
                        let b = w.trailing_zeros() as usize;
                        sums[base + b] += pol;
                        w &= w - 1;
                    }
                }
                continue;
            }
            let mut any = 0u64;
            for (c, &p) in cur.iter_mut().zip(plane0) {
                *c = p ^ m0;
                any |= *c;
            }
            for &lit in &lits[1..] {
                if any == 0 {
                    break;
                }
                let (f, m) = Self::unpack(lit);
                let plane = &batch.planes[f * slices..(f + 1) * slices];
                any = 0;
                for (c, &p) in cur.iter_mut().zip(plane) {
                    *c &= p ^ m;
                    any |= *c;
                }
            }
            if any == 0 {
                continue;
            }
            for (slice, &word) in cur.iter().enumerate() {
                let mut w = word;
                let base = row0 + slice * SLICE_LANES;
                while w != 0 {
                    let b = w.trailing_zeros() as usize;
                    sums[base + b] += pol;
                    w &= w - 1;
                }
            }
        }
        self.total_clauses
    }
}

/// Derive the compressed include-list form from a predecoded
/// [`SoaProgram`], reusing `out`'s buffers — pruning OFF, so the result
/// is byte-identical to the SoA and sliced walks (the equivalence-gated
/// path).
pub fn derive_compressed_into(prog: &SoaProgram, classes: usize, out: &mut CompressedProgram) {
    derive_compressed_opts_into(prog, classes, None, out);
}

/// [`derive_compressed_into`] with weak-clause pruning: clauses with
/// MORE than `max_includes` include entries are dropped entirely.
/// Pruned clauses change class sums (and the modeled commit count), so
/// this derivation must never feed the equivalence-gated auto path —
/// callers opt in explicitly and own the accuracy consequences
/// (EXPERIMENTS.md §Compressed).
pub fn derive_compressed_pruned_into(
    prog: &SoaProgram,
    classes: usize,
    max_includes: usize,
    out: &mut CompressedProgram,
) {
    derive_compressed_opts_into(prog, classes, Some(max_includes), out);
}

fn derive_compressed_opts_into(
    prog: &SoaProgram,
    classes: usize,
    prune_over: Option<usize>,
    out: &mut CompressedProgram,
) {
    out.clear();
    out.classes = classes;
    out.base_sums.resize(classes, 0);
    out.max_feat = prog.max_feat;
    out.lits.reserve(prog.feats.len());
    // Commits the compressed walk still models: every underived clause
    // except pruned ones (resolved clauses keep their commit cycle,
    // exactly like `derive_sliced_into`).
    let mut committed = 0u64;
    let mut seen: std::collections::HashMap<u32, u8> = std::collections::HashMap::new();
    for seg in &prog.clauses {
        let (s, e) = (seg.start as usize, seg.end as usize);
        if s == e {
            out.base_sums[seg.class as usize] += seg.pol as i32;
            committed += 1;
            continue;
        }
        if let Some(cap) = prune_over {
            if e - s > cap {
                out.pruned += 1;
                continue;
            }
        }
        committed += 1;
        seen.clear();
        let mut dead = false;
        for (&f, &m) in prog.feats[s..e].iter().zip(&prog.masks[s..e]) {
            let bit = if m == 0 { 1u8 } else { 2u8 };
            let entry = seen.entry(f).or_insert(0);
            *entry |= bit;
            if *entry == 3 {
                dead = true;
                break;
            }
        }
        if dead {
            continue;
        }
        let start = out.lits.len() as u32;
        for (&f, &m) in prog.feats[s..e].iter().zip(&prog.masks[s..e]) {
            debug_assert!(f < (MAX_LITERALS as u32) / 2, "feature address exceeds 11 bits");
            out.lits.push(((f as u16) << 1) | u16::from(m != 0));
        }
        out.clauses.push(CompressedClause {
            start,
            end: out.lits.len() as u32,
            class: seg.class,
            pol: seg.pol,
        });
    }
    out.total_clauses = committed;
    let lit_space = prog.clauses.len() as f64
        * 2.0
        * prog.max_feat.map_or(0.0, |f| (f + 1) as f64);
    out.density = if lit_space > 0.0 { out.lits.len() as f64 / lit_space } else { 0.0 };
}

/// Derive into a fresh [`CompressedProgram`] (pruning off).
pub fn derive_compressed(prog: &SoaProgram, classes: usize) -> CompressedProgram {
    let mut out = CompressedProgram::default();
    derive_compressed_into(prog, classes, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Program-memory integrity: FNV-1a digests + seeded bit-flip injection
// ---------------------------------------------------------------------------

/// Incremental FNV-1a-64 over program backing buffers — the scrub
/// layer's detection primitive (EXPERIMENTS.md §Integrity).  Same
/// constants as the wire format's `tm::serialize::fnv1a64`, so a digest
/// recorded at fence time and one recomputed by a scrub tick agree iff
/// the bytes agree.  FNV-1a's per-byte odd-prime multiply is injective
/// mod 2^64, so any single flipped bit ALWAYS changes the digest —
/// single-event upsets cannot hide.
#[derive(Debug, Clone)]
pub struct ProgramDigest(u64);

impl Default for ProgramDigest {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgramDigest {
    pub fn new() -> Self {
        ProgramDigest(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    #[inline]
    pub fn u16(&mut self, v: u16) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    #[inline]
    pub fn i32(&mut self, v: i32) {
        self.u32(v as u32);
    }

    /// `Option<u32>` with an explicit presence byte, so `None` and
    /// `Some(0)` hash apart.
    #[inline]
    pub fn opt_u32(&mut self, v: Option<u32>) {
        match v {
            None => self.byte(0),
            Some(x) => {
                self.byte(1);
                self.u32(x);
            }
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Digest every buffer a [`SoaProgram`] executes from (ops, masks,
/// commit table, cached bound).
pub fn digest_soa(prog: &SoaProgram) -> u64 {
    let mut d = ProgramDigest::new();
    for &f in &prog.feats {
        d.u32(f);
    }
    for &m in &prog.masks {
        d.u32(m);
    }
    for seg in &prog.clauses {
        d.u32(seg.start);
        d.u32(seg.end);
        d.u16(seg.class);
        d.byte(seg.pol as u8);
    }
    d.opt_u32(prog.max_feat);
    d.finish()
}

/// Digest every buffer a [`SlicedProgram`] executes from.
pub fn digest_sliced(prog: &SlicedProgram) -> u64 {
    let mut d = ProgramDigest::new();
    for &f in &prog.feats {
        d.u32(f);
    }
    for &m in &prog.masks {
        d.u64(m);
    }
    for seg in &prog.clauses {
        d.u32(seg.start);
        d.u32(seg.end);
        d.u16(seg.class);
        d.byte(seg.pol as u8);
    }
    for &b in &prog.base_sums {
        d.i32(b);
    }
    d.u64(prog.total_clauses);
    d.u64(prog.classes as u64);
    d.opt_u32(prog.max_feat);
    d.finish()
}

/// Digest every buffer a [`CompressedProgram`] executes from.
pub fn digest_compressed(prog: &CompressedProgram) -> u64 {
    let mut d = ProgramDigest::new();
    for &l in &prog.lits {
        d.u16(l);
    }
    for seg in &prog.clauses {
        d.u32(seg.start);
        d.u32(seg.end);
        d.u16(seg.class);
        d.byte(seg.pol as u8);
    }
    for &b in &prog.base_sums {
        d.i32(b);
    }
    d.u64(prog.total_clauses);
    d.u64(prog.classes as u64);
    d.opt_u32(prog.max_feat);
    d.finish()
}

/// Tiny splitmix64 step for reproducible corruption targeting (the isa
/// layer stays dependency-free; this is NOT the simulation PRNG).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// One corruptible span: (word count, bits per word, flip closure).
type FlipSpan<'a> = (usize, u32, &'a mut dyn FnMut(usize, u32));

/// Flip `n_bits` DISTINCT seeded pseudo-random bits across `spans`,
/// where each span is (word count, bits per word, flip closure).
/// Distinctness (linear probing on collision) guarantees the corruption
/// never cancels itself out, so `n_bits >= 1` flipped on a non-empty
/// program ALWAYS changes its digest.  Returns bits actually flipped
/// (0 only when every span is empty).
fn flip_spans(seed: u64, n_bits: u32, spans: &mut [FlipSpan<'_>]) -> u32 {
    let total_bits: u64 = spans.iter().map(|(n, w, _)| *n as u64 * *w as u64).sum();
    if total_bits == 0 {
        return 0;
    }
    let mut rng = seed;
    let mut chosen: Vec<u64> = Vec::with_capacity(n_bits as usize);
    let mut flipped = 0u32;
    for _ in 0..n_bits.min(total_bits.min(u32::MAX as u64) as u32) {
        let mut bit = splitmix64(&mut rng) % total_bits;
        while chosen.contains(&bit) {
            bit = (bit + 1) % total_bits;
        }
        chosen.push(bit);
        let mut off = bit;
        for (n, w, flip) in spans.iter_mut() {
            let span_bits = *n as u64 * *w as u64;
            if off < span_bits {
                flip((off / *w as u64) as usize, (off % *w as u64) as u32);
                break;
            }
            off -= span_bits;
        }
        flipped += 1;
    }
    flipped
}

/// Flip `n_bits` seeded bits in a [`SoaProgram`]'s data arrays (feats +
/// masks) — the fault-injection half of the scrub story.  Returns bits
/// flipped.  The corrupted program is exactly what an SEU leaves
/// behind: structurally intact tables over rotted payload words.
pub fn flip_soa_bits(prog: &mut SoaProgram, seed: u64, n_bits: u32) -> u32 {
    let (feats, masks) = (&mut prog.feats, &mut prog.masks);
    flip_spans(
        seed,
        n_bits,
        &mut [
            (feats.len(), 32, &mut |i, b| feats[i] ^= 1 << b),
            (masks.len(), 32, &mut |i, b| masks[i] ^= 1 << b),
        ],
    )
}

/// Flip `n_bits` seeded bits in a [`SlicedProgram`]'s data arrays
/// (feats + masks + base_sums).  Returns bits flipped.
pub fn flip_sliced_bits(prog: &mut SlicedProgram, seed: u64, n_bits: u32) -> u32 {
    let (feats, masks, base) = (&mut prog.feats, &mut prog.masks, &mut prog.base_sums);
    flip_spans(
        seed,
        n_bits,
        &mut [
            (feats.len(), 32, &mut |i, b| feats[i] ^= 1 << b),
            (masks.len(), 64, &mut |i, b| masks[i] ^= 1u64 << b),
            (base.len(), 32, &mut |i, b| base[i] ^= 1 << b),
        ],
    )
}

/// Flip `n_bits` seeded bits in a [`CompressedProgram`]'s data arrays
/// (lits + base_sums).  Returns bits flipped.
pub fn flip_compressed_bits(prog: &mut CompressedProgram, seed: u64, n_bits: u32) -> u32 {
    let (lits, base) = (&mut prog.lits, &mut prog.base_sums);
    flip_spans(
        seed,
        n_bits,
        &mut [
            (lits.len(), 16, &mut |i, b| lits[i] ^= 1 << b),
            (base.len(), 32, &mut |i, b| base[i] ^= 1 << b),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instr_field_roundtrip() {
        let i = Instr::new(true, false, true, 1234, false);
        assert_eq!(i.polarity(), -1);
        assert!(!i.cc());
        assert!(i.e());
        assert_eq!(i.offset(), 1234);
        assert!(!i.complement());
    }

    #[test]
    fn instr_all_fields_max() {
        let i = Instr::new(true, true, true, MAX_OFFSET, true);
        assert_eq!(i.polarity(), -1);
        assert!(i.cc() && i.e() && i.complement());
        assert_eq!(i.offset(), MAX_OFFSET);
    }

    #[test]
    fn walk_detects_literal_parity_corruption() {
        // TA 2 (even) but L bit says complement.
        let ins = Instr::new(false, false, false, 2, true);
        let mut w = DecodeWalk::new(1);
        assert_eq!(w.step(0, ins, 8), Err(IsaError::LiteralParity { index: 0 }));
    }

    #[test]
    fn walk_detects_offset_overrun() {
        let ins = Instr::new(false, false, false, 9, true);
        let mut w = DecodeWalk::new(1);
        assert_eq!(
            w.step(0, ins, 8),
            Err(IsaError::OffsetOverrun { index: 0, ta: 9, literals: 8 })
        );
    }

    #[test]
    fn single_instruction_single_clause() {
        // Clause = literal 0 (feature 0). Datapoint bits pass through.
        let ins = Instr::new(false, false, false, 0, false);
        let packed = vec![0b1010u32, 0];
        let sums = decode_infer_packed(&[ins], &packed, 1).unwrap();
        assert_eq!(sums[0][0], 0);
        assert_eq!(sums[0][1], 1);
        assert_eq!(sums[0][3], 1);
    }

    #[test]
    fn complement_inverts() {
        // Clause = NOT feature 0 (TA 1).
        let ins = Instr::new(false, false, false, 1, true);
        let packed = vec![0b01u32];
        let sums = decode_infer_packed(&[ins], &packed, 1).unwrap();
        assert_eq!(sums[0][0], 0); // feature=1 -> !f=0
        assert_eq!(sums[0][1], 1); // feature=0 -> !f=1
    }

    #[test]
    fn cc_toggle_separates_clauses() {
        // Two clauses over feature 0: clause0 (+) = f, clause1 (-) = f.
        let i0 = Instr::new(false, false, false, 0, false);
        let i1 = Instr::new(true, true, false, 0, false);
        let packed = vec![1u32];
        let sums = decode_infer_packed(&[i0, i1], &packed, 1).unwrap();
        assert_eq!(sums[0][0], 0); // +1 - 1
    }

    #[test]
    fn same_cc_same_clause_ands() {
        // One clause including f0 AND f1: fires only when both are 1.
        let i0 = Instr::new(false, false, false, 0, false);
        let i1 = Instr::new(false, false, false, 2, false); // delta 2 -> TA 2
        let packed = vec![0b11u32, 0b01u32]; // dp0: f0=1,f1=1; dp1: f0=1,f1=0
        let sums = decode_infer_packed(&[i0, i1], &packed, 1).unwrap();
        assert_eq!(sums[0][0], 1);
        assert_eq!(sums[0][1], 0);
    }

    #[test]
    fn e_toggle_advances_class() {
        let i0 = Instr::new(false, false, false, 0, false); // class 0, clause a
        let i1 = Instr::new(false, true, true, 0, false); // class 1 (E toggled)
        let packed = vec![1u32];
        let sums = decode_infer_packed(&[i0, i1], &packed, 2).unwrap();
        assert_eq!(sums[0][0], 1);
        assert_eq!(sums[1][0], 1);
    }

    #[test]
    fn class_overrun_detected() {
        let i0 = Instr::new(false, false, false, 0, false);
        let i1 = Instr::new(false, true, true, 0, false);
        let err = decode_infer_packed(&[i0, i1], &[1u32], 1).unwrap_err();
        assert_eq!(err, IsaError::ClassOverrun { index: 1 });
    }

    #[test]
    fn pack_literals_bit_layout() {
        let rows = vec![vec![1u8, 0], vec![0u8, 1], vec![1u8, 1]];
        let packed = pack_literals(&rows);
        assert_eq!(packed, vec![0b101, 0b110]);
    }

    #[test]
    fn soa_walk_matches_packed_walk() {
        // Two classes, three clauses, mixed complements — the SoA
        // execution must reproduce decode_infer_packed exactly.
        let instrs = vec![
            Instr::new(false, false, false, 0, false), // class 0, clause a: f0
            Instr::new(false, false, false, 3, true),  // ... AND !f1 (TA 3)
            Instr::new(true, true, false, 2, false),   // clause b (-): f1
            Instr::new(false, false, true, 1, true),   // class 1: !f0
        ];
        let packed = vec![0b1010u32, 0b0110u32];
        let reference = decode_infer_packed(&instrs, &packed, 2).unwrap();

        let prog = predecode(&instrs, 2, MAX_LITERALS).unwrap();
        assert_eq!(prog.len(), 4);
        assert_eq!(prog.clause_count(), 3);
        assert_eq!(prog.max_feat, Some(1));
        let mut sums = vec![[0i32; 32]; 2];
        let commits = prog.execute_into(&packed, &mut sums);
        assert_eq!(commits, 3);
        assert_eq!(sums, reference);
    }

    #[test]
    fn soa_segments_are_contiguous_and_cover_all_ops() {
        let instrs = vec![
            Instr::new(false, false, false, 0, false),
            Instr::new(false, false, false, 2, false),
            Instr::new(true, true, false, 0, false),
            Instr::new(false, false, true, 1, true),
        ];
        let prog = predecode(&instrs, 2, 8).unwrap();
        assert_eq!(prog.clauses[0].start, 0);
        for w in prog.clauses.windows(2) {
            assert_eq!(w[0].end, w[1].start, "contiguous segments");
        }
        assert_eq!(prog.clauses.last().unwrap().end as usize, instrs.len());
        // XOR masks fold the complement bit.
        assert_eq!(prog.masks, vec![0, 0, 0, u32::MAX]);
        assert_eq!(prog.feats, vec![0, 1, 0, 0]);
    }

    #[test]
    fn soa_predecode_reuses_buffers_and_surfaces_errors() {
        let good = vec![Instr::new(false, false, false, 0, false)];
        let mut prog = predecode(&good, 1, 8).unwrap();
        // Reprogram in place.
        predecode_into(&good, 1, 8, &mut prog).unwrap();
        assert_eq!(prog.len(), 1);
        // Corrupt stream errors exactly like DecodeWalk.
        let bad = vec![Instr::new(false, false, false, 9, true)];
        assert_eq!(
            predecode_into(&bad, 1, 8, &mut prog),
            Err(IsaError::OffsetOverrun { index: 0, ta: 9, literals: 8 })
        );
        // Errors never leave a half-predecoded program behind.
        assert!(prog.is_empty());
        assert_eq!(prog.clause_count(), 0);
        assert_eq!(prog.max_feat, None);
    }

    #[test]
    fn soa_empty_stream_is_empty_program() {
        let prog = predecode(&[], 3, MAX_LITERALS).unwrap();
        assert!(prog.is_empty());
        assert_eq!(prog.clause_count(), 0);
        assert_eq!(prog.max_feat, None);
        let mut sums = vec![[0i32; 32]; 3];
        assert_eq!(prog.execute_into(&[], &mut sums), 0);
    }

    #[test]
    fn apply_commit_popcounts() {
        let mut sums = vec![[0i32; 32]; 2];
        apply_commit(&mut sums, (1, -1, 0b1001));
        assert_eq!(sums[1][0], -1);
        assert_eq!(sums[1][3], -1);
        assert_eq!(sums[1][1], 0);
        assert_eq!(sums[0][0], 0);
    }

    #[test]
    fn sliced_pack_bit_layout_and_padding() {
        // 3 rows, 2 features: plane f, slice 0, bit b = rows[b][f].
        let rows = vec![vec![1u8, 0], vec![0u8, 1], vec![1u8, 1]];
        let b = pack_literals_sliced(&rows);
        assert_eq!(b.rows, 3);
        assert_eq!(b.features, 2);
        assert_eq!(b.slices, 1);
        assert_eq!(b.padded_rows(), 64);
        assert_eq!(b.plane(0), &[0b101u64]);
        assert_eq!(b.plane(1), &[0b110u64]);

        // 65 rows forces a second slice; row 64 lands in bit 0 of it.
        let rows: Vec<Vec<u8>> = (0..65).map(|r| vec![u8::from(r == 64)]).collect();
        let b = pack_literals_sliced(&rows);
        assert_eq!(b.slices, 2);
        assert_eq!(b.plane(0), &[0u64, 1u64]);

        // Reuse: repacking a smaller batch leaves no residue.
        let mut reused = b;
        pack_literals_sliced_into(&[vec![1u8]], &mut reused);
        assert_eq!(reused.slices, 1);
        assert_eq!(reused.plane(0), &[1u64]);
    }

    #[test]
    fn sliced_literal_word_interleaves_complements() {
        // literal 2f = feature f's plane word; literal 2f+1 = its
        // bitwise complement (the online feedback kernel's read path).
        let rows = vec![vec![1u8, 0], vec![0u8, 1], vec![1u8, 1]];
        let b = pack_literals_sliced(&rows);
        assert_eq!(b.literal_word(0, 0), 0b101);
        assert_eq!(b.literal_word(1, 0), !0b101u64);
        assert_eq!(b.literal_word(2, 0), 0b110);
        assert_eq!(b.literal_word(3, 0), !0b110u64);
    }

    #[test]
    fn sliced_walk_matches_packed_walk_on_32_rows() {
        // Same program and rows as `soa_walk_matches_packed_walk`: the
        // 64-lane kernel must agree bit lane for bit lane.
        let instrs = vec![
            Instr::new(false, false, false, 0, false),
            Instr::new(false, false, false, 3, true),
            Instr::new(true, true, false, 2, false),
            Instr::new(false, false, true, 1, true),
        ];
        let packed = vec![0b1010u32, 0b0110u32];
        let reference = decode_infer_packed(&instrs, &packed, 2).unwrap();

        let prog = predecode(&instrs, 2, MAX_LITERALS).unwrap();
        let sliced = derive_sliced(&prog, 2);
        assert_eq!(sliced.clause_count(), 3);
        assert_eq!(sliced.total_clauses, 3);
        assert_eq!(sliced.max_feat, prog.max_feat);
        assert_eq!(sliced.masks, vec![0, u64::MAX, 0, u64::MAX]);

        // Rows 0..32 reconstructed from the packed lanes.
        let rows: Vec<Vec<u8>> = (0..32)
            .map(|b| packed.iter().map(|&w| (w >> b & 1) as u8).collect())
            .collect();
        let batch = pack_literals_sliced(&rows);
        let mut sums = vec![0i32; 2 * batch.padded_rows()];
        let mut cur = Vec::new();
        let commits = sliced.execute_into(&batch, &mut sums, &mut cur);
        assert_eq!(commits, 3);
        for class in 0..2 {
            for b in 0..32 {
                assert_eq!(
                    sums[class * batch.padded_rows() + b],
                    reference[class][b],
                    "class {class} lane {b}"
                );
            }
        }
        // Padding rows behave like all-zero feature rows: class 1's
        // clause is !f0, which FIRES on them.
        assert_eq!(sums[batch.padded_rows() + 63], 1);
    }

    #[test]
    fn sliced_derivation_drops_tautology_killers() {
        // Class 0 has real clauses; class 1 is the encoder's
        // tautology-killer pair (f0 AND !f0) — it can never fire, so
        // derivation resolves it out while keeping commit-count parity.
        let instrs = vec![
            Instr::new(false, false, false, 0, false), // class 0: f0
            Instr::new(false, true, true, 0, false),   // class 1 killer: f0
            Instr::new(false, true, true, 1, true),    // ... AND !f0
        ];
        let prog = predecode(&instrs, 2, MAX_LITERALS).unwrap();
        assert_eq!(prog.clause_count(), 2);
        let sliced = derive_sliced(&prog, 2);
        assert_eq!(sliced.clause_count(), 1, "killer clause dropped");
        assert_eq!(sliced.total_clauses, 2, "commit cycles keep parity");
        assert_eq!(sliced.base_sums, vec![0, 0]);

        let rows = vec![vec![1u8], vec![0u8]];
        let batch = pack_literals_sliced(&rows);
        let mut sums = vec![0i32; 2 * batch.padded_rows()];
        assert_eq!(sliced.execute_into(&batch, &mut sums, &mut Vec::new()), 2);
        assert_eq!(sums[0], 1); // class 0, row 0: f0=1
        assert_eq!(sums[1], 0); // class 0, row 1: f0=0
        // Class 1 never fires anywhere.
        let padded = batch.padded_rows();
        assert!(sums[padded..].iter().all(|&v| v == 0));
    }

    #[test]
    fn sliced_derivation_resolves_exclude_only_clauses() {
        // An empty clause segment (exclude-only: the empty AND is true)
        // cannot come out of `predecode`, but a hand-built SoaProgram
        // can hold one; the 32-lane walk commits a full u32::MAX word
        // for it, and the sliced derivation must match via `base_sums`.
        let prog = SoaProgram {
            feats: vec![0],
            masks: vec![0],
            clauses: vec![
                ClauseSeg { start: 0, end: 0, class: 0, pol: -1 }, // exclude-only
                ClauseSeg { start: 0, end: 1, class: 1, pol: 1 },  // f0
            ],
            max_feat: Some(0),
        };
        let mut soa_sums = vec![[0i32; 32]; 2];
        prog.execute_into(&[0b01u32], &mut soa_sums);

        let sliced = derive_sliced(&prog, 2);
        assert_eq!(sliced.clause_count(), 1);
        assert_eq!(sliced.base_sums, vec![-1, 0]);
        assert_eq!(sliced.total_clauses, 2);

        let rows = vec![vec![1u8], vec![0u8]];
        let batch = pack_literals_sliced(&rows);
        let mut sums = vec![0i32; 2 * batch.padded_rows()];
        sliced.execute_into(&batch, &mut sums, &mut Vec::new());
        let padded = batch.padded_rows();
        for b in 0..2 {
            assert_eq!(sums[b], soa_sums[0][b], "class 0 row {b}");
            assert_eq!(sums[padded + b], soa_sums[1][b], "class 1 row {b}");
        }
        // The exclude-only constant covers padding rows too, exactly
        // like the u32::MAX commit covers unused lanes.
        assert_eq!(sums[padded - 1], -1);
    }

    #[test]
    fn sliced_derivation_reuses_buffers() {
        let instrs = vec![Instr::new(false, false, false, 0, false)];
        let prog = predecode(&instrs, 1, 8).unwrap();
        let mut sliced = derive_sliced(&prog, 1);
        assert_eq!(sliced.clause_count(), 1);
        // Re-derive in place from a different program: no residue.
        let killer = vec![
            Instr::new(false, false, false, 0, false),
            Instr::new(false, false, false, 1, true),
        ];
        let prog2 = predecode(&killer, 1, 8).unwrap();
        derive_sliced_into(&prog2, 1, &mut sliced);
        assert_eq!(sliced.clause_count(), 0);
        assert_eq!(sliced.total_clauses, 1);
        assert_eq!(sliced.base_sums, vec![0]);
    }

    #[test]
    fn compressed_walk_matches_sliced_walk_on_32_rows() {
        // Same program and rows as `sliced_walk_matches_packed_walk…`:
        // the sparse gather kernel must agree bit lane for bit lane,
        // including its 1-include fused fast path (clauses here have
        // both 1- and 2-entry include lists).
        let instrs = vec![
            Instr::new(false, false, false, 0, false),
            Instr::new(false, false, false, 3, true),
            Instr::new(true, true, false, 2, false),
            Instr::new(false, false, true, 1, true),
        ];
        let packed = vec![0b1010u32, 0b0110u32];
        let reference = decode_infer_packed(&instrs, &packed, 2).unwrap();

        let prog = predecode(&instrs, 2, MAX_LITERALS).unwrap();
        let comp = derive_compressed(&prog, 2);
        assert_eq!(comp.clause_count(), 3);
        assert_eq!(comp.total_clauses, 3);
        assert_eq!(comp.pruned, 0);
        assert_eq!(comp.max_feat, prog.max_feat);
        // lits pack feature<<1 | complement, flat across clauses.
        assert_eq!(comp.lits, vec![0 << 1, (1 << 1) | 1, 1 << 1, (0 << 1) | 1]);
        assert_eq!(comp.include_bytes(), 8);
        assert!((comp.avg_includes() - 4.0 / 3.0).abs() < 1e-12);
        // Density: 4 kept entries over 3 clauses * 2 * (max_feat+1).
        assert!((comp.density - 4.0 / 12.0).abs() < 1e-12);

        let rows: Vec<Vec<u8>> = (0..32)
            .map(|b| packed.iter().map(|&w| (w >> b & 1) as u8).collect())
            .collect();
        let batch = pack_literals_sliced(&rows);
        let mut sums = vec![0i32; 2 * batch.padded_rows()];
        let mut cur = Vec::new();
        assert_eq!(comp.execute_into(&batch, &mut sums, &mut cur), 3);
        for class in 0..2 {
            for b in 0..32 {
                assert_eq!(
                    sums[class * batch.padded_rows() + b],
                    reference[class][b],
                    "class {class} lane {b}"
                );
            }
        }
        // Padding-lane parity with the sliced walk (!f0 fires on the
        // all-zero padding rows).
        assert_eq!(sums[batch.padded_rows() + 63], 1);
    }

    #[test]
    fn compressed_derivation_resolves_degenerates_like_sliced() {
        // Killer pair drops (but keeps its commit cycle); exclude-only
        // folds into base_sums — identical to derive_sliced.
        let prog = SoaProgram {
            feats: vec![0, 0, 0],
            masks: vec![0, 0, u32::MAX],
            clauses: vec![
                ClauseSeg { start: 0, end: 0, class: 0, pol: -1 }, // exclude-only
                ClauseSeg { start: 0, end: 1, class: 1, pol: 1 },  // f0
                ClauseSeg { start: 1, end: 3, class: 1, pol: 1 },  // f0 AND !f0
            ],
            max_feat: Some(0),
        };
        let comp = derive_compressed(&prog, 2);
        let sliced = derive_sliced(&prog, 2);
        assert_eq!(comp.clause_count(), 1);
        assert_eq!(comp.total_clauses, 3);
        assert_eq!(comp.base_sums, sliced.base_sums);
        assert_eq!(comp.base_sums, vec![-1, 0]);

        let rows = vec![vec![1u8], vec![0u8]];
        let batch = pack_literals_sliced(&rows);
        let padded = batch.padded_rows();
        let mut comp_sums = vec![0i32; 2 * padded];
        let mut sliced_sums = vec![0i32; 2 * padded];
        assert_eq!(
            comp.execute_into(&batch, &mut comp_sums, &mut Vec::new()),
            sliced.execute_into(&batch, &mut sliced_sums, &mut Vec::new())
        );
        assert_eq!(comp_sums, sliced_sums);
    }

    #[test]
    fn compressed_early_exit_never_changes_sums() {
        // A 3-include clause that dies on its first literal for every
        // row: the early-exit must skip the rest without touching sums,
        // exactly as the dense AND would produce an all-zero word.
        let prog = SoaProgram {
            feats: vec![0, 1, 2, 0],
            masks: vec![0, 0, 0, 0],
            clauses: vec![
                ClauseSeg { start: 0, end: 3, class: 0, pol: 1 }, // f0 AND f1 AND f2
                ClauseSeg { start: 3, end: 4, class: 0, pol: -1 }, // f0
            ],
            max_feat: Some(2),
        };
        let comp = derive_compressed(&prog, 1);
        let sliced = derive_sliced(&prog, 1);
        // Every row has f0 = 0, so clause 0's seed word is zero.
        let rows = vec![vec![0u8, 1, 1]; 70];
        let batch = pack_literals_sliced(&rows);
        let padded = batch.padded_rows();
        let mut comp_sums = vec![0i32; padded];
        let mut sliced_sums = vec![0i32; padded];
        comp.execute_into(&batch, &mut comp_sums, &mut Vec::new());
        sliced.execute_into(&batch, &mut sliced_sums, &mut Vec::new());
        assert_eq!(comp_sums, sliced_sums);
        assert!(comp_sums.iter().all(|&v| v == 0));
    }

    #[test]
    fn compressed_pruning_is_opt_in_and_counted() {
        // Pruning drops clauses with MORE than max_includes entries;
        // the modeled commit count shrinks with them, and the pruned
        // counter reports exactly what was lost.  The unpruned
        // derivation of the same program keeps everything.
        let prog = SoaProgram {
            feats: vec![0, 0, 1, 2],
            masks: vec![0, 0, 0, 0],
            clauses: vec![
                ClauseSeg { start: 0, end: 1, class: 0, pol: 1 },  // f0 (1 include)
                ClauseSeg { start: 1, end: 4, class: 0, pol: -1 }, // f0 AND f1 AND f2
            ],
            max_feat: Some(2),
        };
        let mut pruned = CompressedProgram::default();
        derive_compressed_pruned_into(&prog, 1, 2, &mut pruned);
        assert_eq!(pruned.clause_count(), 1);
        assert_eq!(pruned.pruned, 1);
        assert_eq!(pruned.total_clauses, 1, "pruned clause loses its commit cycle");

        let full = derive_compressed(&prog, 1);
        assert_eq!(full.clause_count(), 2);
        assert_eq!(full.pruned, 0);
        assert_eq!(full.total_clauses, 2);

        // On an all-ones row the pruned program diverges (+1 vs 0) —
        // the reason pruning must never ride the equivalence path.
        let batch = pack_literals_sliced(&[vec![1u8, 1, 1]]);
        let padded = batch.padded_rows();
        let (mut ps, mut fs) = (vec![0i32; padded], vec![0i32; padded]);
        pruned.execute_into(&batch, &mut ps, &mut Vec::new());
        full.execute_into(&batch, &mut fs, &mut Vec::new());
        assert_eq!(ps[0], 1);
        assert_eq!(fs[0], 0);
    }

    #[test]
    fn compressed_derivation_reuses_buffers() {
        let instrs = vec![Instr::new(false, false, false, 0, false)];
        let prog = predecode(&instrs, 1, 8).unwrap();
        let mut comp = derive_compressed(&prog, 1);
        assert_eq!(comp.clause_count(), 1);
        let killer = vec![
            Instr::new(false, false, false, 0, false),
            Instr::new(false, false, false, 1, true),
        ];
        let prog2 = predecode(&killer, 1, 8).unwrap();
        derive_compressed_into(&prog2, 1, &mut comp);
        assert_eq!(comp.clause_count(), 0);
        assert_eq!(comp.total_clauses, 1);
        assert_eq!(comp.base_sums, vec![0]);
        assert_eq!(comp.include_bytes(), 0);
        comp.clear();
        assert_eq!(comp.classes, 0);
        assert_eq!(comp.density, 0.0);
    }
}
