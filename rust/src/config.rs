//! Workload shapes and the `artifacts/manifest.tsv` loader.
//!
//! A [`TMShape`] is the static architecture of one TM workload: feature
//! count, class count, clauses per class, and the training hyperparameters
//! baked into its AOT artifacts.  The authoritative source is the manifest
//! emitted by `python -m compile.aot` (TSV twin of manifest.json — the
//! offline build has no JSON crate); shapes used by pure-simulator tests
//! can also be constructed directly.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Static architecture + hyperparameters of one TM workload.
#[derive(Debug, Clone, PartialEq)]
pub struct TMShape {
    pub name: String,
    pub features: usize,
    pub classes: usize,
    /// Clauses per class; polarity alternates +,- within a class.
    pub clauses: usize,
    /// Class-sum clamp used by training feedback.
    pub t: i32,
    /// Specificity (Type I decrement probability 1/s).
    pub s: f64,
    pub train_batch: usize,
    pub n_states: i32,
}

impl TMShape {
    /// Literals L = 2F (feature, complement interleaved).
    pub fn literals(&self) -> usize {
        2 * self.features
    }

    /// Total clauses K = M * C.
    pub fn total_clauses(&self) -> usize {
        self.classes * self.clauses
    }

    /// Total TAs in the dense model (the paper's 3,136,000 for MNIST).
    pub fn total_tas(&self) -> usize {
        self.total_clauses() * self.literals()
    }

    /// A synthetic shape for tests.
    pub fn synthetic(features: usize, classes: usize, clauses: usize) -> Self {
        TMShape {
            name: format!("synth_{features}f_{classes}m_{clauses}c"),
            features,
            classes,
            clauses,
            t: (clauses as i32 / 2 - 1).max(1),
            s: 3.0,
            train_batch: 32,
            n_states: 128,
        }
    }
}

/// One artifact pair (inference + train step) described by the manifest.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub shape: TMShape,
    pub infer_hlo: String,
    pub train_hlo: String,
}

/// Parsed `artifacts/manifest.tsv`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub configs: BTreeMap<String, ManifestEntry>,
    pub root: PathBuf,
}

impl Manifest {
    /// Parse the TSV manifest text (exposed for unit tests).
    pub fn parse(text: &str, root: PathBuf) -> anyhow::Result<Self> {
        let mut lines = text.lines();
        let header: Vec<&str> = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty manifest"))?
            .split('\t')
            .collect();
        let col = |name: &str| -> anyhow::Result<usize> {
            header
                .iter()
                .position(|&h| h == name)
                .ok_or_else(|| anyhow::anyhow!("manifest missing column {name}"))
        };
        let (c_name, c_feat, c_cls, c_clu) = (col("name")?, col("features")?, col("classes")?, col("clauses")?);
        let (c_t, c_s, c_batch, c_n) = (col("T")?, col("s")?, col("train_batch")?, col("n_states")?);
        let (c_inf, c_trn) = (col("infer_hlo")?, col("train_hlo")?);

        let mut configs = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(f.len() == header.len(), "manifest row {i}: field count");
            let shape = TMShape {
                name: f[c_name].to_string(),
                features: f[c_feat].parse()?,
                classes: f[c_cls].parse()?,
                clauses: f[c_clu].parse()?,
                t: f[c_t].parse()?,
                s: f[c_s].parse()?,
                train_batch: f[c_batch].parse()?,
                n_states: f[c_n].parse()?,
            };
            configs.insert(
                shape.name.clone(),
                ManifestEntry {
                    shape,
                    infer_hlo: f[c_inf].to_string(),
                    train_hlo: f[c_trn].to_string(),
                },
            );
        }
        Ok(Manifest { configs, root })
    }

    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.tsv"))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Locate the artifacts directory relative to the repo root (works
    /// from `cargo test`, benches and examples).
    pub fn load_default() -> anyhow::Result<Self> {
        for c in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(c).join("manifest.tsv").exists() {
                return Self::load(c);
            }
        }
        anyhow::bail!("artifacts/manifest.tsv not found; run `make artifacts`")
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&ManifestEntry> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no config named {name} in manifest"))
    }

    pub fn infer_hlo_path(&self, name: &str) -> anyhow::Result<PathBuf> {
        Ok(self.root.join(&self.entry(name)?.infer_hlo))
    }

    pub fn train_hlo_path(&self, name: &str) -> anyhow::Result<PathBuf> {
        Ok(self.root.join(&self.entry(name)?.train_hlo))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_arithmetic_matches_paper_example() {
        // Paper §1: MNIST with 784 features -> 1568 literals; 200 clauses
        // x 10 classes -> 3,136,000 TAs.
        let s = TMShape {
            name: "mnist".into(),
            features: 784,
            classes: 10,
            clauses: 200,
            t: 50,
            s: 10.0,
            train_batch: 32,
            n_states: 128,
        };
        assert_eq!(s.literals(), 1568);
        assert_eq!(s.total_clauses(), 2000);
        assert_eq!(s.total_tas(), 3_136_000);
    }

    #[test]
    fn synthetic_shape_has_attainable_t() {
        let s = TMShape::synthetic(8, 3, 10);
        assert!(s.t < s.clauses as i32 / 2);
        assert!(s.t >= 1);
    }

    #[test]
    fn parse_tsv_roundtrip() {
        let text = "name\tfeatures\tclasses\tclauses\tT\ts\ttrain_batch\tn_states\tinfer_hlo\ttrain_hlo\n\
                    emg\t64\t6\t100\t20\t3.0\t32\t128\ti.hlo.txt\tt.hlo.txt\n";
        let m = Manifest::parse(text, PathBuf::from("/tmp")).unwrap();
        let e = m.entry("emg").unwrap();
        assert_eq!(e.shape.features, 64);
        assert_eq!(e.shape.t, 20);
        assert_eq!(e.shape.s, 3.0);
        assert_eq!(m.infer_hlo_path("emg").unwrap(), PathBuf::from("/tmp/i.hlo.txt"));
    }

    #[test]
    fn parse_rejects_missing_column() {
        assert!(Manifest::parse("name\tfeatures\n", PathBuf::new()).is_err());
    }

    #[test]
    fn parse_rejects_ragged_row() {
        let text = "name\tfeatures\tclasses\tclauses\tT\ts\ttrain_batch\tn_states\tinfer_hlo\ttrain_hlo\nbad\t1\n";
        assert!(Manifest::parse(text, PathBuf::new()).is_err());
    }

    #[test]
    fn manifest_loads_if_built() {
        if let Ok(m) = Manifest::load_default() {
            assert!(m.configs.contains_key("quickstart"));
            let e = m.entry("mnist").unwrap();
            assert_eq!(e.shape.literals(), 2 * e.shape.features);
            assert!(m.infer_hlo_path("mnist").unwrap().exists());
        }
    }
}
