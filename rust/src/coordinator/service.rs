//! The inference service: accelerator ownership, request execution,
//! live reprogramming, metrics.

use std::time::Instant;

use crate::accel::core::{AccelConfig, Core, CoreError};
use crate::accel::engine as sched;
use crate::accel::multicore::{MultiCore, ParallelMode};
use crate::tm::model::TMModel;
use crate::trainer::online::{FeedbackError, OnlineTrainer};

/// Buildable description of an accelerator engine.  [`Engine`] itself is
/// not `Clone` (it owns memories, FIFOs and lifetime counters), but the
/// replica pool needs to construct N identical replicas and re-construct
/// one after a panic — the spec is the cloneable recipe for that.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    Single(AccelConfig),
    Multi {
        cores: usize,
        per_core: AccelConfig,
        parallel: ParallelMode,
    },
}

impl EngineSpec {
    pub fn base() -> Self {
        EngineSpec::Single(AccelConfig::base())
    }
    pub fn single_core() -> Self {
        EngineSpec::Single(AccelConfig::single_core())
    }
    pub fn five_core() -> Self {
        EngineSpec::Multi {
            cores: 5,
            per_core: AccelConfig::multicore_core(),
            parallel: ParallelMode::Auto,
        }
    }
    pub fn custom(cfg: AccelConfig) -> Self {
        EngineSpec::Single(cfg)
    }

    /// Construct a fresh engine from the spec.
    pub fn build(&self) -> Engine {
        match self {
            EngineSpec::Single(cfg) => Engine::Single(Core::new(cfg.clone())),
            EngineSpec::Multi { cores, per_core, parallel } => {
                Engine::Multi(MultiCore::new(*cores, per_core.clone()).with_parallel(*parallel))
            }
        }
    }
}

/// Which accelerator build serves requests.
pub enum Engine {
    Single(Core),
    Multi(MultiCore),
}

impl Engine {
    pub fn base() -> Self {
        Engine::Single(Core::new(AccelConfig::base()))
    }
    pub fn single_core() -> Self {
        Engine::Single(Core::new(AccelConfig::single_core()))
    }
    pub fn five_core() -> Self {
        Engine::Multi(MultiCore::five_core())
    }

    /// A single core with a customized configuration (e.g. the Fig 6
    /// deeper-memory deployments).
    pub fn custom(cfg: AccelConfig) -> Self {
        Engine::Single(Core::new(cfg))
    }

    /// The cloneable recipe this engine was built from (for spawning
    /// replica pools off an already-constructed engine).
    pub fn to_spec(&self) -> EngineSpec {
        match self {
            Engine::Single(c) => EngineSpec::Single(c.cfg.clone()),
            Engine::Multi(m) => EngineSpec::Multi {
                cores: m.n_cores(),
                per_core: m.cores[0].cfg.clone(),
                parallel: m.parallel,
            },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Single(c) => c.cfg.name,
            Engine::Multi(_) => "multicore_x5",
        }
    }

    pub fn program_model(&mut self, model: &TMModel) -> Result<(), CoreError> {
        match self {
            Engine::Single(c) => c.program_model(model),
            Engine::Multi(m) => m.program_model(model),
        }
    }

    /// FNV-1a digest of every derived program buffer this engine
    /// executes from (see [`Core::program_digest`]); `None` until
    /// programmed.  The scrub layer records this at fence time and
    /// re-verifies it before serving and on scrub ticks.
    pub fn program_digest(&self) -> Option<u64> {
        match self {
            Engine::Single(c) => c.program_digest(),
            Engine::Multi(m) => m.program_digest(),
        }
    }

    /// Fault injection: flip `n_bits` seeded bits in THIS engine's own
    /// derived-program copy (never a shared model Arc).  Returns bits
    /// flipped (0 when unprogrammed).
    pub fn flip_program_bits(&mut self, seed: u64, n_bits: u32) -> u32 {
        match self {
            Engine::Single(c) => c.flip_program_bits(seed, n_bits),
            Engine::Multi(m) => m.flip_program_bits(seed, n_bits),
        }
    }

    /// Run up to 32 datapoints; returns (preds, simulated batch cycles).
    ///
    /// Malformed requests (empty, >32 rows, ragged widths) are rejected
    /// with [`CoreError::BadBatch`] — the packing layer would panic on
    /// them, and a request must never be able to kill a serving worker.
    pub fn run_rows(&mut self, rows: &[Vec<u8>]) -> Result<(Vec<usize>, u64), CoreError> {
        sched::validate_rows(rows, 32)?;
        match self {
            Engine::Single(c) => {
                let packed = crate::isa::pack_features(rows);
                let r = c.run_batch(&packed)?;
                Ok((
                    r.preds[..rows.len()].iter().map(|&p| p as usize).collect(),
                    r.cycles.total(),
                ))
            }
            Engine::Multi(m) => {
                let packed = crate::isa::pack_features(rows);
                let r = m.run_batch(&packed)?;
                Ok((
                    r.preds[..rows.len()].iter().map(|&p| p as usize).collect(),
                    r.batch_cycles,
                ))
            }
        }
    }

    pub fn freq_mhz(&self) -> f64 {
        match self {
            Engine::Single(c) => c.cfg.freq_mhz,
            Engine::Multi(m) => m.cores[0].cfg.freq_mhz,
        }
    }
}

/// Re-exported from the batch scheduler, where the margins-aware bulk
/// paths live (`classify_rows_margins_{core,multicore}`).
pub use crate::accel::engine::margins_from_sums;

/// Service counters (simulated time is cycle-derived, not wall time).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub inferences: u64,
    pub batches: u64,
    pub reprograms: u64,
    pub simulated_cycles: u64,
    /// Host wall-clock time spent executing successful requests, in
    /// microseconds — unlike `simulated_cycles`, which is accelerator
    /// time at the configured clock.  The admission front-end's
    /// utilization view: busy time over wall time is how loaded a
    /// replica actually is, regardless of simulator speed.
    pub busy_micros: u64,
    pub errors: u64,
}

impl Metrics {
    /// Simulated accelerator busy-time in microseconds.
    pub fn simulated_us(&self, freq_mhz: f64) -> f64 {
        self.simulated_cycles as f64 / freq_mhz
    }

    /// Mean per-inference latency in microseconds.
    pub fn mean_latency_us(&self, freq_mhz: f64) -> f64 {
        if self.inferences == 0 {
            return 0.0;
        }
        self.simulated_us(freq_mhz) / self.inferences as f64
    }
}

/// A fine-tune request against a service that never opted in, or a
/// malformed feedback window.
#[derive(Debug, thiserror::Error)]
pub enum FineTuneError {
    #[error("fine-tuning is not enabled on this service (call enable_fine_tune)")]
    Disabled,
    #[error("{0}")]
    Feedback(#[from] FeedbackError),
    /// The updated model no longer fits the engine (only reachable when
    /// a reseed swapped in a larger shape than the engine provisions).
    #[error("reprogram after feedback: {0}")]
    Core(#[from] CoreError),
}

/// Accelerator + counters; every mutation goes through here so the
/// metrics can never drift from reality.
pub struct InferenceService {
    pub engine: Engine,
    pub metrics: Metrics,
    model_version: u64,
    /// Opt-in online feedback state ([`Self::enable_fine_tune`]).
    tuner: Option<OnlineTrainer>,
}

impl InferenceService {
    pub fn new(engine: Engine) -> Self {
        InferenceService {
            engine,
            metrics: Metrics::default(),
            model_version: 0,
            tuner: None,
        }
    }

    pub fn model_version(&self) -> u64 {
        self.model_version
    }

    /// Opt in to online fine-tuning: attach the incremental trainer
    /// whose TA memory future [`Self::fine_tune`] windows update.
    pub fn enable_fine_tune(&mut self, tuner: OnlineTrainer) {
        self.tuner = Some(tuner);
    }

    pub fn fine_tune_enabled(&self) -> bool {
        self.tuner.is_some()
    }

    /// The attached trainer, if fine-tuning is enabled (the pool layer
    /// re-warm-starts it when an offline retrain replaces the model).
    pub fn tuner_mut(&mut self) -> Option<&mut OnlineTrainer> {
        self.tuner.as_mut()
    }

    /// Apply one labeled feedback window to the attached trainer and
    /// reprogram the engine with the updated model — the single-service
    /// shape of the pool's `Job::Feedback` + mini-fence sequence.
    /// Feedback time lands in `busy_micros` (the replica is genuinely
    /// busy, just not inferring); `reprogram` bumps the model version
    /// like any other install.
    pub fn fine_tune(&mut self, xs: &[Vec<u8>], ys: &[usize]) -> Result<TMModel, FineTuneError> {
        let t0 = Instant::now();
        let tuner = self.tuner.as_mut().ok_or(FineTuneError::Disabled)?;
        tuner.feedback_batch(xs, ys)?;
        let model = tuner.model();
        self.metrics.busy_micros += t0.elapsed().as_micros() as u64;
        self.reprogram(&model)?;
        Ok(model)
    }

    /// Live reprogram (the paper's no-resynthesis model swap).
    pub fn reprogram(&mut self, model: &TMModel) -> Result<(), CoreError> {
        self.engine.program_model(model)?;
        self.metrics.reprograms += 1;
        self.model_version += 1;
        Ok(())
    }

    /// Digest of the engine's derived program buffers — `None` until
    /// programmed (see [`Engine::program_digest`]).
    pub fn program_digest(&self) -> Option<u64> {
        self.engine.program_digest()
    }

    /// Fault injection into this service's own program copy (see
    /// [`Engine::flip_program_bits`]).
    pub fn flip_program_bits(&mut self, seed: u64, n_bits: u32) -> u32 {
        self.engine.flip_program_bits(seed, n_bits)
    }

    /// Serve one request of up to 32 datapoints.
    pub fn infer(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let t0 = Instant::now();
        match self.engine.run_rows(rows) {
            Ok((preds, cycles)) => {
                self.metrics.inferences += rows.len() as u64;
                self.metrics.batches += 1;
                self.metrics.simulated_cycles += cycles;
                self.metrics.busy_micros += t0.elapsed().as_micros() as u64;
                Ok(preds)
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Serve an arbitrary-size request through the bulk batch scheduler
    /// ([`crate::accel::engine`]): the row stream is packed once and
    /// driven through `classify_rows_core` / `classify_rows_multicore`,
    /// so per-batch setup (and the multi-core path's thread spawn) is
    /// amortized across the whole request instead of paid per 32 rows.
    pub fn infer_all(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        // An empty *request* is a client bug (the bulk classifiers
        // accept empty streams); ragged widths are caught by the
        // classifiers' own validate_rows pass — no double scan here.
        if rows.is_empty() {
            self.metrics.errors += 1;
            return Err(CoreError::BadBatch { rows: 0, reason: "empty request" });
        }
        let t0 = Instant::now();
        let run = match &mut self.engine {
            Engine::Single(c) => sched::classify_rows_core(c, rows),
            Engine::Multi(m) => sched::classify_rows_multicore(m, rows),
        };
        match run {
            Ok((preds, stats)) => {
                self.metrics.inferences += stats.inferences;
                self.metrics.batches += stats.batches;
                self.metrics.simulated_cycles += stats.simulated_cycles;
                self.metrics.busy_micros += t0.elapsed().as_micros() as u64;
                Ok(preds)
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Serve an arbitrary-size request, returning predictions *and* the
    /// per-datapoint confidence margins — the telemetry flavour of
    /// [`Self::infer_all`] the autotuner's monitor and the canary
    /// mirror ride on.  Counters update exactly like a normal request
    /// (telemetry IS traffic).
    ///
    /// Routes through the margins-aware bulk scheduler
    /// (`classify_rows_margins_{core,multicore}`): one pack pass, a
    /// reused batch scratch, and — on a multi-core engine — the
    /// chunk-amortized thread spawn, so a probe or mirror window costs
    /// the same as the equivalent [`Self::infer_all`] call.
    pub fn infer_with_margins(
        &mut self,
        rows: &[Vec<u8>],
    ) -> Result<(Vec<usize>, Vec<i32>), CoreError> {
        if rows.is_empty() {
            self.metrics.errors += 1;
            return Err(CoreError::BadBatch { rows: 0, reason: "empty request" });
        }
        let t0 = Instant::now();
        let run = match &mut self.engine {
            Engine::Single(c) => sched::classify_rows_margins_core(c, rows),
            Engine::Multi(m) => sched::classify_rows_margins_multicore(m, rows),
        };
        match run {
            Ok((preds, margins, stats)) => {
                self.metrics.inferences += stats.inferences;
                self.metrics.batches += stats.batches;
                self.metrics.simulated_cycles += stats.simulated_cycles;
                self.metrics.busy_micros += t0.elapsed().as_micros() as u64;
                Ok((preds, margins))
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Accuracy over a labeled set (the recalibration monitor's probe).
    pub fn measure_accuracy(&mut self, xs: &[Vec<u8>], ys: &[usize]) -> Result<f64, CoreError> {
        let preds = self.infer_all(xs)?;
        let correct = preds.iter().zip(ys).filter(|(p, y)| p == y).count();
        Ok(correct as f64 / xs.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 160).noise(0.05).seed(30).generate();
        (crate::trainer::train_model(&shape, &data, 4, 2), data)
    }

    #[test]
    fn service_counts_inferences() {
        let (model, data) = trained();
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&model).unwrap();
        let preds = svc.infer_all(&data.xs).unwrap();
        assert_eq!(preds.len(), 160);
        assert_eq!(svc.metrics.inferences, 160);
        assert_eq!(svc.metrics.batches, 5);
        assert!(svc.metrics.simulated_cycles > 0);
        assert_eq!(svc.metrics.reprograms, 1);
    }

    #[test]
    fn engines_agree_on_predictions() {
        let (model, data) = trained();
        let mut a = InferenceService::new(Engine::base());
        let mut b = InferenceService::new(Engine::five_core());
        a.reprogram(&model).unwrap();
        b.reprogram(&model).unwrap();
        assert_eq!(
            a.infer_all(&data.xs).unwrap(),
            b.infer_all(&data.xs).unwrap()
        );
    }

    #[test]
    fn accuracy_probe_matches_reference() {
        let (model, data) = trained();
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&model).unwrap();
        let got = svc.measure_accuracy(&data.xs, &data.ys).unwrap();
        let want = crate::tm::reference::accuracy(&model, &data.xs, &data.ys);
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn errors_counted() {
        let mut svc = InferenceService::new(Engine::base());
        // Not programmed yet.
        assert!(svc.infer(&[vec![0u8; 12]]).is_err());
        assert_eq!(svc.metrics.errors, 1);
    }

    #[test]
    fn malformed_requests_error_instead_of_panicking() {
        let (model, data) = trained();
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&model).unwrap();

        // Empty request.
        assert!(matches!(
            svc.infer(&[]),
            Err(CoreError::BadBatch { rows: 0, .. })
        ));
        assert!(matches!(
            svc.infer_all(&[]),
            Err(CoreError::BadBatch { rows: 0, .. })
        ));
        // 33 rows in a single-batch call.
        let wide: Vec<Vec<u8>> = vec![vec![0u8; 12]; 33];
        assert!(matches!(
            svc.infer(&wide),
            Err(CoreError::BadBatch { rows: 33, .. })
        ));
        // Ragged widths.
        let ragged = vec![vec![0u8; 12], vec![0u8; 3]];
        assert!(matches!(
            svc.infer(&ragged),
            Err(CoreError::BadBatch { rows: 2, .. })
        ));
        assert!(matches!(
            svc.infer_all(&ragged),
            Err(CoreError::BadBatch { rows: 2, .. })
        ));
        assert_eq!(svc.metrics.errors, 5);

        // The service is not poisoned: a well-formed request still works.
        let preds = svc.infer_all(&data.xs).unwrap();
        assert_eq!(preds.len(), data.len());
        // >32 rows are fine on the bulk path (split into batches).
        assert_eq!(svc.infer_all(&wide).unwrap().len(), 33);
    }

    #[test]
    fn engine_spec_builds_equivalent_engines() {
        let (model, data) = trained();
        for spec in [EngineSpec::base(), EngineSpec::five_core()] {
            let mut direct = InferenceService::new(spec.build());
            let mut again = InferenceService::new(spec.build());
            direct.reprogram(&model).unwrap();
            again.reprogram(&model).unwrap();
            assert_eq!(
                direct.infer_all(&data.xs).unwrap(),
                again.infer_all(&data.xs).unwrap()
            );
        }
        // Round-trip through a built engine.
        let spec = Engine::five_core().to_spec();
        assert!(matches!(spec, EngineSpec::Multi { cores: 5, .. }));
        let mut svc = InferenceService::new(spec.build());
        svc.reprogram(&model).unwrap();
        let mut base = InferenceService::new(Engine::base());
        base.reprogram(&model).unwrap();
        assert_eq!(
            svc.infer_all(&data.xs).unwrap(),
            base.infer_all(&data.xs).unwrap()
        );
    }

    #[test]
    fn margins_match_class_sum_gap() {
        let (model, data) = trained();
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&model).unwrap();
        let (preds, margins) = svc.infer_with_margins(&data.xs).unwrap();
        assert_eq!(preds.len(), data.len());
        assert_eq!(margins.len(), data.len());
        // Cross-check against the dense reference sums.
        for ((x, &p), &m) in data.xs.iter().zip(&preds).zip(&margins) {
            let lits = crate::tm::reference::literals_from_features(x);
            let mut sums = crate::tm::reference::class_sums_dense(&model, &lits);
            assert_eq!(p, crate::tm::reference::predict_dense(&model, &lits));
            sums.sort_unstable_by(|a, b| b.cmp(a));
            assert_eq!(m, sums[0] - sums[1]);
            assert!(m >= 0, "winner minus runner-up is never negative");
        }
        // Telemetry counts as traffic.
        assert_eq!(svc.metrics.inferences, data.len() as u64);
    }

    #[test]
    fn margins_agree_across_engines() {
        let (model, data) = trained();
        let mut a = InferenceService::new(Engine::base());
        let mut b = InferenceService::new(Engine::five_core());
        a.reprogram(&model).unwrap();
        b.reprogram(&model).unwrap();
        assert_eq!(
            a.infer_with_margins(&data.xs).unwrap(),
            b.infer_with_margins(&data.xs).unwrap()
        );
    }

    #[test]
    fn margins_reject_malformed_requests() {
        let (model, _) = trained();
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&model).unwrap();
        assert!(matches!(
            svc.infer_with_margins(&[]),
            Err(CoreError::BadBatch { rows: 0, .. })
        ));
        let ragged = vec![vec![0u8; 12], vec![0u8; 3]];
        assert!(matches!(
            svc.infer_with_margins(&ragged),
            Err(CoreError::BadBatch { rows: 2, .. })
        ));
        assert_eq!(svc.metrics.errors, 2);
    }

    #[test]
    fn fine_tune_is_opt_in_and_updates_the_served_model() {
        let (model, data) = trained();
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&model).unwrap();
        // Not enabled: typed refusal, nothing mutated.
        assert!(matches!(
            svc.fine_tune(&data.xs, &data.ys),
            Err(FineTuneError::Disabled)
        ));
        assert_eq!(svc.model_version(), 1);

        svc.enable_fine_tune(OnlineTrainer::from_model(&model, 41));
        assert!(svc.fine_tune_enabled());
        let tuned = svc.fine_tune(&data.xs, &data.ys).unwrap();
        // The engine now serves the tuned model, version bumped.
        assert_eq!(svc.model_version(), 2);
        let preds = svc.infer_all(&data.xs).unwrap();
        let want: Vec<usize> = data
            .xs
            .iter()
            .map(|x| {
                let lits = crate::tm::reference::literals_from_features(x);
                crate::tm::reference::predict_dense(&tuned, &lits)
            })
            .collect();
        assert_eq!(preds, want);

        // Malformed windows surface as typed feedback errors.
        assert!(matches!(
            svc.fine_tune(&data.xs[..2], &data.ys[..1]),
            Err(FineTuneError::Feedback(_))
        ));
    }

    #[test]
    fn model_version_bumps_on_reprogram() {
        let (model, _) = trained();
        let mut svc = InferenceService::new(Engine::base());
        assert_eq!(svc.model_version(), 0);
        svc.reprogram(&model).unwrap();
        svc.reprogram(&model).unwrap();
        assert_eq!(svc.model_version(), 2);
    }
}
