//! Replica-pool request front-end: the AXIS/queue interface of the
//! deployed system scaled across N worker threads, each owning an
//! [`InferenceService`] replica, fed through the admission front-end
//! (offline toolchain has no tokio; std primitives give the same
//! shape: sharded queues, condvars, message-passing replies).
//!
//! Properties the pool guarantees (EXPERIMENTS.md §Serving,
//! §Admission and §Multi-tenant):
//!
//! * **Versioned broadcast reprogram.**  [`ServiceHandle::program`]
//!   publishes the model under a monotonically increasing version and
//!   blocks until *every* live replica has swapped (the version fence:
//!   each worker drains its in-flight request, swaps, then resumes).
//!   Once `program` returns, no later inference can observe an older
//!   model, and all replicas report the same version.
//! * **Multi-model routing.**  The pool embeds a [`ModelRegistry`];
//!   [`ServiceHandle::register_model`] adds tenants (deduplicated on
//!   `(name, content hash)` — never across tenant names) and
//!   [`ServiceHandle::with_model`] scopes a handle so
//!   every RPC on it carries that [`ModelId`] route.  Replicas hold a
//!   per-replica model *affinity*; a [`ShardingPolicy`] decides whether
//!   affinity is fixed (`Dedicated`) or traffic-driven (`TimeShared`,
//!   with a dwell-time reprogram-thrash guard).  A plain handle routes
//!   at [`ModelId::DEFAULT`], which is why single-model pools behave
//!   exactly like the pre-registry front-end.
//! * **Panic supervision.**  A request that panics its worker does not
//!   kill the pool: the panic is caught, the failing request gets a
//!   typed [`ServeError::WorkerPanicked`], and the replica is rebuilt
//!   from its [`EngineSpec`] and reprogrammed from its assigned model
//!   before taking more work.  Counters survive the respawn.
//! * **Classed admission.**  Every request carries a [`Priority`]
//!   class (`Normal` by default, `Critical` for canary mirrors).
//!   Workers pop class-major — `Critical` overtakes queued `Low`
//!   everywhere — and each class has a bounded queue with a
//!   [`ShedPolicy`] (block / reject / shed-oldest), so under overload
//!   the control plane keeps flowing while bulk traffic queues or
//!   sheds ([`ServeError::Overloaded`]).
//! * **Sharded queues with work stealing.**  Jobs are routed
//!   affinity-first to per-replica shards; a worker pops its own shard
//!   first and steals from siblings, so replicas no longer contend on
//!   one global lock and an idle replica never watches a busy one.
//! * **Deadline-aware admission.**  A request whose deadline cannot be
//!   met given current same-or-higher-class queue depth (projected by
//!   a service-time EWMA) is refused at submit with
//!   [`ServeError::DeadlineExceeded`] — not discovered at pop.  Queued
//!   requests that expire anyway are shed unexecuted by the first
//!   worker to pop them.
//! * **Autoscaling.**  With an [`AutoscaleConfig`], a supervisor
//!   thread scales the live replica count between `min..=max` from
//!   observed queue depth and deadline-miss rate (never retiring a
//!   canary, and never retiring a model's last dedicated replica).
//! * **Typed errors.**  Engine rejections ([`CoreError`], including
//!   the `BadBatch` malformed-request validation), worker panics,
//!   admission refusals, unroutable models and pool shutdown are
//!   distinct [`ServeError`] variants.
//! * **Aggregated metrics.**  [`ServiceHandle::pool_stats`] reports
//!   per-replica [`Metrics`], a pool rollup, the per-class
//!   [`AdmissionStats`] and the per-model [`ModelStats`] rollups;
//!   [`ServiceHandle::stats`] keeps the old single-service shape.
//! * **Self-healing model integrity.**  With an [`IntegrityConfig`]
//!   scrub cadence, every replica records an FNV-1a digest of its
//!   derived program buffers at fence time, re-verifies it before
//!   serving each request (and on background scrub ticks for idle
//!   replicas), and on mismatch re-derives the programs from the
//!   golden model `Arc` before any corrupted answer can leave the
//!   pool.  A replica that keeps tripping (panic respawns, failed
//!   heals) is quarantined by a per-replica circuit breaker with
//!   exponential backoff — routing, stealing and feasibility treat it
//!   like a dead replica, and a half-open verify probe gates its
//!   rejoin.  [`PoolStats::integrity`] reports the counters.

use std::collections::{HashMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{
    AdmissionConfig, AdmissionStats, AutoscaleConfig, ClassCounters, Fault, FaultArmory,
    FaultPlan, IntegrityConfig, IntegrityCounters, IntegrityStats, ModelCounters, ModelStats,
    PoolConfig, Priority, ServiceEstimator, ShedPolicy, PRIORITY_COUNT,
};
use super::registry::{ModelEntry, ModelId, ModelRegistry, RegisterOutcome};
use super::service::{EngineSpec, InferenceService, Metrics};
use crate::accel::core::CoreError;
use crate::model_cost::resources::ResourceBudget;
use crate::tm::model::TMModel;
use crate::trainer::online::{FeedbackError, OnlineTrainer};

/// Snapshot returned by [`ServiceHandle::stats`] (the pool rollup).
pub type ServerStats = Metrics;

/// Errors a request can come back with.  Worker death, engine
/// rejection, admission refusal and shutdown are distinguishable, so a
/// client can retry, back off, fix its request, or stop.
#[derive(Debug, thiserror::Error)]
pub enum ServeError {
    /// The engine rejected the request (malformed batch, model not
    /// programmed, model too big, …).  The replica is fine.
    #[error(transparent)]
    Core(#[from] CoreError),
    /// The replica serving this request panicked.  It has been rebuilt
    /// from its assigned model; retrying on the pool is safe.
    #[error("replica {replica} panicked serving this request (replica respawned)")]
    WorkerPanicked { replica: usize },
    /// The pool has been shut down; no further requests are accepted.
    #[error("service pool is shut down")]
    ShutDown,
    /// A worker dropped the reply without answering (worker death that
    /// supervision could not intercept).
    #[error("replica worker died without replying")]
    WorkerGone,
    /// A canary operation could not proceed (no canary active, pool too
    /// small to dedicate a replica, no baseline model to fall back to).
    #[error("canary: {0}")]
    Canary(&'static str),
    /// The request's deadline passed before a replica produced an
    /// answer, or admission projected it could never be met (see
    /// [`ServiceHandle::infer_deadline`]).  The pool is fine — the job
    /// was refused at submit, dropped unexecuted by the first worker to
    /// pick it up, or its late answer was discarded.
    #[error("request deadline exceeded before a replica could serve it")]
    DeadlineExceeded,
    /// The request's class queue is at capacity and its backpressure
    /// policy refuses new work (`Reject`), or this request was evicted
    /// by a newer one (`ShedOldest`).  Retry with backoff, downgrade,
    /// or drop — the pool is saturated, not broken.
    #[error("pool overloaded: request refused by admission control")]
    Overloaded,
    /// The request's model route has no live replica pinned (or
    /// pinnable) to it under the `Dedicated` sharding policy — every
    /// eligible replica is dedicated to a different model.  Register
    /// the model on a larger pool or switch to `TimeShared`.
    #[error("model {model} has no live replica under the Dedicated sharding policy")]
    NoReplica { model: ModelId },
    /// The model id is not (or no longer) in the pool's registry.
    /// Queued requests for a retiring model are failed with this.
    #[error("model {0} is not registered")]
    UnknownModel(ModelId),
    /// A feedback window was submitted for a route that never opted in
    /// ([`ServiceHandle::enable_online_feedback`]).  Online TA updates
    /// mutate serving state, so they are strictly opt-in per model.
    #[error("model {0} has online feedback disabled (call enable_online_feedback)")]
    FeedbackDisabled(ModelId),
    /// The feedback window itself was malformed (row/label count
    /// mismatch, wrong feature width, out-of-range label).  Nothing was
    /// applied; the trainer and the served model are untouched.
    #[error("feedback: {0}")]
    Feedback(#[from] FeedbackError),
}

/// Per-replica snapshot inside [`PoolStats`].
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub metrics: Metrics,
    /// Last model version this replica acknowledged (see
    /// [`PoolStats::version`]).
    pub model_version: u64,
    /// Times this replica was rebuilt after a caught panic.
    pub respawns: u64,
    pub alive: bool,
    /// Model this replica is currently affine to (programs at fences,
    /// serves Pool traffic for).  `None` until first assignment.
    pub assigned: Option<ModelId>,
    /// When this replica hosts a canary: the model whose candidate it
    /// is evaluating.
    pub canary_of: Option<ModelId>,
}

/// Aggregated pool snapshot: per-replica metrics plus the rollup, the
/// per-class admission counters and the per-model rollups.
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub replicas: Vec<ReplicaStats>,
    /// Rollup across replicas: counters are summed; `reprograms` is the
    /// pool model VERSION — one bump per `program` broadcast, per
    /// canary program/dismiss, and per registry mutation (not the
    /// per-replica reprogram sum).
    pub total: Metrics,
    /// Current target model version (bumped by every fence-raising
    /// operation: program, canary lifecycle, register/retire, and
    /// `TimeShared` replica switches onto registered models).
    pub version: u64,
    /// Replica serving a canary candidate FOR THIS HANDLE'S ROUTE, if
    /// any (the single-model view; [`PoolStats::canaries`] lists all).
    pub canary: Option<usize>,
    /// Every active canary, `(model, replica)`, sorted by model id.
    pub canaries: Vec<(ModelId, usize)>,
    /// Per-class admission counters plus autoscaler activity.
    pub admission: AdmissionStats,
    /// Per-model counter rollups, sorted by model id (only routes that
    /// carried traffic or were registered appear).
    pub models: Vec<ModelStats>,
    /// Replica self-reassignments between models (`TimeShared`
    /// adoption; the reprogram-thrash numerator, pool-wide).
    pub sharding_switches: u64,
    /// Scrub-and-repair plus circuit-breaker counters.  All zero
    /// unless the pool was spawned with an [`IntegrityConfig`] scrub
    /// cadence.
    pub integrity: IntegrityStats,
}

/// One telemetry probe reply: predictions, per-datapoint confidence
/// margins (top-1 minus top-2 class sum), and the pool model version
/// the serving replica ran — the feed of the autotune monitor
/// ([`crate::coordinator::autotune`]).
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub preds: Vec<usize>,
    pub margins: Vec<i32>,
    /// Pool version fence value the replica had acknowledged when it
    /// served this probe.
    pub model_version: u64,
}

/// How replicas relate to the models they serve.
///
/// Parsed from the CLI via [`std::str::FromStr`] (`"dedicated"`,
/// `"time-shared"`).
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum ShardingPolicy {
    /// Replicas are pinned to a model at registration rebalance and
    /// never reprogram for traffic.  A model whose pinned replicas are
    /// all gone is unroutable ([`ServeError::NoReplica`]) — strict
    /// per-tenant isolation, zero reprogram jitter.
    Dedicated,
    /// Affinity-aware routing: requests prefer an affine replica, and a
    /// replica adopts (reprograms onto) a foreign model only when no
    /// affine replica is free — rate-limited by `dwell`, the minimum
    /// time a replica holds a model before it may switch again (the
    /// reprogram-thrash guard).
    TimeShared {
        /// Minimum residency before a replica may switch models again.
        dwell: Duration,
    },
}

impl ShardingPolicy {
    /// [`ShardingPolicy::TimeShared`] with the default 25 ms dwell —
    /// long enough to amortize a reprogram, short enough to follow
    /// shifting tenant mixes.
    pub fn time_shared() -> Self {
        ShardingPolicy::TimeShared { dwell: Duration::from_millis(25) }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardingPolicy::Dedicated => "dedicated",
            ShardingPolicy::TimeShared { .. } => "time-shared",
        }
    }
}

impl Default for ShardingPolicy {
    fn default() -> Self {
        ShardingPolicy::time_shared()
    }
}

impl std::fmt::Display for ShardingPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ShardingPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "dedicated" => Ok(ShardingPolicy::Dedicated),
            "time-shared" | "timeshared" | "time_shared" => Ok(ShardingPolicy::time_shared()),
            other => Err(format!(
                "unknown sharding policy {other:?} (expected dedicated|time-shared)"
            )),
        }
    }
}

/// Which replicas may serve a job.  `Pool(m)` is live traffic for
/// model `m`: served by replicas affine to `m` (or adopting it under
/// `TimeShared`), never by a canary replica.  `CanaryOnly(m)` is the
/// mirrored evaluation stream for `m`'s candidate, served exclusively
/// by `m`'s canary replica.  `Any` is model-agnostic work (stall
/// injection) that any non-canary replica may take.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
enum Target {
    Pool(ModelId),
    CanaryOnly(ModelId),
    Any,
}

/// One queued unit of work.  The class it was admitted under is the
/// queue it sits in, not a field; the per-model counter handle rides
/// along so pop/shed sites can mirror without a directory lookup.
enum Job {
    Infer {
        rows: Vec<Vec<u8>>,
        target: Target,
        /// Expiry instant of a deadline request: a worker that pops an
        /// already-expired job replies [`ServeError::DeadlineExceeded`]
        /// without executing it, so a saturated queue sheds abandoned
        /// work instead of computing answers nobody is waiting for.
        deadline: Option<Instant>,
        mstats: Option<Arc<ModelCounters>>,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Fault injection: occupy the owning worker for `dur` (tests and
    /// chaos drills — the deterministic "saturated pool" for deadline
    /// coverage).
    Stall {
        dur: Duration,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Inference plus the confidence-margin telemetry the drift monitor
    /// and the canary comparator consume.  Rides the same queues as
    /// plain requests — telemetry IS traffic, so the monitor observes
    /// exactly what clients do.
    Telemetry {
        rows: Vec<Vec<u8>>,
        target: Target,
        /// Same shed-unexecuted expiry semantics as `Infer::deadline`.
        deadline: Option<Instant>,
        mstats: Option<Arc<ModelCounters>>,
        reply: mpsc::Sender<Result<Telemetry, ServeError>>,
    },
    /// Fault injection: panic inside the owning worker.  Exercises the
    /// real supervision path (tests, chaos drills) — targetable, so a
    /// canary replica's respawn-with-candidate path is reachable too.
    Crash {
        target: Target,
        mstats: Option<Arc<ModelCounters>>,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Online-feedback control job: ONE replica applies the labeled
    /// window to the route's [`OnlineTrainer`] (serialized by the
    /// trainer map's lock) and replies with the updated model snapshot;
    /// the submitting handle then installs that snapshot behind the
    /// regular version fence, which re-derives every replica's
    /// Soa/Sliced/Compressed programs — a mini-fence broadcast shaped
    /// exactly like a canary promote.
    Feedback {
        xs: Vec<Vec<u8>>,
        ys: Vec<usize>,
        target: Target,
        mstats: Option<Arc<ModelCounters>>,
        reply: mpsc::Sender<Result<Arc<TMModel>, ServeError>>,
    },
    /// Background integrity scrub: replica `replica` recomputes its
    /// program digest, compares it with the fence-time record, and
    /// heals from the golden model on mismatch.  Control work with no
    /// reply channel and no model counters; it rides the `Low` queue
    /// of its replica's own shard and is never stolen by siblings
    /// (the digest belongs to exactly one engine).
    Scrub { replica: usize },
}

impl Job {
    fn target(&self) -> Target {
        match self {
            Job::Infer { target, .. }
            | Job::Telemetry { target, .. }
            | Job::Crash { target, .. }
            | Job::Feedback { target, .. } => *target,
            // Stalls are a pool-wide chaos tool, never model-routed.
            // Scrubs are replica-pinned by [`next_job`]'s pop filter,
            // not by target.
            Job::Stall { .. } | Job::Scrub { .. } => Target::Any,
        }
    }

    fn deadline(&self) -> Option<Instant> {
        match self {
            Job::Infer { deadline, .. } | Job::Telemetry { deadline, .. } => *deadline,
            // Feedback is control work: it must never be shed on a
            // deadline — a dropped window is silently lost training.
            Job::Stall { .. } | Job::Crash { .. } | Job::Feedback { .. } | Job::Scrub { .. } => {
                None
            }
        }
    }

    /// Per-model counter handle attached at submit (None for untargeted
    /// work).
    fn mstats(&self) -> Option<&Arc<ModelCounters>> {
        match self {
            Job::Infer { mstats, .. }
            | Job::Telemetry { mstats, .. }
            | Job::Crash { mstats, .. }
            | Job::Feedback { mstats, .. } => mstats.as_ref(),
            Job::Stall { .. } | Job::Scrub { .. } => None,
        }
    }

    fn attach(&mut self, counters: Option<Arc<ModelCounters>>) {
        match self {
            Job::Infer { mstats, .. }
            | Job::Telemetry { mstats, .. }
            | Job::Crash { mstats, .. }
            | Job::Feedback { mstats, .. } => *mstats = counters,
            Job::Stall { .. } | Job::Scrub { .. } => {}
        }
    }

    /// Reply with a typed error without executing (shed, eviction,
    /// canary drain).
    fn fail(self, err: impl FnOnce() -> ServeError) {
        match self {
            Job::Infer { reply, .. } | Job::Crash { reply, .. } | Job::Stall { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
            Job::Telemetry { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
            Job::Feedback { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
            // No reply channel: a shed scrub just evaporates (the
            // scrubber re-issues one next tick).
            Job::Scrub { .. } => {}
        }
    }

    /// Reply with a canary error (the job was targeted at a canary that
    /// no longer exists).
    fn fail_canary(self, reason: &'static str) {
        self.fail(|| ServeError::Canary(reason));
    }
}

/// One replica's work-queue shard: a bounded-by-admission FIFO per
/// priority class.  Workers pop their own shard first, then steal.
#[derive(Default)]
struct ShardQueue {
    /// Per-class FIFOs, indexed by [`Priority::index`].
    classes: [VecDeque<Job>; PRIORITY_COUNT],
    /// Set at pool teardown: a closed shard accepts no new jobs, so a
    /// submission racing the last replica's death cannot strand its
    /// client.
    closed: bool,
}

#[derive(Default)]
struct Shard {
    q: Mutex<ShardQueue>,
}

/// An active canary: one replica serving a candidate for `model_id`
/// while the rest of the pool stays on the registered models.  At most
/// one canary per model; canaries of different models occupy distinct
/// replicas (multi-canary: racing K candidates on K replicas).
struct CanaryCell {
    model_id: ModelId,
    replica: usize,
    candidate: Arc<TMModel>,
}

/// The versioned model cell — the fence state plus the registry and
/// the per-replica affinity table.
struct ModelCell {
    /// Target version; bumped by every fence-raising mutation —
    /// program broadcasts, canary lifecycle, register/retire
    /// rebalances, and `TimeShared` adoption switches — so versions
    /// stay strictly monotone across all of them.
    version: u64,
    /// Registered models (the authoritative model table).  The
    /// single-model wrappers install under [`ModelId::DEFAULT`].
    registry: ModelRegistry,
    /// Per-replica model affinity: which registered model each replica
    /// programs at a fence and serves Pool traffic for.
    assign: Vec<Option<ModelId>>,
    /// Active canaries (at most one per model, distinct replicas).
    canaries: Vec<CanaryCell>,
    /// Per-replica acknowledged version (monotone).
    acks: Vec<u64>,
    /// Per-replica swap failure, tagged with the version it failed at.
    errors: Vec<Option<(u64, CoreError)>>,
    alive: Vec<bool>,
}

impl ModelCell {
    fn canary_for(&self, m: ModelId) -> Option<&CanaryCell> {
        self.canaries.iter().find(|c| c.model_id == m)
    }

    fn canary_on(&self, replica: usize) -> Option<&CanaryCell> {
        self.canaries.iter().find(|c| c.replica == replica)
    }

    fn is_canary(&self, replica: usize) -> bool {
        self.canary_on(replica).is_some()
    }
}

#[derive(Clone, Default)]
struct ReplicaMetrics {
    metrics: Metrics,
    respawns: u64,
}

/// Per-replica circuit-breaker flap tracker.  A "trip" is a panic
/// respawn or a failed heal; `breaker_trips` of them inside the
/// rolling `breaker_window` quarantine the replica for
/// `quarantine_base * 2^level` (capped at `quarantine_max`), after
/// which a half-open verify probe gates its rejoin.  `level` is NOT
/// reset on rejoin: a repeat offender serves exponentially longer
/// holds.
#[derive(Default)]
struct BreakerState {
    /// Trip instants inside the rolling window (pruned on every trip).
    trips: Vec<Instant>,
    /// Quarantine count so far — the backoff exponent.
    level: u32,
    /// End of the current quarantine hold; `None` when routable.
    until: Option<Instant>,
}

struct Shared {
    /// Per-replica work-queue shards; workers pop their own shard first
    /// and steal from siblings, class-major.
    shards: Vec<Shard>,
    /// Guards parking of idle workers and blocked submitters.  Held
    /// only to park or wake — never while queueing or serving.
    park: Mutex<()>,
    /// Workers park here when every shard they can serve is empty.
    work_cv: Condvar,
    /// Submitters blocked by a full class queue (`ShedPolicy::Block`)
    /// park here until a pop frees a slot.
    space_cv: Condvar,
    /// Bumped under `park` by every enqueue, fence and shutdown wake; a
    /// worker records it before scanning the shards and refuses to park
    /// if it moved — the lost-wakeup guard, without holding any shard
    /// lock while parked.
    epoch: AtomicU64,
    shutdown: AtomicBool,
    /// Submitters currently blocked on a full class queue (lets the pop
    /// hot path skip the park lock when nobody waits).
    space_waiters: AtomicUsize,
    /// Round-robin cursor for Pool job routing.
    rr: AtomicUsize,
    /// Admission policy (per-class caps and shed policies).
    config: AdmissionConfig,
    /// Per-class admission accounting, indexed by [`Priority::index`].
    counters: [ClassCounters; PRIORITY_COUNT],
    /// Service-time EWMA feeding deadline-aware admission.
    estimator: ServiceEstimator,
    /// Lock-free liveness mirror of `cell.alive` (routing and
    /// feasibility read it without the cell lock).
    alive_mirror: Vec<AtomicBool>,
    /// Lock-free mirror of `cell.assign`: `0` = unassigned, else
    /// `model_id + 1`.  Routing and the Dedicated reachability check
    /// read it without the cell lock; the authoritative table stays in
    /// the cell.
    assign_mirror: Vec<AtomicU64>,
    /// Scale-down requests from the supervisor; the flagged worker
    /// exits at its next pop instead of taking work.
    retire: Vec<AtomicBool>,
    /// Set when a worker thread has fully exited (its DeathWatch ran);
    /// the supervisor only revives slots whose previous thread is gone.
    exited: Vec<AtomicBool>,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    /// Worker threads started by the supervisor after spawn (joined by
    /// [`PoolJoin`]).
    extra_workers: Mutex<Vec<JoinHandle<()>>>,
    /// Armed fault plans, polled by workers once per popped job.
    faults: FaultArmory,
    cell: Mutex<ModelCell>,
    /// Wakes `program` callers waiting on replica acks.
    fence_cv: Condvar,
    /// Mirror of `cell.version`, readable without the cell lock (the
    /// workers' pop loop polls it; never lock cell inside a shard
    /// lock).
    version: AtomicU64,
    /// Lock-free mirror of `cell.canaries` by replica: `0` = this
    /// replica hosts no canary, else `model_id + 1` of the model whose
    /// candidate it serves.  Routing and the submit-time canary check
    /// poll it alongside `version`.
    canary_mirror: Vec<AtomicU64>,
    /// Number of active canaries (fast-path gate: zero means every
    /// canary_mirror slot is zero).
    canary_count: AtomicUsize,
    /// Set once a second model route appears; single-model pools keep
    /// the notify_one submit hot path.
    multi_model: AtomicBool,
    /// Replica self-reassignments between models (`TimeShared`
    /// adoption) — the pool-wide thrash counter.
    switches: AtomicU64,
    /// Per-model counter directory, keyed by `ModelId.0`, created on
    /// first touch (register, program, or first routed request).
    model_dir: Mutex<HashMap<u64, Arc<ModelCounters>>>,
    sharding: ShardingPolicy,
    metrics: Mutex<Vec<ReplicaMetrics>>,
    spec: EngineSpec,
    /// Opt-in online trainers, keyed by `ModelId.0`.  A `Job::Feedback`
    /// locks the route's trainer on one replica, applies the window,
    /// and the resulting model is re-installed through the version
    /// fence like any other program — so the sliced/compressed
    /// programs are re-derived once and broadcast, never per-replica.
    online: Mutex<HashMap<u64, OnlineTrainer>>,
    /// Scrub cadence + breaker policy (from [`PoolConfig::integrity`]).
    /// `scrub_interval: None` turns the whole integrity layer off.
    integrity_cfg: IntegrityConfig,
    /// Live scrub/heal/breaker counters ([`PoolStats::integrity`]).
    integrity: IntegrityCounters,
    /// Per-replica program digest recorded at the last successful
    /// fence program (`0` = nothing recorded: unprogrammed replica or
    /// scrubbing off).  Workers verify against it before serving.
    digests: Vec<AtomicU64>,
    /// Lock-free quarantine mirror: routing, stealing-feasibility and
    /// the autoscaler skip a quarantined replica like a dead one.
    quarantined: Vec<AtomicBool>,
    /// Authoritative per-replica breaker state behind the mirror.
    breakers: Vec<Mutex<BreakerState>>,
}

/// Poison-tolerant mutex lock: a panicking thread must never wedge
/// the pool.  Every critical section in this module completes its
/// invariant-restoring writes before any call that can panic, so
/// adopting a poisoned guard observes consistent state; supervision
/// separately rebuilds whichever replica panicked.
trait LockExt<T> {
    fn plock(&self) -> std::sync::MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn plock(&self) -> std::sync::MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Poison-tolerant bounded condvar wait (same rationale as
/// [`LockExt::plock`]; the timeout flag is deliberately dropped —
/// every caller re-checks its predicate under the returned guard).
fn pwait_timeout<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, T>,
    dur: Duration,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait_timeout(guard, dur).unwrap_or_else(|p| p.into_inner()).0
}

/// Cloneable client handle to a running replica pool, scoped to one
/// model route.  [`spawn_pool`] hands back a handle routing at
/// [`ModelId::DEFAULT`]; [`ServiceHandle::with_model`] derives a
/// handle for another registered model — every RPC (infer, telemetry,
/// program, canary lifecycle) on the derived handle targets that
/// model, which is what makes autotuners and canary controllers
/// per-model instances without any internal changes.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Shared>,
    route: ModelId,
}

/// Joiner for the pool's worker threads (and the autoscaling
/// supervisor, when configured).  `join` is idempotent: the first call
/// joins everything, later calls are no-ops.  Dropping the joiner
/// shuts the pool down (queued requests drain first) and joins.
pub struct PoolJoin {
    workers: Vec<JoinHandle<()>>,
    supervisor: Option<JoinHandle<()>>,
    scrubber: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl PoolJoin {
    pub fn join(&mut self) {
        for h in self.workers.drain(..) {
            // Workers catch request panics themselves; a join error here
            // would mean supervision itself died, which Exit handling
            // already recorded in `alive`.
            let _ = h.join();
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        if let Some(s) = self.scrubber.take() {
            let _ = s.join();
        }
        // Workers the supervisor scaled up after spawn.  The supervisor
        // is joined above, so no more can appear while we drain.
        loop {
            let extra: Vec<JoinHandle<()>> = {
                let mut held = self.shared.extra_workers.plock();
                held.drain(..).collect()
            };
            if extra.is_empty() {
                break;
            }
            for h in extra {
                let _ = h.join();
            }
        }
    }
}

impl Drop for PoolJoin {
    fn drop(&mut self) {
        shutdown_shared(&self.shared);
        self.join();
    }
}

/// Spawn a single-replica pool — the drop-in shape of the old
/// one-worker front-end.
pub fn spawn(spec: EngineSpec) -> (ServiceHandle, PoolJoin) {
    spawn_pool(spec, 1)
}

/// Spawn a fixed pool of `replicas` workers with default admission
/// (every class: cap 1024, block when full — nothing is ever refused).
pub fn spawn_pool(spec: EngineSpec, replicas: usize) -> (ServiceHandle, PoolJoin) {
    spawn_pool_cfg(spec, PoolConfig::fixed(replicas))
}

/// Spawn a pool under a full [`PoolConfig`] with the default
/// [`ShardingPolicy`] (`TimeShared`, 25 ms dwell).
pub fn spawn_pool_cfg(spec: EngineSpec, cfg: PoolConfig) -> (ServiceHandle, PoolJoin) {
    spawn_pool_sharded(spec, cfg, ShardingPolicy::default())
}

/// Spawn a pool under a full [`PoolConfig`] and an explicit
/// [`ShardingPolicy`]: initial replica count, per-class admission
/// policy, model-to-replica sharding, and (optionally) the autoscaling
/// supervisor.  Panics on an invalid config (zero caps, `min > max`) —
/// configs come from validated CLI flags or test literals.
pub fn spawn_pool_sharded(
    spec: EngineSpec,
    cfg: PoolConfig,
    sharding: ShardingPolicy,
) -> (ServiceHandle, PoolJoin) {
    if let Err(e) = cfg.validate() {
        panic!("invalid pool config: {e}");
    }
    let initial = match &cfg.autoscale {
        Some(a) => cfg.replicas.clamp(a.min, a.max),
        None => cfg.replicas.max(1),
    };
    // Slots above `initial` are pre-provisioned for the autoscaler:
    // they exist in every per-replica structure but start dead/exited.
    let slots = cfg.autoscale.as_ref().map_or(initial, |a| a.max.max(initial));
    let shared = Arc::new(Shared {
        shards: (0..slots).map(|_| Shard::default()).collect(),
        park: Mutex::new(()),
        work_cv: Condvar::new(),
        space_cv: Condvar::new(),
        epoch: AtomicU64::new(0),
        shutdown: AtomicBool::new(false),
        space_waiters: AtomicUsize::new(0),
        rr: AtomicUsize::new(0),
        config: cfg.admission.clone(),
        counters: Default::default(),
        estimator: ServiceEstimator::default(),
        alive_mirror: (0..slots).map(|i| AtomicBool::new(i < initial)).collect(),
        assign_mirror: (0..slots).map(|_| AtomicU64::new(0)).collect(),
        retire: (0..slots).map(|_| AtomicBool::new(false)).collect(),
        exited: (0..slots).map(|i| AtomicBool::new(i >= initial)).collect(),
        scale_ups: AtomicU64::new(0),
        scale_downs: AtomicU64::new(0),
        extra_workers: Mutex::new(Vec::new()),
        faults: FaultArmory::default(),
        cell: Mutex::new(ModelCell {
            version: 0,
            registry: ModelRegistry::new(),
            assign: vec![None; slots],
            canaries: Vec::new(),
            acks: vec![0; slots],
            errors: (0..slots).map(|_| None).collect(),
            alive: (0..slots).map(|i| i < initial).collect(),
        }),
        fence_cv: Condvar::new(),
        version: AtomicU64::new(0),
        canary_mirror: (0..slots).map(|_| AtomicU64::new(0)).collect(),
        canary_count: AtomicUsize::new(0),
        multi_model: AtomicBool::new(false),
        switches: AtomicU64::new(0),
        model_dir: Mutex::new(HashMap::new()),
        sharding,
        metrics: Mutex::new(vec![ReplicaMetrics::default(); slots]),
        spec,
        online: Mutex::new(HashMap::new()),
        integrity_cfg: cfg.integrity.clone(),
        integrity: IntegrityCounters::default(),
        digests: (0..slots).map(|_| AtomicU64::new(0)).collect(),
        quarantined: (0..slots).map(|_| AtomicBool::new(false)).collect(),
        breakers: (0..slots).map(|_| Mutex::new(BreakerState::default())).collect(),
    });
    let workers = (0..initial).map(|i| spawn_worker(&shared, i)).collect();
    let supervisor = cfg.autoscale.map(|auto| {
        let s = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("rttm-supervisor".into())
            .spawn(move || supervisor_loop(&s, &auto))
            .expect("spawn pool supervisor")
    });
    let scrubber = cfg.integrity.scrub_interval.map(|interval| {
        let s = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("rttm-scrubber".into())
            .spawn(move || scrubber_loop(&s, interval))
            .expect("spawn pool scrubber")
    });
    let join = PoolJoin { workers, supervisor, scrubber, shared: Arc::clone(&shared) };
    (ServiceHandle { shared, route: ModelId::DEFAULT }, join)
}

fn spawn_worker(shared: &Arc<Shared>, idx: usize) -> JoinHandle<()> {
    let s = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("rttm-replica-{idx}"))
        .spawn(move || worker_loop(&s, idx))
        .expect("spawn replica worker")
}

impl ServiceHandle {
    /// Derive a handle routing at `id`: every RPC on the returned
    /// handle — inference, telemetry, program, the whole canary
    /// lifecycle — targets that model.  Ids come from
    /// [`Self::register_model`]; routing at an unregistered id yields
    /// [`CoreError::NotProgrammed`] answers (nothing to serve), and
    /// under `Dedicated` sharding [`ServeError::NoReplica`] once every
    /// replica is pinned elsewhere.
    pub fn with_model(&self, id: ModelId) -> ServiceHandle {
        ServiceHandle { shared: Arc::clone(&self.shared), route: id }
    }

    /// The model this handle routes at ([`ModelId::DEFAULT`] for
    /// handles straight from [`spawn_pool`]).
    pub fn model_route(&self) -> ModelId {
        self.route
    }

    /// The pool's sharding policy.
    pub fn sharding(&self) -> ShardingPolicy {
        self.shared.sharding
    }

    /// Register a model under a deployment `name`: deduplicated on
    /// `(name, content hash)` — the SAME tenant re-registering an
    /// identical model returns the existing id without touching
    /// replicas, while identical bytes under a different name are a
    /// fresh, isolated tenant — otherwise the replica affinity table is
    /// rebalanced across all registered models behind one version
    /// fence.
    pub fn register_model(&self, name: &str, model: TMModel) -> Result<ModelId, ServeError> {
        Ok(self.register_model_outcome(name, Arc::new(model))?.id)
    }

    /// [`Self::register_model`] for an already-shared model.
    pub fn register_model_arc(
        &self,
        name: &str,
        model: Arc<TMModel>,
    ) -> Result<ModelId, ServeError> {
        Ok(self.register_model_outcome(name, model)?.id)
    }

    /// [`Self::register_model_arc`] returning the full
    /// [`RegisterOutcome`], so multi-tenant front-ends
    /// (`spawn_pool_sharded` setup, `rttm serve --models`) can surface
    /// true duplicates — same name AND same bytes — to the operator.
    pub fn register_model_outcome(
        &self,
        name: &str,
        model: Arc<TMModel>,
    ) -> Result<RegisterOutcome, ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let (target, outcome) = {
            let mut cell = self.shared.cell.plock();
            let outcome = cell.registry.register(name, model);
            if outcome.deduped {
                return Ok(outcome);
            }
            rebalance_locked(&self.shared, &mut cell);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, outcome)
        };
        resolve_model_counters(&self.shared, outcome.id);
        self.fence_wait(target)?;
        Ok(outcome)
    }

    /// Retire a model: remove it from the registry, dismiss its canary
    /// if one is active, rebalance the freed replicas across the
    /// remaining models, and fail its still-queued requests with
    /// [`ServeError::UnknownModel`] — all behind one version fence.
    /// Requests submitted after retirement find no model to program
    /// and answer [`CoreError::NotProgrammed`] (`TimeShared`) or
    /// [`ServeError::NoReplica`] (`Dedicated`).  Ids are never reused.
    pub fn retire_model(&self, id: ModelId) -> Result<(), ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let (target, had_canary) = {
            let mut cell = self.shared.cell.plock();
            if !cell.registry.retire(id) {
                return Err(ServeError::UnknownModel(id));
            }
            let had_canary = match cell.canaries.iter().position(|c| c.model_id == id) {
                Some(pos) => {
                    cell.canaries.remove(pos);
                    true
                }
                None => false,
            };
            if had_canary {
                publish_canaries(&self.shared, &cell);
            }
            rebalance_locked(&self.shared, &mut cell);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, had_canary)
        };
        if had_canary {
            drain_canary_jobs_for(&self.shared, id, "canary dismissed: its model was retired");
        }
        // A retired model keeps no online trainer: its feedback stream
        // is dead, and the id is never reused.
        self.shared.online.plock().remove(&id.0);
        // Queued live traffic for the retired model has no replica left
        // to adopt it once the rebalance lands — fail it typed.
        drain_jobs(
            &self.shared,
            |t| t == Target::Pool(id) || t == Target::CanaryOnly(id),
            || ServeError::UnknownModel(id),
        );
        self.fence_wait(target)
    }

    /// Every registered model's entry (id, name, content hash, budget).
    pub fn registered_models(&self) -> Vec<ModelEntry> {
        self.shared.cell.plock().registry.entries().cloned().collect()
    }

    /// Attach (or clear) a per-model resource budget — the frontier a
    /// scoped autotuner must respect.  Pure metadata: no fence.
    pub fn set_model_budget(
        &self,
        id: ModelId,
        budget: Option<ResourceBudget>,
    ) -> Result<(), ServeError> {
        if self.shared.cell.plock().registry.set_budget(id, budget) {
            Ok(())
        } else {
            Err(ServeError::UnknownModel(id))
        }
    }

    pub fn model_budget(&self, id: ModelId) -> Option<ResourceBudget> {
        self.shared.cell.plock().registry.get(id).and_then(|e| e.budget.clone())
    }

    /// Per-model counter rollups, sorted by model id.  Routes appear
    /// once registered or once they carry traffic; unregistered routes
    /// are named `m<id>`.
    pub fn model_stats(&self) -> Vec<ModelStats> {
        let names: HashMap<u64, String> = {
            let cell = self.shared.cell.plock();
            cell.registry.entries().map(|e| (e.id.0, e.name.clone())).collect()
        };
        let dir = self.shared.model_dir.plock();
        let mut out: Vec<ModelStats> = dir
            .iter()
            .map(|(&id, counters)| ModelStats {
                id: ModelId(id),
                name: names
                    .get(&id)
                    .cloned()
                    .unwrap_or_else(|| ModelId(id).to_string()),
                classes: counters.snapshot(),
                switches: counters.switches.load(Ordering::Acquire),
            })
            .collect();
        drop(dir);
        out.sort_by_key(|m| m.id);
        out
    }

    /// Every active canary as `(model, replica)`, sorted by model id.
    pub fn canary_replicas(&self) -> Vec<(ModelId, usize)> {
        let cell = self.shared.cell.plock();
        let mut out: Vec<(ModelId, usize)> =
            cell.canaries.iter().map(|c| (c.model_id, c.replica)).collect();
        drop(cell);
        out.sort();
        out
    }

    /// Blocking inference RPC at [`Priority::Normal`].  Any number of
    /// rows; the replica splits them into 32-lane batches through the
    /// bulk scheduler.  Never served by an active canary replica.
    pub fn infer(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        self.infer_class(rows, Priority::Normal)
    }

    /// Blocking inference RPC at an explicit priority class.
    pub fn infer_class(
        &self,
        rows: Vec<Vec<u8>>,
        class: Priority,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::Pool(self.route), class, None)
    }

    /// Inference RPC with a per-request deadline: blocks at most
    /// `timeout`, then returns [`ServeError::DeadlineExceeded`] instead
    /// of waiting forever on a saturated queue.  Admission refuses the
    /// request outright when projected queue wait already exceeds the
    /// deadline; an admitted job that expires anyway is shed by the
    /// first worker to pop it (it replies the same typed error without
    /// executing), so abandoned requests cost the pool a queue slot,
    /// not an inference; a job that was already mid-execution at
    /// expiry completes and its late answer is discarded.
    pub fn infer_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_deadline_class(rows, timeout, Priority::Normal)
    }

    /// [`Self::infer_deadline`] at an explicit priority class.
    pub fn infer_deadline_class(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
        class: Priority,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::Pool(self.route), class, Some(timeout))
    }

    /// Blocking inference RPC served EXCLUSIVELY by this route's canary
    /// replica (the mirrored evaluation stream), at
    /// [`Priority::Critical`] — the verdict pipeline must survive
    /// overload.  Errors with [`ServeError::Canary`] when no canary is
    /// active for this route.
    pub fn infer_canary(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::CanaryOnly(self.route), Priority::Critical, None)
    }

    /// [`Self::infer_canary`] with a deadline, riding the same
    /// shed-unexecuted path as [`Self::infer_deadline`].
    pub fn infer_canary_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::CanaryOnly(self.route), Priority::Critical, Some(timeout))
    }

    /// Blocking telemetry RPC: inference plus confidence margins and
    /// the serving replica's acknowledged model version.  The autotune
    /// monitor's probe path — it queues behind (and alongside) regular
    /// traffic on purpose, and is never served by an active canary.
    pub fn infer_telemetry(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::Pool(self.route), Priority::Normal, None)
    }

    /// [`Self::infer_telemetry`] at an explicit priority class (the
    /// autotuner probes at [`Priority::High`] so drift detection keeps
    /// working under saturation).
    pub fn infer_telemetry_class(
        &self,
        rows: Vec<Vec<u8>>,
        class: Priority,
    ) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::Pool(self.route), class, None)
    }

    /// [`Self::infer_telemetry`] with a deadline, riding the same
    /// shed-unexecuted path as [`Self::infer_deadline`].
    pub fn infer_telemetry_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::Pool(self.route), Priority::Normal, Some(timeout))
    }

    /// Telemetry served exclusively by this route's canary replica —
    /// the candidate half of a paired canary window, at
    /// [`Priority::Critical`].
    pub fn infer_telemetry_canary(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::CanaryOnly(self.route), Priority::Critical, None)
    }

    /// [`Self::infer_telemetry_canary`] with a deadline.
    pub fn infer_telemetry_canary_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::CanaryOnly(self.route), Priority::Critical, Some(timeout))
    }

    fn infer_job(
        &self,
        rows: Vec<Vec<u8>>,
        target: Target,
        class: Priority,
        timeout: Option<Duration>,
    ) -> Result<Vec<usize>, ServeError> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Infer { rows, target, deadline, mstats: None, reply }, class)?;
        recv_reply(&rx, timeout)
    }

    fn telemetry_job(
        &self,
        rows: Vec<Vec<u8>>,
        target: Target,
        class: Priority,
        timeout: Option<Duration>,
    ) -> Result<Telemetry, ServeError> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Telemetry { rows, target, deadline, mstats: None, reply }, class)?;
        recv_reply(&rx, timeout)
    }

    /// Blocking reprogram RPC (the runtime-tuning path) for THIS
    /// HANDLE'S ROUTE, behind the version fence: installs `model` as
    /// the route's registered content and returns once every affine
    /// replica serves it.  A failed swap (e.g. model too big for the
    /// configured memories) leaves the failing replicas *unprogrammed*
    /// — never on a stale model — so the pool still cannot serve mixed
    /// versions.  An active canary FOR THIS ROUTE is dismissed by the
    /// broadcast; other models' replicas and canaries are untouched.
    pub fn program(&self, model: TMModel) -> Result<(), ServeError> {
        self.program_arc(Arc::new(model))
    }

    fn program_arc(&self, model: Arc<TMModel>) -> Result<(), ServeError> {
        // An externally-installed model supersedes whatever the online
        // trainer had accumulated: reseed it so the next feedback
        // window fine-tunes the model actually being served.
        self.program_impl(model, true)
    }

    fn program_impl(&self, model: Arc<TMModel>, reseed: bool) -> Result<(), ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let route = self.route;
        let hint = model.shape.name.clone();
        if reseed {
            self.reseed_online(&model);
        }
        let (target, had_canary) = {
            let mut cell = self.shared.cell.plock();
            let is_new = cell.registry.install(route, &hint, model);
            if is_new {
                // First install of this id: fold it into the affinity
                // partition.  (With a single registered model this
                // assigns every replica — the old broadcast semantics.)
                rebalance_locked(&self.shared, &mut cell);
            }
            let had_canary = match cell.canaries.iter().position(|c| c.model_id == route) {
                Some(pos) => {
                    cell.canaries.remove(pos);
                    true
                }
                None => false,
            };
            if had_canary {
                publish_canaries(&self.shared, &cell);
            }
            cell.version += 1;
            // Publish under the cell lock so the mirror stays ordered.
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, had_canary)
        };
        resolve_model_counters(&self.shared, route);
        // Only a broadcast that actually dismissed a canary can have
        // stranded CanaryOnly jobs; the common path skips the shard
        // sweep entirely.
        if had_canary {
            drain_canary_jobs_for(&self.shared, route, "canary dismissed by a pool broadcast");
        }
        self.fence_wait(target)
    }

    /// Program `model` onto EXACTLY ONE replica — this route's canary —
    /// behind the version fence; the rest of the pool keeps serving the
    /// registered models, and live traffic is routed away from the
    /// canary until it is promoted ([`Self::promote_canary`]) or
    /// dismissed ([`Self::dismiss_canary`]).  Returns the canary
    /// replica index.  Each model may run its own canary concurrently
    /// on a distinct replica (multi-canary).
    ///
    /// Re-programming an active canary replaces its candidate in
    /// place.  Requires this route to have a registered baseline (the
    /// model to compare against) and at least two live replicas (a
    /// 1-replica "canary" would be a whole-pool swap); under
    /// `Dedicated` sharding the canary replica is taken from the
    /// route's own pinned replicas, never another tenant's.  On error
    /// the canary replica is left unprogrammed — call
    /// [`Self::dismiss_canary`] to restore it to its pool model.
    pub fn program_canary(&self, model: TMModel) -> Result<usize, ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let route = self.route;
        let dedicated = self.shared.sharding == ShardingPolicy::Dedicated;
        let (target, replica) = {
            let mut cell = self.shared.cell.plock();
            if cell.registry.model(route).is_none() {
                return Err(ServeError::Canary("pool has no baseline model"));
            }
            if cell.alive.iter().filter(|&&a| a).count() < 2 {
                return Err(ServeError::Canary("need at least 2 live replicas"));
            }
            // Keep an already-chosen canary replica; otherwise dedicate
            // the highest-index live non-canary replica (under
            // Dedicated: one of this route's own).
            let replica = match cell.canary_for(route) {
                Some(c) => c.replica,
                None => {
                    let pick = (0..cell.alive.len()).rev().find(|&i| {
                        cell.alive[i]
                            && !cell.is_canary(i)
                            && (!dedicated || cell.assign[i] == Some(route))
                    });
                    match pick {
                        Some(i) => i,
                        None => {
                            return Err(ServeError::Canary(
                                "no replica available to host this model's canary",
                            ))
                        }
                    }
                }
            };
            // Dedicating `replica` must leave the route at least one
            // live non-canary server for the baseline half.
            let rest_ok = (0..cell.alive.len()).any(|i| {
                i != replica
                    && cell.alive[i]
                    && !cell.is_canary(i)
                    && (!dedicated
                        || cell.assign[i] == Some(route)
                        || cell.assign[i].is_none())
            });
            if !rest_ok {
                return Err(ServeError::Canary("need at least 2 live replicas"));
            }
            let candidate = Arc::new(model);
            match cell.canaries.iter_mut().find(|c| c.model_id == route) {
                Some(c) => c.candidate = candidate,
                None => cell.canaries.push(CanaryCell { model_id: route, replica, candidate }),
            }
            publish_canaries(&self.shared, &cell);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, replica)
        };
        self.fence_wait(target)?;
        Ok(replica)
    }

    /// Broadcast this route's canary candidate to the route's replicas
    /// (the promote half of a canary verdict).  One fence: the
    /// candidate becomes the route's registered content, the canary
    /// replica rejoins the route's pool, and other models never notice.
    pub fn promote_canary(&self) -> Result<(), ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let route = self.route;
        let target = {
            let mut cell = self.shared.cell.plock();
            let Some(pos) = cell.canaries.iter().position(|c| c.model_id == route) else {
                return Err(ServeError::Canary("no canary active"));
            };
            let c = cell.canaries.remove(pos);
            publish_canaries(&self.shared, &cell);
            let hint = c.candidate.shape.name.clone();
            // The promoted candidate supersedes the online trainer's
            // snapshot exactly like an external program would.
            self.reseed_online(&c.candidate);
            cell.registry.install(route, &hint, c.candidate);
            cell.assign[c.replica] = Some(route);
            self.shared.assign_mirror[c.replica].store(route.0 + 1, Ordering::Release);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            cell.version
        };
        drain_canary_jobs_for(&self.shared, route, "canary promoted to the pool model");
        self.fence_wait(target)
    }

    /// Tear this route's canary down: the canary replica is
    /// re-programmed with the route's pool model behind the fence (the
    /// reject half of a verdict, and the cleanup after a failed
    /// [`Self::program_canary`]).  Returns `false` (without touching
    /// anything) when no canary is active for this route — dismissal
    /// is idempotent.
    pub fn dismiss_canary(&self) -> Result<bool, ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let route = self.route;
        let target = {
            let mut cell = self.shared.cell.plock();
            let Some(pos) = cell.canaries.iter().position(|c| c.model_id == route) else {
                return Ok(false);
            };
            cell.canaries.remove(pos);
            publish_canaries(&self.shared, &cell);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            cell.version
        };
        drain_canary_jobs_for(&self.shared, route, "canary dismissed");
        self.fence_wait(target)?;
        Ok(true)
    }

    /// Replica currently serving a canary candidate FOR THIS ROUTE, if
    /// any.
    pub fn canary_replica(&self) -> Option<usize> {
        canary_replica_of(&self.shared, self.route)
    }

    /// Opt this route into online feedback: seed an [`OnlineTrainer`]
    /// from the route's registered model so [`Self::feedback`] can
    /// apply labeled windows incrementally.  Idempotent in effect — a
    /// second call re-snapshots the trainer from the current model
    /// (discarding fractional TA state, like any reseed).  Fails with
    /// [`ServeError::UnknownModel`] when the route has no registered
    /// model to warm-start from.
    pub fn enable_online_feedback(&self, seed: u64) -> Result<(), ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let route = self.route;
        let model = {
            let cell = self.shared.cell.plock();
            cell.registry.model(route).ok_or(ServeError::UnknownModel(route))?
        };
        let tuner = OnlineTrainer::from_model(&model, seed);
        self.shared.online.plock().insert(route.0, tuner);
        Ok(())
    }

    /// Apply one labeled feedback window to this route's online
    /// trainer and re-install the updated model behind the version
    /// fence (a mini-fence: the sliced/compressed programs are derived
    /// once and broadcast to every affine replica, exactly like a
    /// retrain swap — so versions stay strictly monotone and the pool
    /// never serves mixed models).  The TA-state update itself runs on
    /// one pool replica as a [`Priority::High`] control job so it is
    /// accounted (and fault-injected) like any other work.  Requires
    /// [`Self::enable_online_feedback`] first.
    pub fn feedback(&self, xs: Vec<Vec<u8>>, ys: Vec<usize>) -> Result<(), ServeError> {
        let route = self.route;
        let (reply, rx) = mpsc::channel();
        self.submit(
            Job::Feedback { xs, ys, target: Target::Pool(route), mstats: None, reply },
            Priority::High,
        )?;
        let updated = rx.recv().map_err(|_| ServeError::WorkerGone)??;
        // The trainer already holds the post-window TA states; a reseed
        // here would quantize them back to the include/exclude
        // boundary and lose the accumulated confidence.
        self.program_impl(updated, false)
    }

    /// Total labeled rows folded into this route's online trainer, or
    /// `None` while online feedback is disabled.
    pub fn online_rows_fed(&self) -> Option<u64> {
        self.shared.online.plock().get(&self.route.0).map(|t| t.rows_fed())
    }

    /// Reseed the route's online trainer (when one exists) from a
    /// freshly-installed model so subsequent feedback windows fine-tune
    /// what is actually being served.
    fn reseed_online(&self, model: &TMModel) {
        let mut online = self.shared.online.plock();
        if let Some(tuner) = online.get_mut(&self.route.0) {
            tuner.reseed_from_model(model);
        }
    }

    /// Wake workers, wait until every live replica acked `target`, and
    /// surface a swap failure recorded for EXACTLY this fence.  Version
    /// targets are unique per broadcast, so only this caller can own a
    /// matching error; failures belonging to a newer concurrent
    /// broadcast are left for that caller (a superseded model returns
    /// Ok — the fence still guarantees no replica serves anything older
    /// than it).
    fn fence_wait(&self, target: u64) -> Result<(), ServeError> {
        // Wake parked workers so they observe the fence.
        wake_work(&self.shared, true);
        let mut cell = self.shared.cell.plock();
        loop {
            if !cell.alive.iter().any(|&a| a) {
                return Err(ServeError::ShutDown);
            }
            let done = cell
                .alive
                .iter()
                .zip(&cell.acks)
                .all(|(&alive, &acked)| !alive || acked >= target);
            if done {
                break;
            }
            cell = self.shared.fence_cv.wait(cell).unwrap_or_else(|p| p.into_inner());
        }
        for slot in cell.errors.iter_mut() {
            if slot.as_ref().is_some_and(|(v, _)| *v == target) {
                let (_, err) = slot.take().expect("checked above");
                return Err(ServeError::Core(err));
            }
        }
        Ok(())
    }

    /// Pool rollup in the old single-service shape (counters summed,
    /// `reprograms` = the pool model version — see [`PoolStats::total`]).
    pub fn stats(&self) -> Result<ServerStats, ServeError> {
        Ok(self.pool_stats().total)
    }

    /// Per-class admission counters plus autoscaler activity.
    pub fn admission_stats(&self) -> AdmissionStats {
        let mut stats = AdmissionStats {
            classes: Default::default(),
            scale_ups: self.shared.scale_ups.load(Ordering::Acquire),
            scale_downs: self.shared.scale_downs.load(Ordering::Acquire),
        };
        for (slot, counters) in stats.classes.iter_mut().zip(&self.shared.counters) {
            *slot = counters.snapshot();
        }
        stats
    }

    /// Full per-replica + rollup + admission + per-model snapshot.
    pub fn pool_stats(&self) -> PoolStats {
        let (version, acks, alive, assign, canaries) = {
            let cell = self.shared.cell.plock();
            let mut canaries: Vec<(ModelId, usize)> =
                cell.canaries.iter().map(|c| (c.model_id, c.replica)).collect();
            canaries.sort();
            (cell.version, cell.acks.clone(), cell.alive.clone(), cell.assign.clone(), canaries)
        };
        let per = self.shared.metrics.plock();
        let replicas: Vec<ReplicaStats> = per
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                metrics: r.metrics.clone(),
                model_version: acks[i],
                respawns: r.respawns,
                alive: alive[i],
                assigned: assign[i],
                canary_of: canaries.iter().find(|(_, rep)| *rep == i).map(|(m, _)| *m),
            })
            .collect();
        drop(per);
        let mut total = Metrics::default();
        for r in &replicas {
            total.inferences += r.metrics.inferences;
            total.batches += r.metrics.batches;
            total.simulated_cycles += r.metrics.simulated_cycles;
            total.busy_micros += r.metrics.busy_micros;
            total.errors += r.metrics.errors;
        }
        total.reprograms = version;
        let canary = canaries.iter().find(|(m, _)| *m == self.route).map(|(_, rep)| *rep);
        PoolStats {
            replicas,
            total,
            version,
            canary,
            canaries,
            admission: self.admission_stats(),
            models: self.model_stats(),
            sharding_switches: self.shared.switches.load(Ordering::Acquire),
            integrity: self.shared.integrity.snapshot(),
        }
    }

    /// Ask the pool to stop.  Queued requests are drained first; new
    /// submissions are rejected with [`ServeError::ShutDown`].
    /// Idempotent.
    pub fn shutdown(&self) {
        shutdown_shared(&self.shared);
    }

    /// Arm a [`FaultPlan`] against a chosen replica: its next popped
    /// job is stalled, panicked on, or dropped without a reply.  The
    /// generalized fault-injection surface overload and supervision
    /// tests share instead of hand-rolling failure modes.
    #[doc(hidden)]
    pub fn inject_fault(&self, plan: FaultPlan) {
        self.shared.faults.arm(plan);
    }

    /// Fault injection: make the replica that picks this request panic
    /// mid-request.  Returns the same typed error a real panic would,
    /// after supervision has respawned the replica.  For tests and
    /// chaos drills.  Never lands on an active canary (like any Pool
    /// job).
    #[doc(hidden)]
    pub fn inject_panic(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            Job::Crash { target: Target::Pool(self.route), mstats: None, reply },
            Priority::Normal,
        )?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Fault injection on this route's CANARY replica: exercises the
    /// respawn-while-canary supervision path (the rebuilt replica must
    /// come back serving the CANDIDATE, not the pool model).
    #[doc(hidden)]
    pub fn inject_panic_canary(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            Job::Crash { target: Target::CanaryOnly(self.route), mstats: None, reply },
            Priority::Critical,
        )?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Fault injection: occupy whichever replica pops this job for
    /// `dur` — the deterministic "saturated pool" for deadline tests
    /// and chaos drills.  Returns immediately; the returned receiver
    /// resolves when the stall ends (drop it to fire and forget).
    /// Queued like a normal request; [`Self::inject_fault`] with
    /// [`FaultPlan::stall`] targets a specific replica instead.
    #[doc(hidden)]
    pub fn inject_stall(
        &self,
        dur: Duration,
    ) -> Result<mpsc::Receiver<Result<Vec<usize>, ServeError>>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Stall { dur, reply }, Priority::Normal)?;
        Ok(rx)
    }

    /// The admission front-end: shutdown / canary / routability
    /// validity, deadline feasibility, the per-class bound with its
    /// backpressure policy, then routing to a shard.  Every counter
    /// site mirrors into the job's per-model [`ModelCounters`].
    fn submit(&self, mut job: Job, class: Priority) -> Result<(), ServeError> {
        let shared = &*self.shared;
        let ci = class.index();
        if shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let target = job.target();
        let mstats = match target {
            Target::Pool(m) | Target::CanaryOnly(m) => {
                Some(resolve_model_counters(shared, m))
            }
            Target::Any => None,
        };
        job.attach(mstats.clone());
        if let Target::CanaryOnly(m) = target {
            if canary_replica_of(shared, m).is_none() {
                return Err(ServeError::Canary("no canary active"));
            }
        }
        // Dedicated reachability: a model whose pinned replicas are all
        // gone (and with no unassigned replica left to pin) can never
        // be served — fail fast instead of queueing forever.
        if let Target::Pool(m) = target {
            if shared.sharding == ShardingPolicy::Dedicated {
                let tag = m.0 + 1;
                let reachable = (0..shared.shards.len()).any(|i| {
                    shared.alive_mirror[i].load(Ordering::Acquire)
                        && !shared.retire[i].load(Ordering::Acquire)
                        && !shared.quarantined[i].load(Ordering::Acquire)
                        && !is_canary_replica(shared, i)
                        && matches!(
                            shared.assign_mirror[i].load(Ordering::Acquire),
                            v if v == tag || v == 0
                        )
                });
                if !reachable {
                    return Err(ServeError::NoReplica { model: m });
                }
            }
        }
        // Deadline-aware admission (Pool targets only — the canary
        // mirror is control traffic and never feasibility-rejected):
        // refuse a request whose projected queue wait behind
        // same-or-higher-class work already exceeds its deadline.
        let feasibility = job.deadline().filter(|_| matches!(target, Target::Pool(_)));
        if let Some(deadline) = feasibility {
            let ahead: u64 = Priority::ALL[ci..]
                .iter()
                .map(|p| shared.counters[p.index()].depth())
                .sum();
            let replicas = match target {
                Target::Pool(m) => self.live_pool_replicas(m),
                _ => 1,
            };
            if let Some(wait) = shared.estimator.projected_wait(ahead, replicas) {
                let slack = deadline.saturating_duration_since(Instant::now());
                if wait > slack {
                    shared.counters[ci].reject_deadline();
                    if let Some(ms) = &mstats {
                        ms.classes[ci].reject_deadline();
                    }
                    return Err(ServeError::DeadlineExceeded);
                }
            }
        }
        // Per-class bound + backpressure policy.
        let cap = shared.config.cap(class) as u64;
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return Err(ServeError::ShutDown);
            }
            if shared.counters[ci].depth() < cap {
                break;
            }
            match shared.config.policy(class) {
                ShedPolicy::Reject => {
                    shared.counters[ci].reject_overloaded();
                    if let Some(ms) = &mstats {
                        ms.classes[ci].reject_overloaded();
                    }
                    return Err(ServeError::Overloaded);
                }
                ShedPolicy::ShedOldest => {
                    // Evict the oldest queued request of this class (its
                    // client gets the typed Overloaded error).  If a
                    // popper emptied the class first, the loop re-checks
                    // the bound and admits.
                    self.shed_oldest(class);
                }
                ShedPolicy::Block => {
                    shared.space_waiters.fetch_add(1, Ordering::AcqRel);
                    let guard = shared.park.plock();
                    // Re-check under the park lock: a pop between the
                    // depth check and here would otherwise be a lost
                    // wake.  The bounded wait is a belt-and-braces
                    // backstop, not the wake mechanism.
                    if shared.counters[ci].depth() < cap
                        || shared.shutdown.load(Ordering::Acquire)
                    {
                        shared.space_waiters.fetch_sub(1, Ordering::AcqRel);
                        continue;
                    }
                    let timeout = Duration::from_millis(10);
                    let _ = pwait_timeout(&shared.space_cv, guard, timeout);
                    shared.space_waiters.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
        // Route: canary jobs to their model's canary shard, pool jobs
        // affinity-first over live, non-canary, non-retiring replicas.
        let shard = match target {
            Target::CanaryOnly(m) => match canary_replica_of(shared, m) {
                Some(i) => i,
                None => return Err(ServeError::Canary("no canary active")),
            },
            Target::Pool(m) => self.route_pool(m),
            Target::Any => self.route_any(),
        };
        {
            let mut q = shared.shards[shard].q.plock();
            if q.closed {
                return Err(ServeError::ShutDown);
            }
            // Re-checked UNDER the shard lock: dismissal clears the
            // mirror and then drains this shard (also under this lock),
            // so a CanaryOnly job admitted here is either rejected now
            // or found by the drain — never stranded.
            if let Target::CanaryOnly(m) = target {
                if shared.canary_mirror[shard].load(Ordering::Acquire) != m.0 + 1 {
                    return Err(ServeError::Canary("no canary active"));
                }
            }
            shared.counters[ci].admit();
            if let Some(ms) = &mstats {
                ms.classes[ci].admit();
            }
            q.classes[ci].push_back(job);
        }
        // With a canary active or several models in play, the one woken
        // worker might be ineligible for the new job (wrong canary,
        // foreign affinity) and would park again without another
        // wake-up — wake everyone.  A single-model, canary-free pool
        // keeps notify_one and avoids a per-request thundering herd.
        wake_work(shared, wake_all_needed(shared));
        Ok(())
    }

    /// Live replicas eligible for `m`'s Pool traffic — affine or still
    /// unassigned (feasibility divisor).
    fn live_pool_replicas(&self, m: ModelId) -> usize {
        let shared = &*self.shared;
        let tag = m.0 + 1;
        shared
            .alive_mirror
            .iter()
            .enumerate()
            .filter(|(i, a)| {
                a.load(Ordering::Acquire)
                    && !shared.quarantined[*i].load(Ordering::Acquire)
                    && !is_canary_replica(shared, *i)
                    && matches!(
                        shared.assign_mirror[*i].load(Ordering::Acquire),
                        v if v == tag || v == 0
                    )
            })
            .count()
            .max(1)
    }

    /// Pick a shard for `m`'s Pool job: round-robin over live,
    /// non-canary, non-retiring replicas, preferring one already affine
    /// to `m`, then an unassigned one, then any (whose owner adopts the
    /// model under `TimeShared`, or which work stealing rescues under
    /// `Dedicated`).  With none eligible right now (mass death or
    /// mid-scale), park the job anywhere — work stealing or the
    /// teardown drain will find it.
    fn route_pool(&self, m: ModelId) -> usize {
        let shared = &*self.shared;
        let n = shared.shards.len();
        let start = shared.rr.fetch_add(1, Ordering::Relaxed) % n;
        let tag = m.0 + 1;
        let mut unassigned = None;
        let mut fallback = None;
        for k in 0..n {
            let i = (start + k) % n;
            if is_canary_replica(shared, i)
                || !shared.alive_mirror[i].load(Ordering::Acquire)
                || shared.retire[i].load(Ordering::Acquire)
                || shared.quarantined[i].load(Ordering::Acquire)
            {
                continue;
            }
            match shared.assign_mirror[i].load(Ordering::Acquire) {
                v if v == tag => return i,
                0 => {
                    if unassigned.is_none() {
                        unassigned = Some(i);
                    }
                }
                _ => {
                    if fallback.is_none() {
                        fallback = Some(i);
                    }
                }
            }
        }
        unassigned.or(fallback).unwrap_or(start)
    }

    /// Pick a shard for model-agnostic work: round-robin over live,
    /// non-canary, non-retiring replicas.
    fn route_any(&self) -> usize {
        let shared = &*self.shared;
        let n = shared.shards.len();
        let start = shared.rr.fetch_add(1, Ordering::Relaxed) % n;
        for k in 0..n {
            let i = (start + k) % n;
            if !is_canary_replica(shared, i)
                && shared.alive_mirror[i].load(Ordering::Acquire)
                && !shared.retire[i].load(Ordering::Acquire)
                && !shared.quarantined[i].load(Ordering::Acquire)
            {
                return i;
            }
        }
        start
    }

    /// Evict the oldest queued request of `class` (scanning shards in
    /// index order — "oldest" is per-shard FIFO order, which is exact
    /// on a single shard and the oldest front across shards otherwise).
    fn shed_oldest(&self, class: Priority) {
        let shared = &*self.shared;
        let ci = class.index();
        let mut victim = None;
        for shard in &shared.shards {
            let mut q = shard.q.plock();
            if let Some(job) = q.classes[ci].pop_front() {
                shared.counters[ci].pop_shed();
                if let Some(ms) = job.mstats() {
                    ms.classes[ci].pop_shed();
                }
                victim = Some(job);
                break;
            }
        }
        if let Some(job) = victim {
            wake_space(shared);
            job.fail(|| ServeError::Overloaded);
        }
    }
}

fn recv_reply<T>(
    rx: &mpsc::Receiver<Result<T, ServeError>>,
    timeout: Option<Duration>,
) -> Result<T, ServeError> {
    match timeout {
        None => rx.recv().map_err(|_| ServeError::WorkerGone)?,
        Some(t) => match rx.recv_timeout(t) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServeError::DeadlineExceeded),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::WorkerGone),
        },
    }
}

/// Wake parked workers after enqueueing work (or raising a fence):
/// the epoch is bumped UNDER the park lock, so a worker that scanned
/// the shards before this enqueue cannot park past it.
fn wake_work(shared: &Shared, all: bool) {
    let _guard = shared.park.plock();
    shared.epoch.fetch_add(1, Ordering::Release);
    if all {
        shared.work_cv.notify_all();
    } else {
        shared.work_cv.notify_one();
    }
}

/// Must enqueues wake EVERY worker?  Yes once a canary is active or a
/// second model has carried traffic: the one woken worker might be
/// ineligible (wrong canary, foreign affinity) and would park again
/// without another wake.  A single-model, canary-free pool keeps
/// notify_one and avoids a per-request thundering herd.
fn wake_all_needed(shared: &Shared) -> bool {
    shared.canary_count.load(Ordering::Acquire) > 0
        || shared.multi_model.load(Ordering::Acquire)
}

/// Wake submitters blocked on a full class queue, if any.
fn wake_space(shared: &Shared) {
    if shared.space_waiters.load(Ordering::Acquire) == 0 {
        return;
    }
    let _guard = shared.park.plock();
    shared.space_cv.notify_all();
}

/// Flip the pool to shutdown and wake everything parked on it.
/// Idempotent.
fn shutdown_shared(shared: &Shared) {
    shared.shutdown.store(true, Ordering::Release);
    let _guard = shared.park.plock();
    shared.epoch.fetch_add(1, Ordering::Release);
    shared.work_cv.notify_all();
    shared.space_cv.notify_all();
}

/// Re-publish the lock-free canary mirrors from the authoritative cell
/// (call under the cell lock after any canary mutation).
fn publish_canaries(shared: &Shared, cell: &ModelCell) {
    for (i, mirror) in shared.canary_mirror.iter().enumerate() {
        let tag = cell.canary_on(i).map_or(0, |c| c.model_id.0 + 1);
        mirror.store(tag, Ordering::Release);
    }
    shared.canary_count.store(cell.canaries.len(), Ordering::Release);
}

/// Replica hosting `m`'s canary right now, per the lock-free mirror.
fn canary_replica_of(shared: &Shared, m: ModelId) -> Option<usize> {
    if shared.canary_count.load(Ordering::Acquire) == 0 {
        return None;
    }
    shared
        .canary_mirror
        .iter()
        .position(|c| c.load(Ordering::Acquire) == m.0 + 1)
}

/// Is replica `i` hosting ANY model's canary, per the mirror?
fn is_canary_replica(shared: &Shared, i: usize) -> bool {
    shared.canary_count.load(Ordering::Acquire) > 0
        && shared.canary_mirror[i].load(Ordering::Acquire) != 0
}

/// The per-model counter block for `m`, creating it on first touch.
/// Once a second model appears in the directory, enqueue wakes switch
/// to notify_all (see [`wake_all_needed`]).
fn resolve_model_counters(shared: &Shared, m: ModelId) -> Arc<ModelCounters> {
    let mut dir = shared.model_dir.plock();
    let counters = Arc::clone(dir.entry(m.0).or_default());
    if dir.len() > 1 {
        shared.multi_model.store(true, Ordering::Release);
    }
    counters
}

/// Recompute the replica→model affinity partition (call under the cell
/// lock after register/retire): registered ids round-robin across live
/// non-canary replicas, dead slots pre-assigned to the first id so a
/// later scale-up revives them onto real work.  With a single
/// registered model this assigns every replica — the pre-registry
/// broadcast semantics.
fn rebalance_locked(shared: &Shared, cell: &mut ModelCell) {
    let ids = cell.registry.ids();
    let mut k = 0usize;
    for i in 0..cell.assign.len() {
        if cell.is_canary(i) {
            continue;
        }
        let next = if ids.is_empty() {
            None
        } else if cell.alive[i] {
            let id = ids[k % ids.len()];
            k += 1;
            Some(id)
        } else {
            Some(ids[0])
        };
        cell.assign[i] = next;
        shared.assign_mirror[i].store(next.map_or(0, |m| m.0 + 1), Ordering::Release);
    }
}

/// What the queue wait resolved to.
enum Next {
    Work { job: Job, class: Priority },
    /// A newer model version is pending — swap before taking work.
    Resync,
    Exit,
}

/// Runs on every worker exit — normal return, supervisor retirement,
/// or a panic that escaped `catch_unwind` (e.g. an invalid spec
/// panicking in `build()`): marks the replica dead and wakes fence
/// waiters so `program` can never hang on a corpse.  When the LAST
/// replica dies, flips the pool to shutdown and drops any parked jobs,
/// so clients blocked on replies get [`ServeError::WorkerGone`]
/// instead of waiting forever.
struct DeathWatch<'a> {
    shared: &'a Shared,
    idx: usize,
}

impl Drop for DeathWatch<'_> {
    fn drop(&mut self) {
        self.shared.alive_mirror[self.idx].store(false, Ordering::Release);
        let (all_dead, cleared) = {
            let mut cell = self.shared.cell.plock();
            cell.alive[self.idx] = false;
            // A dying canary takes its candidate with it: clear its
            // canary state so that model's Pool traffic stops avoiding
            // a corpse and new CanaryOnly submissions are rejected
            // instead of stranded.  Symmetrically, if this death
            // leaves ONLY canaries alive, every canary must be
            // dismissed — Pool jobs would otherwise have no eligible
            // worker and their callers would block forever.  The
            // version bump makes surviving canaries resync onto their
            // pool models before serving live traffic.
            let mut cleared: Vec<ModelId> = Vec::new();
            if let Some(pos) = cell.canaries.iter().position(|c| c.replica == self.idx) {
                cleared.push(cell.canaries.remove(pos).model_id);
            }
            let only_canaries_left = !cell.canaries.is_empty()
                && cell
                    .alive
                    .iter()
                    .enumerate()
                    .all(|(i, &a)| !a || cell.is_canary(i));
            if only_canaries_left {
                cleared.extend(cell.canaries.drain(..).map(|c| c.model_id));
            }
            if !cleared.is_empty() {
                publish_canaries(self.shared, &cell);
                cell.version += 1;
                self.shared.version.store(cell.version, Ordering::Release);
            }
            (!cell.alive.iter().any(|&a| a), cleared)
        };
        self.shared.fence_cv.notify_all();
        if !cleared.is_empty() && !all_dead {
            for m in &cleared {
                drain_canary_jobs_for(self.shared, *m, "canary replica died");
            }
            // Wake survivors: the version bump above needs a resync.
            wake_work(self.shared, true);
        }
        if all_dead {
            close_shards(self.shared);
            shutdown_shared(self.shared);
        }
        // A dead replica is not quarantined — clear the breaker so a
        // revived slot starts with a clean slate (the revive fence
        // re-records its digest).
        self.shared.quarantined[self.idx].store(false, Ordering::Release);
        *self.shared.breakers[self.idx].plock() = BreakerState::default();
        self.shared.digests[self.idx].store(0, Ordering::Release);
        // Last: the supervisor may revive this slot only once the
        // worker is fully gone.
        self.shared.retire[self.idx].store(false, Ordering::Release);
        self.shared.exited[self.idx].store(true, Ordering::Release);
    }
}

/// Teardown: close every shard and drop whatever is still queued.
/// Dropping a job drops its reply sender, so blocked clients get
/// [`ServeError::WorkerGone`].
fn close_shards(shared: &Shared) {
    let mut dropped: Vec<Job> = Vec::new();
    for shard in &shared.shards {
        let mut q = shard.q.plock();
        q.closed = true;
        for (ci, class) in q.classes.iter_mut().enumerate() {
            while let Some(job) = class.pop_front() {
                shared.counters[ci].pop_shed();
                if let Some(ms) = job.mstats() {
                    ms.classes[ci].pop_shed();
                }
                dropped.push(job);
            }
        }
    }
    drop(dropped);
}

/// Sweep every shard and fail still-queued jobs whose target matches
/// `pred` with a typed error — no worker is (or will be) eligible for
/// them, so leaving them queued would strand their callers.  Replies
/// are sent outside the shard locks.
fn drain_jobs(
    shared: &Shared,
    pred: impl Fn(Target) -> bool,
    err: impl Fn() -> ServeError,
) {
    let mut stranded: Vec<Job> = Vec::new();
    for shard in &shared.shards {
        let mut q = shard.q.plock();
        for (ci, class) in q.classes.iter_mut().enumerate() {
            let mut kept = VecDeque::with_capacity(class.len());
            while let Some(job) = class.pop_front() {
                if pred(job.target()) {
                    shared.counters[ci].pop_shed();
                    if let Some(ms) = job.mstats() {
                        ms.classes[ci].pop_shed();
                    }
                    stranded.push(job);
                } else {
                    kept.push_back(job);
                }
            }
            *class = kept;
        }
    }
    if !stranded.is_empty() {
        wake_space(shared);
    }
    for job in stranded {
        job.fail(&err);
    }
}

/// Fail `m`'s still-queued canary-targeted jobs (after its canary was
/// cleared by dismissal, a pool broadcast, promotion, retirement, or
/// canary-worker death).  Other models' canary streams are untouched.
fn drain_canary_jobs_for(shared: &Shared, m: ModelId, reason: &'static str) {
    let mut stranded: Vec<Job> = Vec::new();
    for shard in &shared.shards {
        let mut q = shard.q.plock();
        for (ci, class) in q.classes.iter_mut().enumerate() {
            let mut kept = VecDeque::with_capacity(class.len());
            while let Some(job) = class.pop_front() {
                if job.target() == Target::CanaryOnly(m) {
                    shared.counters[ci].pop_shed();
                    if let Some(ms) = job.mstats() {
                        ms.classes[ci].pop_shed();
                    }
                    stranded.push(job);
                } else {
                    kept.push_back(job);
                }
            }
            *class = kept;
        }
    }
    if !stranded.is_empty() {
        wake_space(shared);
    }
    for job in stranded {
        job.fail_canary(reason);
    }
}

/// May a worker serve a job with this target?  A worker hosting model
/// X's canary serves ONLY `CanaryOnly(X)` jobs — a candidate under
/// evaluation is never exposed to live traffic, and the baseline never
/// answers the mirrored stream.  Non-canary workers serve Pool jobs
/// for their affine model as-is, and foreign models' Pool jobs only on
/// an adoption pass (`adopt` — gated by [`may_adopt`]).
///
/// `assigned` / `canary_of` are the worker-local answers learned at
/// its last fence resync from the AUTHORITATIVE cell (every canary or
/// affinity mutation bumps the version, so a worker always resyncs
/// before taking work under a new assignment) — deliberately not the
/// lock-free mirrors, whose propagation lag could otherwise let a
/// freshly-assigned canary pick up one live request.
fn eligible(
    target: Target,
    assigned: Option<ModelId>,
    canary_of: Option<ModelId>,
    adopt: bool,
) -> bool {
    match target {
        Target::Any => canary_of.is_none(),
        Target::Pool(m) => canary_of.is_none() && (assigned == Some(m) || adopt),
        Target::CanaryOnly(m) => canary_of == Some(m),
    }
}

/// May this worker adopt a foreign model's Pool job (reprogramming
/// itself to serve it)?  Canaries never adopt.  An unassigned worker
/// always may.  Under `Dedicated`, an assigned worker never switches.
/// Under `TimeShared`, the dwell window since its last switch is the
/// thrash guard: adversarially alternating traffic costs at most one
/// reprogram per dwell per replica instead of one per request.
fn may_adopt(shared: &Shared, state: &WorkerState) -> bool {
    if state.canary_of.is_some() {
        return false;
    }
    match (state.assigned, shared.sharding) {
        (None, _) => true,
        (Some(_), ShardingPolicy::Dedicated) => false,
        (Some(_), ShardingPolicy::TimeShared { dwell }) => {
            state.last_switch.is_none_or(|t| t.elapsed() >= dwell)
        }
    }
}

/// Worker-local execution state: the service, the model Arc it last
/// programmed (so fences that do not change THIS replica's model — e.g.
/// a sibling becoming a canary — ack without a redundant reprogram),
/// which model the cell assigned this worker at its last resync, which
/// model's canary it hosts (if any), and when it last switched models
/// (the `TimeShared` dwell clock).
struct WorkerState {
    service: InferenceService,
    last_model: Option<Arc<TMModel>>,
    assigned: Option<ModelId>,
    canary_of: Option<ModelId>,
    last_switch: Option<Instant>,
}

fn worker_loop(shared: &Shared, idx: usize) {
    let _watch = DeathWatch { shared, idx };
    let mut state = WorkerState {
        service: InferenceService::new(shared.spec.build()),
        last_model: None,
        assigned: None,
        canary_of: None,
        last_switch: None,
    };
    // A revived slot carries the counters its previous incarnation
    // published (scale-down must not erase served history).
    state.service.metrics = shared.metrics.plock()[idx].metrics.clone();
    let mut my_version = 0u64;
    loop {
        // Fence check between requests: drain (we are between jobs),
        // swap, resume.
        if shared.version.load(Ordering::Acquire) != my_version {
            my_version = program_from_cell(shared, idx, &mut state);
        }
        let assigned = state.assigned;
        let canary_of = state.canary_of;
        let next = loop {
            // Pending reprogram outranks new work: no job may start
            // on a stale replica once the fence is up.
            if shared.version.load(Ordering::Acquire) != my_version {
                break Next::Resync;
            }
            // Supervisor retirement: exit instead of taking work.  (An
            // active canary ignores the flag; the supervisor never
            // targets it, and the race where it just became one must
            // not kill the mirror.)
            if shared.retire[idx].load(Ordering::Acquire) && canary_of.is_none() {
                break Next::Exit;
            }
            // Circuit breaker: a quarantined replica takes no work.
            // It still acks fences (the version check above outranks
            // this one) and still honours retirement; once the hold
            // expires, a half-open verify probe gates its rejoin.  A
            // successful probe re-enters via Resync: the probe may
            // have reprogrammed, so the captured assignment is stale.
            if shared.quarantined[idx].load(Ordering::Acquire) {
                if breaker_half_open(shared, idx, &mut state, &mut my_version) {
                    break Next::Resync;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break Next::Exit;
                }
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            let epoch = shared.epoch.load(Ordering::Acquire);
            let adopt = may_adopt(shared, &state);
            if let Some((job, class)) = next_job(shared, idx, assigned, canary_of, adopt) {
                break Next::Work { job, class };
            }
            if shared.shutdown.load(Ordering::Acquire) {
                break Next::Exit;
            }
            // Nothing to do: park — unless an enqueue raced the scan
            // (the epoch moved), then rescan instead.  The bounded wait
            // is a backstop; the epoch check is the correctness.
            let guard = shared.park.plock();
            if shared.epoch.load(Ordering::Acquire) == epoch {
                let _ = pwait_timeout(&shared.work_cv, guard, Duration::from_millis(10));
            }
        };
        match next {
            Next::Resync => continue,
            // DeathWatch marks the replica dead on the way out.
            Next::Exit => return,
            Next::Work { job, class } => {
                // An adopted foreign-model job: re-pin this worker to
                // the job's model behind a fence, program it, then
                // serve.  (Unregistered routes — e.g. infer before any
                // program — pin without a version bump and serve
                // NotProgrammed, preserving single-model numbering.)
                if let Target::Pool(m) = job.target() {
                    if state.canary_of.is_none() && state.assigned != Some(m) {
                        self_assign(shared, idx, m, job.mstats());
                        state.last_switch = Some(Instant::now());
                        my_version = program_from_cell(shared, idx, &mut state);
                    }
                }
                run_job(shared, idx, &mut state, &mut my_version, job, class);
            }
        }
    }
}

/// Re-pin worker `idx` to model `m` (the adoption half of `TimeShared`
/// sharding, and first-touch pinning of unassigned replicas).  Bumps
/// the fence version ONLY for registered models: pinning to an
/// unregistered route (nothing to program) must not shift the version
/// numbering that single-model tests and fence callers observe.
fn self_assign(shared: &Shared, idx: usize, m: ModelId, mstats: Option<&Arc<ModelCounters>>) {
    let mut cell = shared.cell.plock();
    let registered = cell.registry.contains(m);
    cell.assign[idx] = Some(m);
    shared.assign_mirror[idx].store(m.0 + 1, Ordering::Release);
    if registered {
        cell.version += 1;
        shared.version.store(cell.version, Ordering::Release);
        shared.switches.fetch_add(1, Ordering::AcqRel);
        if let Some(ms) = mstats {
            ms.record_switch();
        }
    }
}

/// Class-major pop with work stealing: scan `Critical` down to `Low`,
/// own shard first then siblings, skipping jobs this worker is not
/// eligible for and shedding expired ones unexecuted.  Within a class
/// the affine pass runs before the adoption pass: a worker only
/// reprograms for a foreign model when no job it can serve as-is
/// exists at that class.
fn next_job(
    shared: &Shared,
    idx: usize,
    assigned: Option<ModelId>,
    canary_of: Option<ModelId>,
    may_adopt: bool,
) -> Option<(Job, Priority)> {
    let n = shared.shards.len();
    let mut expired: Vec<Job> = Vec::new();
    let mut found: Option<(Job, Priority)> = None;
    'classes: for class in Priority::ALL.iter().rev() {
        let ci = class.index();
        // Lock-free skip of empty classes (depth is bumped before the
        // push becomes visible, so a miss here is re-driven by the
        // submitter's epoch bump).
        if shared.counters[ci].depth() == 0 {
            continue;
        }
        for adopt in [false, true] {
            if adopt && !may_adopt {
                break;
            }
            for k in 0..n {
                let shard = (idx + k) % n;
                let mut q = shared.shards[shard].q.plock();
                loop {
                    // A scrub belongs to exactly one replica's engine:
                    // the owner pops it, thieves skip it (the stale
                    // scrubs of a dead replica are swept by the
                    // scrubber's next tick).
                    let pos = q.classes[ci].iter().position(|j| match j {
                        Job::Scrub { replica } => *replica == idx,
                        _ => eligible(j.target(), assigned, canary_of, adopt),
                    });
                    let Some(pos) = pos else { break };
                    let job = q.classes[ci].remove(pos).expect("position just found");
                    if job.deadline().is_some_and(|d| Instant::now() > d) {
                        // Shed expired work before computing it: the
                        // client already got DeadlineExceeded from its
                        // recv_timeout, so executing the job would burn
                        // the replica for a discarded answer.
                        shared.counters[ci].pop_expired();
                        if let Some(ms) = job.mstats() {
                            ms.classes[ci].pop_expired();
                        }
                        expired.push(job);
                    } else {
                        shared.counters[ci].pop_served();
                        if let Some(ms) = job.mstats() {
                            ms.classes[ci].pop_served();
                        }
                        found = Some((job, *class));
                        break;
                    }
                }
                drop(q);
                if found.is_some() {
                    break 'classes;
                }
            }
        }
    }
    if !expired.is_empty() || found.is_some() {
        wake_space(shared);
    }
    for job in expired {
        job.fail(|| ServeError::DeadlineExceeded);
    }
    found
}

fn run_job(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
    job: Job,
    class: Priority,
) {
    // Armed fault plans apply to the next popped job on this replica.
    let mut force_panic = false;
    match shared.faults.poll(idx) {
        Some(Fault::Stall(dur)) => std::thread::sleep(dur),
        Some(Fault::PanicOnJob { .. }) => force_panic = true,
        Some(Fault::DropReply) => {
            // Dropping the job drops its reply sender: the client
            // observes WorkerGone — the supervision blind spot every
            // caller must tolerate.
            drop(job);
            return;
        }
        Some(Fault::FlipModelBits { seed, n_bits }) => {
            // Corrupt THIS replica's derived program buffers — never
            // the golden model Arc — then serve the popped job
            // normally: the pre-serve verify below must catch the
            // corruption before the answer is computed.
            state.service.flip_program_bits(seed, n_bits);
        }
        None => {}
    }
    // Pre-serve integrity verify (scrubbing on only): a corrupted
    // program is detected and healed from the golden model BEFORE any
    // inference executes on it — the zero-divergence guarantee the
    // chaos tests pin.  Background [`Job::Scrub`] ticks give idle
    // replicas the same check.
    if shared.integrity_cfg.scrub_interval.is_some()
        && matches!(job, Job::Infer { .. } | Job::Telemetry { .. })
    {
        // On a failed heal the replica is respawned from its spec and
        // tripped toward quarantine; either way the engine is clean
        // when the job proceeds below.
        let _ = verify_and_heal(shared, idx, state, my_version);
    }
    match job {
        Job::Infer { rows, deadline, mstats, reply, .. } => {
            // The pop-side shed already filtered expired jobs, but an
            // injected stall may have burned the budget since: shed
            // here too rather than compute a discarded answer.  (The
            // pop already counted it served, so only the miss is
            // recorded.)
            if deadline.is_some_and(|d| Instant::now() > d) {
                shared.counters[class.index()].expire_in_service();
                if let Some(ms) = &mstats {
                    ms.classes[class.index()].expire_in_service();
                }
                let _ = reply.send(Err(ServeError::DeadlineExceeded));
                return;
            }
            let t0 = Instant::now();
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                if force_panic {
                    panic!("injected fault (FaultPlan::PanicOnJob)");
                }
                state.service.infer_all(&rows)
            }));
            if matches!(&outcome, Ok(Ok(_))) {
                shared.estimator.observe(t0.elapsed());
            }
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Stall { dur, reply } => {
            std::thread::sleep(dur);
            if force_panic {
                let outcome =
                    panic::catch_unwind(AssertUnwindSafe(|| -> Result<Vec<usize>, CoreError> {
                        panic!("injected fault (FaultPlan::PanicOnJob)")
                    }));
                reply_or_respawn(shared, idx, state, my_version, outcome, reply);
            } else {
                let _ = reply.send(Ok(Vec::new()));
            }
        }
        Job::Telemetry { rows, deadline, mstats, reply, .. } => {
            if deadline.is_some_and(|d| Instant::now() > d) {
                shared.counters[class.index()].expire_in_service();
                if let Some(ms) = &mstats {
                    ms.classes[class.index()].expire_in_service();
                }
                let _ = reply.send(Err(ServeError::DeadlineExceeded));
                return;
            }
            // Capture the fence version the request runs under BEFORE
            // the work: a panic respawn may advance `my_version`.
            let version = *my_version;
            let t0 = Instant::now();
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                if force_panic {
                    panic!("injected fault (FaultPlan::PanicOnJob)");
                }
                state
                    .service
                    .infer_with_margins(&rows)
                    .map(|(preds, margins)| Telemetry { preds, margins, model_version: version })
            }));
            if matches!(&outcome, Ok(Ok(_))) {
                shared.estimator.observe(t0.elapsed());
            }
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Crash { reply, .. } => {
            let outcome =
                panic::catch_unwind(AssertUnwindSafe(|| -> Result<Vec<usize>, CoreError> {
                    panic!("injected fault (ServiceHandle::inject_panic)")
                }));
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Feedback { xs, ys, target, reply, .. } => {
            // Feedback is always Pool-routed (`ServiceHandle::feedback`
            // builds the job); an Any/CanaryOnly target here is a bug.
            let Target::Pool(model) = target else {
                let _ = reply.send(Err(ServeError::Canary(
                    "feedback jobs must target a pool model",
                )));
                return;
            };
            let t0 = Instant::now();
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                if force_panic {
                    panic!("injected fault (FaultPlan::PanicOnJob)");
                }
                apply_feedback(shared, model, &xs, &ys)
            }));
            match outcome {
                Ok(result) => {
                    // The TA-state update ran on this replica: account
                    // its wall time like served work, then publish.
                    state.service.metrics.busy_micros += t0.elapsed().as_micros() as u64;
                    shared.metrics.plock()[idx].metrics = state.service.metrics.clone();
                    let _ = reply.send(result);
                }
                Err(_panic) => {
                    // `reply_or_respawn` maps CoreError; feedback fails
                    // with ServeError directly, so supervise by hand.
                    respawn_replica(shared, idx, state, my_version);
                    let _ = reply.send(Err(ServeError::WorkerPanicked { replica: idx }));
                }
            }
        }
        Job::Scrub { .. } => {
            // Background integrity tick for an idle replica (busy ones
            // are already verified on every pop above).  No reply to
            // send; the counters are the observable outcome.  An armed
            // panic fault still fires here — a scrub pop must not
            // silently swallow the plan.
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                if force_panic {
                    panic!("injected fault (FaultPlan::PanicOnJob)");
                }
                verify_and_heal(shared, idx, state, my_version)
            }));
            if outcome.is_err() {
                respawn_replica(shared, idx, state, my_version);
            }
        }
    }
}

/// Fold one labeled window into `model`'s online trainer and snapshot
/// the updated model.  Runs on a worker replica under the trainer map
/// lock — the lock serializes concurrent feedback windows for the same
/// route, which keeps the PRNG replay deterministic.
fn apply_feedback(
    shared: &Shared,
    model: ModelId,
    xs: &[Vec<u8>],
    ys: &[usize],
) -> Result<Arc<TMModel>, ServeError> {
    let mut online = shared.online.plock();
    let tuner = online.get_mut(&model.0).ok_or(ServeError::FeedbackDisabled(model))?;
    tuner.feedback_batch(xs, ys)?;
    Ok(Arc::new(tuner.model()))
}

/// Shared tail of the per-request supervision protocol, for every job
/// flavour: on success, publish this replica's metrics BEFORE replying
/// (a client that got its answer always sees it reflected in
/// `stats()`); on a caught panic, respawn the replica and fail only
/// the offending request.
fn reply_or_respawn<T>(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
    outcome: std::thread::Result<Result<T, CoreError>>,
    reply: mpsc::Sender<Result<T, ServeError>>,
) {
    match outcome {
        Ok(result) => {
            shared.metrics.plock()[idx].metrics = state.service.metrics.clone();
            let _ = reply.send(result.map_err(ServeError::Core));
        }
        Err(_panic) => {
            respawn_replica(shared, idx, state, my_version);
            let _ = reply.send(Err(ServeError::WorkerPanicked { replica: idx }));
        }
    }
}

/// Supervision: a panicking request may have left the replica in an
/// arbitrary state.  Rebuild the engine from the spec, carry the
/// counters over (plus the error), reprogram from the cell's current
/// assignment for this replica (its affine model — or its canary
/// candidate, if it hosts one), then let the caller fail only the
/// offending request.
fn respawn_replica(shared: &Shared, idx: usize, state: &mut WorkerState, my_version: &mut u64) {
    let mut carried = state.service.metrics.clone();
    carried.errors += 1;
    state.service = InferenceService::new(shared.spec.build());
    // The fresh engine is unprogrammed: the reprogram-skip memo must
    // not survive the rebuild.
    state.last_model = None;
    state.service.metrics = carried;
    {
        let mut per = shared.metrics.plock();
        per[idx].respawns += 1;
        per[idx].metrics = state.service.metrics.clone();
    }
    // Every respawn is a breaker strike: a replica that keeps dying is
    // flapping and gets quarantined instead of thrashing the pool.
    breaker_trip(shared, idx);
    *my_version = program_from_cell(shared, idx, state);
}

/// Swap this worker's service to the model the cell assigns IT — its
/// canary candidate when this replica hosts one, its affine registered
/// model otherwise — and acknowledge the version (the worker half of
/// the fence).  Also the respawn path: called with a freshly built
/// engine, it re-installs the assigned model.  Returns the version
/// applied.
///
/// A fence that does not change this replica's model (same Arc as the
/// last programmed one — e.g. a sibling became a canary, or another
/// model was registered) acks without touching the engine, so fences
/// cost the non-participating replicas one drain, not one reprogram.
fn program_from_cell(shared: &Shared, idx: usize, state: &mut WorkerState) -> u64 {
    let (target, model) = {
        let cell = shared.cell.plock();
        let canary = cell
            .canary_on(idx)
            .map(|c| (c.model_id, Arc::clone(&c.candidate)));
        state.canary_of = canary.as_ref().map(|(m, _)| *m);
        state.assigned = cell.assign[idx];
        let model = match canary {
            Some((_, candidate)) => Some(candidate),
            None => state.assigned.and_then(|m| cell.registry.model(m)),
        };
        (cell.version, model)
    };
    // Program outside the lock: encoding + programming a large model is
    // the slow part, and siblings must be able to ack concurrently.
    let failure = match &model {
        // Memo-skip: the engine is untouched, so the recorded digest
        // stays valid (if a fault corrupted it meanwhile, the next
        // verify catches the mismatch and heals — re-recording here
        // would instead bless the corruption as golden).
        Some(m) if state.last_model.as_ref().is_some_and(|l| Arc::ptr_eq(l, m)) => None,
        Some(m) => match state.service.reprogram(m) {
            Ok(()) => {
                state.last_model = Some(Arc::clone(m));
                record_digest(shared, idx, &state.service);
                None
            }
            Err(e) => {
                // A failed swap must not leave this replica on the
                // stale model: a single core keeps its old program
                // when the new one overflows instruction memory, and a
                // multi-core can stop half-programmed.  Rebuild the
                // engine unprogrammed (counters carried) so the
                // replica serves NotProgrammed, never version N-1.
                let carried = state.service.metrics.clone();
                state.service = InferenceService::new(shared.spec.build());
                state.service.metrics = carried;
                state.last_model = None;
                shared.digests[idx].store(0, Ordering::Release);
                Some(e)
            }
        },
        None => {
            // Nothing assigned — or the assigned model was retired.  A
            // replica must never keep serving retired content, so
            // rebuild unprogrammed; a never-programmed engine is
            // already in that state and acks without a rebuild.
            if state.last_model.is_some() {
                let carried = state.service.metrics.clone();
                state.service = InferenceService::new(shared.spec.build());
                state.service.metrics = carried;
                state.last_model = None;
            }
            shared.digests[idx].store(0, Ordering::Release);
            None
        }
    };
    // Keep the published per-replica metrics fresh (reprogram bumps a
    // counter outside the job path).
    shared.metrics.plock()[idx].metrics = state.service.metrics.clone();
    let mut cell = shared.cell.plock();
    if cell.acks[idx] < target {
        cell.acks[idx] = target;
        cell.errors[idx] = failure.map(|e| (target, e));
        shared.fence_cv.notify_all();
    }
    target
}

/// Record the digest of this replica's freshly-derived program
/// buffers as the fence-time golden reference (no-op with scrubbing
/// off — the integrity layer then costs literally nothing).
fn record_digest(shared: &Shared, idx: usize, service: &InferenceService) {
    if shared.integrity_cfg.scrub_interval.is_none() {
        return;
    }
    shared.digests[idx].store(service.program_digest().unwrap_or(0), Ordering::Release);
}

/// Verify this replica's program memory against its fence-time digest
/// and self-heal on mismatch: re-derive the programs from the golden
/// model `Arc` (which replica-local corruption can never touch),
/// re-verify, and only then serve.  A heal that cannot restore the
/// digest respawns the replica from its spec and trips the breaker.
/// Returns `false` only on that failed-heal path.
fn verify_and_heal(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
) -> bool {
    let recorded = shared.digests[idx].load(Ordering::Acquire);
    if recorded == 0 {
        // Nothing recorded: unprogrammed replica, failed swap, or
        // scrubbing off — nothing to verify against.
        return true;
    }
    let Some(current) = state.service.program_digest() else {
        return true;
    };
    shared.integrity.scrubs.fetch_add(1, Ordering::AcqRel);
    if current == recorded {
        return true;
    }
    shared.integrity.corruptions_detected.fetch_add(1, Ordering::AcqRel);
    let healed = match &state.last_model {
        Some(model) => {
            // The memo Arc IS the golden copy this digest was recorded
            // from; re-deriving from it must reproduce the digest
            // exactly (program derivation is deterministic).
            state.service.reprogram(model).is_ok()
                && state.service.program_digest() == Some(recorded)
        }
        None => false,
    };
    if healed {
        shared.integrity.heals.fetch_add(1, Ordering::AcqRel);
        shared.metrics.plock()[idx].metrics = state.service.metrics.clone();
        return true;
    }
    // Unhealable in place (golden Arc gone, or the re-derive itself
    // misbehaved): heavy hammer — respawn from the spec, which also
    // trips the breaker toward quarantine.
    shared.integrity.failed_heals.fetch_add(1, Ordering::AcqRel);
    respawn_replica(shared, idx, state, my_version);
    false
}

/// One breaker strike against replica `idx` (panic respawn or failed
/// heal).  `breaker_trips` strikes inside the rolling window
/// quarantine the replica with exponential backoff.  Inert unless the
/// integrity layer is on — pools without a scrub cadence keep the
/// pre-breaker semantics exactly.
fn breaker_trip(shared: &Shared, idx: usize) {
    let cfg = &shared.integrity_cfg;
    if cfg.scrub_interval.is_none() {
        return;
    }
    let now = Instant::now();
    let mut b = shared.breakers[idx].plock();
    b.trips.retain(|t| now.duration_since(*t) <= cfg.breaker_window);
    b.trips.push(now);
    if b.trips.len() >= cfg.breaker_trips as usize && b.until.is_none() {
        let hold = cfg
            .quarantine_base
            .saturating_mul(1u32 << b.level.min(16))
            .min(cfg.quarantine_max);
        b.level = b.level.saturating_add(1);
        b.until = Some(now + hold);
        b.trips.clear();
        drop(b);
        shared.quarantined[idx].store(true, Ordering::Release);
        shared.integrity.quarantines.fetch_add(1, Ordering::AcqRel);
    }
}

/// The half-open gate a quarantined replica must pass to rejoin: once
/// the hold expires, re-derive from the cell (the authoritative golden
/// source) and verify the digest.  A clean probe clears the mirror and
/// counts a rejoin; a dirty one re-quarantines with doubled backoff.
/// Returns whether the replica rejoined.
fn breaker_half_open(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
) -> bool {
    let expired = {
        let b = shared.breakers[idx].plock();
        b.until.is_none_or(|t| Instant::now() >= t)
    };
    if !expired {
        return false;
    }
    // The probe: a full re-derive from the cell plus a digest check —
    // the same work a Critical verify request would drive, without
    // occupying the admission queues.
    *my_version = program_from_cell(shared, idx, state);
    let recorded = shared.digests[idx].load(Ordering::Acquire);
    let clean = recorded == 0 || state.service.program_digest() == Some(recorded);
    let mut b = shared.breakers[idx].plock();
    if clean {
        b.until = None;
        b.trips.clear();
        drop(b);
        shared.quarantined[idx].store(false, Ordering::Release);
        shared.integrity.rejoins.fetch_add(1, Ordering::AcqRel);
        true
    } else {
        let cfg = &shared.integrity_cfg;
        let hold = cfg
            .quarantine_base
            .saturating_mul(1u32 << b.level.min(16))
            .min(cfg.quarantine_max);
        b.level = b.level.saturating_add(1);
        b.until = Some(Instant::now() + hold);
        false
    }
}

/// Background scrubber: every `interval`, queue one [`Job::Scrub`] on
/// each routable replica's own shard (Low class — scrubs never delay
/// real traffic) and sweep scrubs stranded on dead replicas' shards
/// (thieves never take a foreign scrub).  At most one scrub is queued
/// per replica regardless of cadence-to-service-time ratio.
fn scrubber_loop(shared: &Arc<Shared>, interval: Duration) {
    // Doze in small ticks so shutdown never waits a full interval.
    let tick = interval.min(Duration::from_millis(20));
    let mut acc = Duration::ZERO;
    loop {
        std::thread::sleep(tick);
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        acc += tick;
        if acc >= interval {
            acc = Duration::ZERO;
            enqueue_scrubs(shared);
        }
    }
}

/// One scrubber tick (see [`scrubber_loop`]).  Scrub jobs are counted
/// in the pool-wide `Low` class counters — the lock-free empty-class
/// skip in [`next_job`] would otherwise never see them — but carry no
/// per-model counters, so per-model reconciliation is untouched.
fn enqueue_scrubs(shared: &Shared) {
    let ci = Priority::Low.index();
    let mut pushed = false;
    let mut swept = false;
    for i in 0..shared.shards.len() {
        let routable = shared.alive_mirror[i].load(Ordering::Acquire)
            && !shared.retire[i].load(Ordering::Acquire)
            && !shared.quarantined[i].load(Ordering::Acquire);
        let mut q = shared.shards[i].q.plock();
        if q.closed {
            continue;
        }
        let queued = q.classes[ci].iter().filter(|j| matches!(j, Job::Scrub { .. })).count();
        if !routable {
            if queued > 0 {
                q.classes[ci].retain(|j| !matches!(j, Job::Scrub { .. }));
                for _ in 0..queued {
                    shared.counters[ci].pop_shed();
                }
                swept = true;
            }
            continue;
        }
        if queued == 0 {
            shared.counters[ci].admit();
            q.classes[ci].push_back(Job::Scrub { replica: i });
            pushed = true;
        }
    }
    if swept {
        wake_space(shared);
    }
    if pushed {
        // Scrubs are replica-pinned: every owner must wake.
        wake_work(shared, true);
    }
}

/// Autoscaling supervisor: samples total queue depth and the
/// deadline-miss delta every `interval`; grows the pool toward `max`
/// under pressure (depth above `depth_per_replica` per live replica,
/// or any miss this interval) and retires one replica toward `min`
/// (never a canary, and under `Dedicated` never a model's last pinned
/// replica) after `idle_ticks` consecutive idle intervals.
fn supervisor_loop(shared: &Arc<Shared>, cfg: &AutoscaleConfig) {
    let mut idle_ticks = 0u32;
    let mut last_misses = 0u64;
    loop {
        std::thread::sleep(cfg.interval);
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let depth: u64 = shared.counters.iter().map(|c| c.depth()).sum();
        let misses: u64 = shared
            .counters
            .iter()
            .map(|c| c.snapshot().deadline_misses)
            .sum();
        let new_misses = misses.saturating_sub(last_misses);
        last_misses = misses;
        // Retiring replicas are on their way out, and a quarantined
        // replica serves nothing: count neither for pressure nor for
        // the `min` floor — which is what lets the autoscaler spawn a
        // replacement for a quarantine-stuck replica.
        let live = (0..shared.alive_mirror.len())
            .filter(|&i| {
                shared.alive_mirror[i].load(Ordering::Acquire)
                    && !shared.retire[i].load(Ordering::Acquire)
                    && !shared.quarantined[i].load(Ordering::Acquire)
            })
            .count();
        let pressured =
            depth > (cfg.depth_per_replica * live.max(1)) as u64 || new_misses > 0;
        if pressured {
            idle_ticks = 0;
            if live < cfg.max {
                scale_up(shared);
            }
        } else if depth == 0 {
            idle_ticks += 1;
            if idle_ticks >= cfg.idle_ticks && live > cfg.min {
                idle_ticks = 0;
                scale_down(shared);
            }
        } else {
            idle_ticks = 0;
        }
    }
}

/// Revive one dead slot whose previous worker has fully exited.
fn scale_up(shared: &Arc<Shared>) {
    let idx = {
        let mut cell = shared.cell.plock();
        let slot = (0..cell.alive.len())
            .find(|&i| !cell.alive[i] && shared.exited[i].load(Ordering::Acquire));
        let Some(i) = slot else { return };
        cell.alive[i] = true;
        cell.acks[i] = 0;
        cell.errors[i] = None;
        i
    };
    shared.retire[idx].store(false, Ordering::Release);
    shared.exited[idx].store(false, Ordering::Release);
    shared.alive_mirror[idx].store(true, Ordering::Release);
    let handle = spawn_worker(shared, idx);
    shared.extra_workers.plock().push(handle);
    shared.scale_ups.fetch_add(1, Ordering::AcqRel);
}

/// Flag the highest-index live, non-canary, non-retiring replica for
/// retirement; it exits at its next pop and its queued jobs are stolen
/// by the survivors.  Under `Dedicated` sharding a model's LAST pinned
/// replica is never retired — no survivor could adopt its traffic.
fn scale_down(shared: &Shared) {
    let victim = {
        let cell = shared.cell.plock();
        (0..cell.alive.len()).rev().find(|&i| {
            if !cell.alive[i]
                || cell.is_canary(i)
                || shared.retire[i].load(Ordering::Acquire)
            {
                return false;
            }
            match (shared.sharding, cell.assign[i]) {
                (ShardingPolicy::Dedicated, Some(m)) if cell.registry.contains(m) => {
                    (0..cell.alive.len()).any(|j| {
                        j != i
                            && cell.alive[j]
                            && !cell.is_canary(j)
                            && !shared.retire[j].load(Ordering::Acquire)
                            && cell.assign[j] == Some(m)
                    })
                }
                _ => true,
            }
        })
    };
    let Some(idx) = victim else { return };
    shared.retire[idx].store(true, Ordering::Release);
    shared.scale_downs.fetch_add(1, Ordering::AcqRel);
    // Wake everyone: the retiring worker must notice the flag.
    wake_work(shared, true);
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).generate();
        (crate::trainer::train_model(&shape, &data, 4, 2), data)
    }

    #[test]
    fn rpc_roundtrip() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.inferences, 96);
        assert_eq!(stats.reprograms, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn infer_before_program_is_error_not_crash() {
        let (h, mut join) = spawn(EngineSpec::base());
        assert!(matches!(
            h.infer(vec![vec![0u8; 12]]),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn concurrent_clients_share_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model).unwrap();
        let mut threads = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            let rows = data.xs.clone();
            threads.push(std::thread::spawn(move || h.infer(rows).unwrap().len()));
        }
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 4 * 96);
        assert_eq!(h.stats().unwrap().inferences, 4 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn reprogram_mid_serving_takes_effect() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let new_model = crate::trainer::train_model(&shape, &drifted, 4, 3);
        h.program(new_model).unwrap();
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before.len(), after.len());
        let stats = h.pool_stats();
        assert_eq!(stats.version, 2);
        assert_eq!(stats.total.reprograms, 2);
        // The fence: both replicas on the new version once program() returned.
        for r in &stats.replicas {
            assert_eq!(r.model_version, 2);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn online_feedback_is_opt_in_and_rides_the_fence() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();

        // Feedback before opting in is a typed error, not a pool death.
        assert!(matches!(
            h.feedback(data.xs.clone(), data.ys.clone()),
            Err(ServeError::FeedbackDisabled(_))
        ));
        assert_eq!(h.online_rows_fed(), None);

        h.enable_online_feedback(7).unwrap();
        h.feedback(data.xs.clone(), data.ys.clone()).unwrap();
        assert_eq!(h.online_rows_fed(), Some(96));

        // The mini-fence: one version bump, every replica on it.
        let stats = h.pool_stats();
        assert_eq!(stats.version, 2);
        for r in &stats.replicas {
            assert_eq!(r.model_version, 2);
        }

        // The served model is exactly the one a lone OnlineTrainer
        // produces from the same snapshot, seed and window.
        let mut mirror = OnlineTrainer::from_model(&model, 7);
        mirror.feedback_batch(&data.xs, &data.ys).unwrap();
        let mut reference = InferenceService::new(EngineSpec::base().build());
        reference.reprogram(&mirror.model()).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap(), reference.infer_all(&data.xs).unwrap());

        // A malformed window is rejected atomically: typed error, no
        // version bump, no rows folded in.
        let ragged = vec![vec![0u8; 12], vec![0u8; 5]];
        assert!(matches!(
            h.feedback(ragged, vec![0, 1]),
            Err(ServeError::Feedback(FeedbackError::WidthMismatch { .. }))
        ));
        assert_eq!(h.online_rows_fed(), Some(96));
        assert_eq!(h.pool_stats().version, 2);

        h.shutdown();
        join.join();
    }

    #[test]
    fn external_reprogram_reseeds_the_online_trainer() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        h.enable_online_feedback(11).unwrap();
        h.feedback(data.xs.clone(), data.ys.clone()).unwrap();

        // An offline retrain supersedes the trainer's accumulated TA
        // state: the next feedback window must fine-tune the newly
        // installed model, not the pre-swap one.
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let new_model = crate::trainer::train_model(&shape, &drifted, 4, 3);
        h.program(new_model.clone()).unwrap();
        h.feedback(drifted.xs.clone(), drifted.ys.clone()).unwrap();

        // Mirror: seed, feed the first window, reseed at the swap, feed
        // the second — byte-identical serving proves the reseed landed.
        let mut mirror = OnlineTrainer::from_model(&model, 11);
        mirror.feedback_batch(&data.xs, &data.ys).unwrap();
        mirror.reseed_from_model(&new_model);
        mirror.feedback_batch(&drifted.xs, &drifted.ys).unwrap();
        let mut reference = InferenceService::new(EngineSpec::base().build());
        reference.reprogram(&mirror.model()).unwrap();
        assert_eq!(
            h.infer(drifted.xs.clone()).unwrap(),
            reference.infer_all(&drifted.xs).unwrap()
        );
        // rows_fed is a lifetime counter: both windows count.
        assert_eq!(h.online_rows_fed(), Some(192));

        h.shutdown();
        join.join();
    }

    #[test]
    fn retiring_a_model_drops_its_online_trainer() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();
        h.enable_online_feedback(3).unwrap();
        h.feedback(data.xs.clone(), data.ys.clone()).unwrap();
        assert!(h.online_rows_fed().is_some());
        h.retire_model(h.model_route()).unwrap();
        assert_eq!(h.online_rows_fed(), None);
        h.shutdown();
        join.join();
    }

    #[test]
    fn telemetry_matches_single_service_and_reports_fence_version() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();

        let mut reference = InferenceService::new(EngineSpec::base().build());
        reference.reprogram(&model).unwrap();
        let (want_preds, want_margins) = reference.infer_with_margins(&data.xs).unwrap();

        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_preds);
        assert_eq!(tel.margins, want_margins);
        assert_eq!(tel.model_version, 1);

        // Telemetry rides the version fence like any request.
        h.program(model).unwrap();
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.model_version, 2);

        // Malformed telemetry probes are typed errors, not pool deaths.
        assert!(matches!(
            h.infer_telemetry(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn malformed_requests_do_not_kill_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();

        assert!(matches!(
            h.infer(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        let ragged = vec![vec![0u8; 12], vec![0u8; 5]];
        assert!(matches!(
            h.infer(ragged),
            Err(ServeError::Core(CoreError::BadBatch { .. }))
        ));
        // The pool keeps serving on the same handle.
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.inferences, 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn injected_panic_respawns_replica_and_pool_survives() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();

        match h.inject_panic() {
            Err(ServeError::WorkerPanicked { replica }) => assert_eq!(replica, 0),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        // Same handle, same answers: the replica was respawned from the
        // last-programmed model.
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before, after);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 1);
        assert!(stats.replicas[0].alive);
        // The panic is visible as an error, and counters survived.
        assert_eq!(stats.total.errors, 1);
        assert_eq!(stats.total.inferences, 2 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_swap_never_leaves_stale_or_mixed_models() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        // A bigger model that cannot fit the instruction memory sized
        // exactly for the small one.
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        let n_big = crate::isa::instruction_count(&big);
        assert!(n_big > n_small, "test premise: {n_big} > {n_small}");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 2);
        h.program(small.clone()).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());

        // The too-big model must fail the swap as a typed error…
        assert!(matches!(h.program(big), Err(ServeError::Core(_))));
        // …and replicas must be unprogrammed — not stale on the old
        // model with the new version acked.
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        // A fitting reprogram fully recovers the pool.
        h.program(small).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());
        h.shutdown();
        join.join();
    }

    #[test]
    fn dead_pool_errors_instead_of_hanging() {
        use crate::accel::core::AccelConfig;
        use crate::accel::multicore::ParallelMode;

        // An invalid spec panics in build() at worker startup — outside
        // the per-request catch_unwind.  The DeathWatch must surface
        // this as errors, never as a hang.
        let bad = EngineSpec::Multi {
            cores: 0,
            per_core: AccelConfig::multicore_core(),
            parallel: ParallelMode::Auto,
        };
        let (h, mut join) = spawn_pool(bad, 2);
        join.join();
        let (model, data) = trained();
        assert!(matches!(h.program(model), Err(ServeError::ShutDown)));
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::ShutDown) | Err(ServeError::WorkerGone)
        ));
    }

    #[test]
    fn canary_serves_only_the_mirrored_stream() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model_a.clone()).unwrap();
        let want_a = h.infer(data.xs.clone()).unwrap();

        // Reference answers for both models.
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();
        assert_ne!(want_a, want_b, "test premise: the models must disagree");

        // No canary yet: canary-targeted requests are typed errors.
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        assert!(h.canary_replica().is_none());

        let replica = h.program_canary(model_b.clone()).unwrap();
        assert_eq!(replica, 2, "highest-index live replica is the canary");
        assert_eq!(h.canary_replica(), Some(2));
        assert_eq!(h.pool_stats().canary, Some(2));

        // Live traffic NEVER sees the candidate; the mirror ONLY does.
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        assert_eq!(h.infer_canary(data.xs.clone()).unwrap(), want_b);
        let tel = h.infer_telemetry_canary(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_b);
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_a);

        // Dismiss: the canary replica returns to the pool model.
        assert!(h.dismiss_canary().unwrap());
        assert!(h.canary_replica().is_none());
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        // Dismissal is idempotent.
        assert!(!h.dismiss_canary().unwrap());

        // Versions strictly monotone: program(1), canary(2), dismiss(3).
        let stats = h.pool_stats();
        assert_eq!(stats.version, 3);
        for r in &stats.replicas {
            assert_eq!(r.model_version, 3);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_promote_broadcasts_the_candidate() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        // Promote with no canary is a typed error.
        assert!(matches!(h.promote_canary(), Err(ServeError::Canary(_))));
        h.program(model_a).unwrap();
        h.program_canary(model_b).unwrap();
        h.promote_canary().unwrap();
        assert!(h.canary_replica().is_none());
        // Every replica now serves the candidate.
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_b);
        }
        let stats = h.pool_stats();
        assert_eq!(stats.version, 3); // program, canary, promote
        for r in &stats.replicas {
            assert_eq!(r.model_version, 3);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_panic_respawns_with_the_candidate_not_the_pool_model() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        // No canary yet: canary-targeted injection is a typed error.
        assert!(matches!(h.inject_panic_canary(), Err(ServeError::Canary(_))));
        h.program(model_a).unwrap();
        let want_a = h.infer(data.xs.clone()).unwrap();
        let replica = h.program_canary(model_b).unwrap();

        // Panic the CANARY worker mid-request: supervision must rebuild
        // it serving the CANDIDATE (a respawn onto the pool model would
        // make every paired window tie and promote any candidate).
        match h.inject_panic_canary() {
            Err(ServeError::WorkerPanicked { replica: r }) => assert_eq!(r, replica),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        assert_eq!(h.infer_canary(data.xs.clone()).unwrap(), want_b);
        // And the pool half is untouched throughout.
        for _ in 0..4 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[replica].respawns, 1);
        assert!(stats.replicas[replica].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_requires_a_baseline_and_two_replicas() {
        let (model, _) = trained();
        // No baseline model programmed yet.
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        assert!(matches!(
            h.program_canary(model.clone()),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
        // Single-replica pool: a "canary" would be a whole-pool swap.
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        assert!(matches!(
            h.program_canary(model),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_canary_program_is_recoverable_by_dismissal() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        assert!(crate::isa::instruction_count(&big) > n_small, "test premise");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 3);
        h.program(small).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        // The candidate overflows the canary replica's memories: typed
        // error, and ONLY that replica was ever disturbed.
        assert!(matches!(h.program_canary(big), Err(ServeError::Core(_))));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        // Dismissal restores the canary replica to the pool model.
        assert!(h.dismiss_canary().unwrap());
        assert!(h.canary_replica().is_none());
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn pool_broadcast_dismisses_an_active_canary() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        h.program_canary(model.clone()).unwrap();
        assert_eq!(h.canary_replica(), Some(1));
        h.program(model).unwrap();
        assert!(h.canary_replica().is_none());
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn deadline_request_errors_on_a_stalled_pool() {
        use std::time::{Duration, Instant};

        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        // Idle pool: a generous deadline behaves exactly like infer().
        let want = h.infer(data.xs.clone()).unwrap();
        assert_eq!(
            h.infer_deadline(data.xs.clone(), Duration::from_secs(30)).unwrap(),
            want
        );
        // Stall the lone replica; a tight deadline must come back as a
        // typed error instead of blocking until the stall clears.
        let stall = h.inject_stall(Duration::from_millis(400)).unwrap();
        let t0 = Instant::now();
        assert!(matches!(
            h.infer_deadline(data.xs.clone(), Duration::from_millis(40)),
            Err(ServeError::DeadlineExceeded)
        ));
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "deadline must not wait out the stall"
        );
        // Once the stall ends the pool recovers; the expired job was
        // shed unexecuted (its late answer had nowhere to go anyway).
        stall.recv().unwrap().unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        h.shutdown();
        join.join();
    }

    #[test]
    fn shutdown_and_join_are_idempotent() {
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.shutdown();
        h.shutdown();
        join.join();
        join.join();
        assert!(matches!(h.infer(vec![vec![0u8; 4]]), Err(ServeError::ShutDown)));
        let (m, _) = trained();
        assert!(matches!(h.program(m), Err(ServeError::ShutDown)));
        // Stats still readable after shutdown (final reporting).
        assert_eq!(h.stats().unwrap().inferences, 0);
    }

    #[test]
    fn critical_overtakes_queued_low_under_stall() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        h.infer(data.xs.clone()).unwrap();

        // Wedge the lone replica so everything below queues behind it.
        let stall = h.inject_stall(Duration::from_millis(200)).unwrap();
        std::thread::sleep(Duration::from_millis(40)); // stall now being served
        let mut lows = Vec::new();
        for _ in 0..3 {
            let h = h.clone();
            let rows = data.xs[..16].to_vec();
            lows.push(std::thread::spawn(move || {
                h.infer_class(rows, Priority::Low).unwrap();
                Instant::now()
            }));
        }
        std::thread::sleep(Duration::from_millis(40)); // lows are queued
        let crit = {
            let h = h.clone();
            let rows = data.xs[..16].to_vec();
            std::thread::spawn(move || {
                h.infer_class(rows, Priority::Critical).unwrap();
                Instant::now()
            })
        };
        // Class-major pop: the Critical request submitted LAST finishes
        // before every queued Low one.
        let crit_done = crit.join().unwrap();
        for t in lows {
            let low_done = t.join().unwrap();
            assert!(
                crit_done < low_done,
                "Critical must overtake queued Low requests"
            );
        }
        stall.recv().unwrap().unwrap();
        h.shutdown();
        join.join();
    }

    #[test]
    fn reject_policy_returns_typed_overloaded() {
        let (model, data) = trained();
        let cfg = PoolConfig {
            replicas: 1,
            admission: AdmissionConfig::uniform(1, ShedPolicy::Reject),
            autoscale: None,
            integrity: IntegrityConfig::default(),
        };
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        let stall = h.inject_stall(Duration::from_millis(250)).unwrap();
        // Wait until the stall is being served (Normal queue empty).
        while h.admission_stats().class(Priority::Normal).depth > 0 {
            std::thread::yield_now();
        }
        // Fill the Low queue (cap 1) with one queued request…
        let queued = {
            let h = h.clone();
            let rows = data.xs.clone();
            std::thread::spawn(move || h.infer_class(rows, Priority::Low))
        };
        while h.admission_stats().class(Priority::Low).depth == 0 {
            std::thread::yield_now();
        }
        // …so the next Low submission is refused with the typed error.
        assert!(matches!(
            h.infer_class(data.xs.clone(), Priority::Low),
            Err(ServeError::Overloaded)
        ));
        assert_eq!(queued.join().unwrap().unwrap(), want);
        stall.recv().unwrap().unwrap();
        let stats = h.admission_stats();
        let low = stats.class(Priority::Low);
        assert_eq!(low.admitted, 1);
        assert_eq!(low.rejected, 1);
        assert_eq!(low.served, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn shed_oldest_evicts_the_oldest_queued_request() {
        let (model, data) = trained();
        let cfg = PoolConfig {
            replicas: 1,
            admission: AdmissionConfig::uniform(1, ShedPolicy::ShedOldest),
            autoscale: None,
            integrity: IntegrityConfig::default(),
        };
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        let stall = h.inject_stall(Duration::from_millis(250)).unwrap();
        while h.admission_stats().class(Priority::Normal).depth > 0 {
            std::thread::yield_now();
        }
        // A queues first, then B arrives: B's admission evicts A
        // (freshest data wins), and B gets A's slot.
        let first = {
            let h = h.clone();
            let rows = data.xs.clone();
            std::thread::spawn(move || h.infer_class(rows, Priority::Low))
        };
        while h.admission_stats().class(Priority::Low).depth == 0 {
            std::thread::yield_now();
        }
        let second = h.infer_class(data.xs.clone(), Priority::Low);
        assert!(matches!(first.join().unwrap(), Err(ServeError::Overloaded)));
        assert_eq!(second.unwrap(), want);
        stall.recv().unwrap().unwrap();
        let stats = h.admission_stats();
        let low = stats.class(Priority::Low);
        assert_eq!(low.admitted, 2);
        assert_eq!(low.shed, 1);
        assert_eq!(low.served, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn infeasible_deadline_is_rejected_at_submit() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        // Warm the service-time estimator with a real request.
        h.infer(data.xs.clone()).unwrap();

        // Pile up queued work so est × ahead dwarfs any slack.
        let stalls: Vec<_> = (0..64)
            .map(|_| h.inject_stall(Duration::from_millis(2)).unwrap())
            .collect();
        assert!(matches!(
            h.infer_deadline(data.xs.clone(), Duration::from_micros(1)),
            Err(ServeError::DeadlineExceeded)
        ));
        let stats = h.admission_stats();
        let normal = stats.class(Priority::Normal);
        assert!(normal.rejected >= 1, "feasibility reject must be counted");
        assert!(normal.deadline_misses >= 1);
        for s in stalls {
            s.recv().unwrap().unwrap();
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn admission_counters_reconcile_when_idle() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();
        for class in Priority::ALL {
            for _ in 0..3 {
                h.infer_class(data.xs[..8].to_vec(), class).unwrap();
            }
        }
        h.infer_telemetry_class(data.xs[..8].to_vec(), Priority::High).unwrap();
        let stats = h.admission_stats();
        for class in Priority::ALL {
            let c = stats.class(class);
            let want = if class == Priority::High { 4 } else { 3 };
            assert_eq!(c.admitted, want, "class {class}");
            assert_eq!(c.served, want, "class {class}");
            assert_eq!(c.depth, 0);
            assert_eq!(c.rejected + c.shed + c.deadline_misses, 0);
        }
        assert_eq!(stats.depth_total(), 0);
        assert_eq!(stats.lost_total(), 0);
        h.shutdown();
        join.join();
    }

    #[test]
    fn drop_reply_fault_surfaces_worker_gone() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        h.inject_fault(FaultPlan::drop_reply(0));
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::WorkerGone)
        ));
        // The fault consumed itself; the replica is healthy.
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 0);
        assert!(stats.replicas[0].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn panic_on_nth_job_fault_fires_once_and_respawns() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        // nth = 2: the next job sails through, the one after panics.
        h.inject_fault(FaultPlan::panic_on_job(0, 2));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::WorkerPanicked { replica: 0 })
        ));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 1);
        assert!(stats.replicas[0].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn stall_fault_wedges_only_the_chosen_replica() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        h.inject_fault(FaultPlan::stall(0, Duration::from_millis(150)));
        // Requests keep answering correctly; at most one rides out the
        // stall.  No panics, no respawns, nobody stuck forever.
        let t0 = Instant::now();
        for _ in 0..4 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        }
        assert!(t0.elapsed() < Duration::from_secs(5));
        let stats = h.pool_stats();
        assert!(stats.replicas.iter().all(|r| r.alive));
        assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 0);
        h.shutdown();
        join.join();
    }

    #[test]
    fn autoscaler_grows_under_pressure_and_shrinks_when_idle() {
        let (model, data) = trained();
        let cfg = PoolConfig {
            replicas: 1,
            admission: AdmissionConfig::default(),
            autoscale: Some(AutoscaleConfig {
                min: 1,
                max: 3,
                interval: Duration::from_millis(10),
                depth_per_replica: 2,
                idle_ticks: 3,
            }),
            integrity: IntegrityConfig::default(),
        };
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        // Saturate the lone replica so queue depth builds up.
        let stall = h.inject_stall(Duration::from_millis(150)).unwrap();
        let clients: Vec<_> = (0..8)
            .map(|_| {
                let h = h.clone();
                let rows = data.xs[..16].to_vec();
                std::thread::spawn(move || h.infer(rows).unwrap())
            })
            .collect();
        let t0 = Instant::now();
        while h.admission_stats().scale_ups == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "no scale-up");
            std::thread::sleep(Duration::from_millis(5));
        }
        for c in clients {
            assert_eq!(c.join().unwrap().len(), 16);
        }
        stall.recv().unwrap().unwrap();
        // Idle again: the supervisor retires back toward min.
        let t0 = Instant::now();
        while h.admission_stats().scale_downs == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "no scale-down");
            std::thread::sleep(Duration::from_millis(5));
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn deadline_telemetry_and_canary_variants_work() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        // Idle pool: generous deadlines behave like the plain RPCs.
        let tel = h
            .infer_telemetry_deadline(data.xs.clone(), Duration::from_secs(30))
            .unwrap();
        assert_eq!(tel.preds.len(), data.len());
        h.program_canary(model).unwrap();
        let preds = h
            .infer_canary_deadline(data.xs.clone(), Duration::from_secs(30))
            .unwrap();
        assert_eq!(preds.len(), data.len());
        let tel = h
            .infer_telemetry_canary_deadline(data.xs.clone(), Duration::from_secs(30))
            .unwrap();
        assert_eq!(tel.preds.len(), data.len());
        h.dismiss_canary().unwrap();
        // With no canary, the deadline canary RPCs are typed errors.
        assert!(matches!(
            h.infer_canary_deadline(data.xs.clone(), Duration::from_millis(50)),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn register_retire_and_route_models() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);

        // Reference answers for both models.
        let mut svc_a = InferenceService::new(EngineSpec::base().build());
        svc_a.reprogram(&model_a).unwrap();
        let want_a = svc_a.infer_all(&data.xs).unwrap();
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();
        assert_ne!(want_a, want_b, "test premise: the models must disagree");

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        let a = h.register_model("tenant-a", model_a.clone()).unwrap();
        let b = h.register_model("tenant-b", model_b).unwrap();
        assert_eq!(a, ModelId(1));
        assert_eq!(b, ModelId(2));
        // Dedup is scoped to the tenant name: the SAME name with
        // identical content hands back the existing id, while identical
        // content under a NEW name is a fresh, isolated tenant.
        let same = h
            .register_model_outcome("tenant-a", Arc::new(model_a.clone()))
            .unwrap();
        assert_eq!((same.id, same.deduped, same.name.as_str()), (a, true, "tenant-a"));
        let copy = h
            .register_model_outcome("tenant-a-copy", Arc::new(model_a))
            .unwrap();
        assert_ne!(copy.id, a, "identical bytes under a new name must not alias");
        assert!(!copy.deduped);
        h.retire_model(copy.id).unwrap();

        let ha = h.with_model(a);
        let hb = h.with_model(b);
        assert_eq!(ha.infer(data.xs.clone()).unwrap(), want_a);
        assert_eq!(hb.infer(data.xs.clone()).unwrap(), want_b);

        let names: Vec<String> =
            h.model_stats().into_iter().map(|m| m.name).collect();
        assert!(names.contains(&"tenant-a".to_string()));
        assert!(names.contains(&"tenant-b".to_string()));

        // Retirement is typed and idempotent-by-error; the other
        // tenant keeps serving.
        h.retire_model(b).unwrap();
        assert!(matches!(h.retire_model(b), Err(ServeError::UnknownModel(m)) if m == b));
        assert!(matches!(
            hb.infer(data.xs.clone()),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        assert_eq!(ha.infer(data.xs.clone()).unwrap(), want_a);

        h.shutdown();
        join.join();
    }

    #[test]
    fn dedicated_pool_pins_replicas_and_types_unroutable_models() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);

        let mut svc_a = InferenceService::new(EngineSpec::base().build());
        svc_a.reprogram(&model_a).unwrap();
        let want_a = svc_a.infer_all(&data.xs).unwrap();
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool_sharded(
            EngineSpec::base(),
            PoolConfig::fixed(2),
            ShardingPolicy::Dedicated,
        );
        let a = h.register_model("tenant-a", model_a).unwrap();
        let b = h.register_model("tenant-b", model_b).unwrap();
        let ha = h.with_model(a);
        let hb = h.with_model(b);
        assert_eq!(ha.infer(data.xs.clone()).unwrap(), want_a);
        assert_eq!(hb.infer(data.xs.clone()).unwrap(), want_b);
        // Dedicated replicas never switch models for foreign traffic.
        assert_eq!(h.pool_stats().sharding_switches, 0);

        // Retiring B re-pins both replicas onto A; B's route becomes a
        // typed NoReplica instead of queueing forever.
        h.retire_model(b).unwrap();
        assert!(matches!(
            hb.infer(data.xs.clone()),
            Err(ServeError::NoReplica { model }) if model == b
        ));
        assert_eq!(ha.infer(data.xs.clone()).unwrap(), want_a);

        h.shutdown();
        join.join();
    }

    #[test]
    fn per_model_budgets_live_on_the_registry() {
        let (model, _data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        let id = h.register_model("budgeted", model).unwrap();
        assert!(h.model_budget(id).is_none());
        h.set_model_budget(id, Some(ResourceBudget::unlimited().with_luts(5000)))
            .unwrap();
        assert_eq!(h.model_budget(id).unwrap().max_luts, Some(5000));
        assert!(matches!(
            h.set_model_budget(ModelId(9), None),
            Err(ServeError::UnknownModel(_))
        ));
        h.shutdown();
        join.join();
    }

    fn scrubbed_cfg(replicas: usize, scrub_ms: u64) -> PoolConfig {
        PoolConfig {
            replicas,
            admission: AdmissionConfig::default(),
            autoscale: None,
            integrity: IntegrityConfig::scrubbed(Duration::from_millis(scrub_ms)),
        }
    }

    #[test]
    fn flipped_program_bits_are_detected_and_healed_before_serving() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), scrubbed_cfg(1, 5));
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        // Corrupt the replica's derived programs on its next pop; the
        // pre-serve verify must heal from the golden Arc so the answer
        // never diverges.
        h.inject_fault(FaultPlan::flip_model_bits(0, 0xDEAD_BEEF, 8));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        let integ = h.pool_stats().integrity;
        assert!(integ.scrubs >= 1, "pre-serve verify must run: {integ:?}");
        assert_eq!(integ.corruptions_detected, 1, "{integ:?}");
        assert_eq!(integ.heals, 1, "{integ:?}");
        assert_eq!(integ.failed_heals, 0, "{integ:?}");
        // The heal is replica-local: no fence version bump.
        assert_eq!(h.pool_stats().version, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn background_scrubber_heals_idle_replicas() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), scrubbed_cfg(2, 5));
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        h.inject_fault(FaultPlan::flip_model_bits(0, 7, 4));
        h.inject_fault(FaultPlan::flip_model_bits(1, 9, 4));
        // Fault plans fire on the next POPPED job — scrub ticks pop
        // like any job, so idle replicas get corrupted by the plan and
        // then healed by a later tick, with no client traffic at all.
        let t0 = Instant::now();
        loop {
            let integ = h.pool_stats().integrity;
            if integ.heals >= 2 {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "scrubber never healed: {integ:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(h.infer(data.xs).unwrap(), want);
        h.shutdown();
        join.join();
    }

    #[test]
    fn breaker_quarantines_flapping_replica_and_readmits_it() {
        let (model, data) = trained();
        let mut cfg = scrubbed_cfg(2, 500);
        cfg.integrity.breaker_trips = 2;
        cfg.integrity.breaker_window = Duration::from_secs(30);
        cfg.integrity.quarantine_base = Duration::from_millis(30);
        cfg.integrity.quarantine_max = Duration::from_millis(60);
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        // Two panic respawns on replica 0 inside the window trip the
        // breaker into quarantine.
        for nth in 0..2u64 {
            h.inject_fault(FaultPlan::panic_on_job(0, 1));
            // Drive jobs until replica 0's plan fires (a sibling may
            // pop some of them).
            let t0 = Instant::now();
            while h.pool_stats().replicas[0].respawns < nth + 1 {
                let _ = h.infer(data.xs[..4].to_vec());
                assert!(t0.elapsed() < Duration::from_secs(10), "plan never fired");
            }
        }
        let integ = h.pool_stats().integrity;
        assert_eq!(integ.quarantines, 1, "{integ:?}");
        // While quarantined the pool keeps serving correct answers on
        // the surviving replica.
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        // After the hold, the half-open probe readmits it.
        let t0 = Instant::now();
        while h.pool_stats().integrity.rejoins < 1 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "quarantined replica never rejoined: {:?}",
                h.pool_stats().integrity
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(h.infer(data.xs).unwrap(), want);
        h.shutdown();
        join.join();
    }

    #[test]
    fn poisoned_internal_lock_does_not_wedge_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        // Poison the metrics and model-directory locks the way a real
        // panic would: die while holding them.
        for which in 0..2 {
            let shared = Arc::clone(&h.shared);
            let t = std::thread::spawn(move || {
                if which == 0 {
                    let _g = shared.metrics.lock().unwrap();
                    panic!("poison the metrics lock");
                } else {
                    let _g = shared.model_dir.lock().unwrap();
                    panic!("poison the model directory lock");
                }
            });
            assert!(t.join().is_err(), "poisoner thread must panic");
        }
        // Serving, stats and shutdown all cross the poisoned locks.
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        let stats = h.pool_stats();
        assert!(stats.total.inferences > 0);
        h.shutdown();
        join.join();
    }

    #[test]
    fn scrub_jobs_reconcile_pool_counters() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), scrubbed_cfg(2, 5));
        h.program(model).unwrap();
        let _ = h.infer(data.xs).unwrap();
        // Let a few scrub generations through.
        std::thread::sleep(Duration::from_millis(60));
        h.shutdown();
        join.join();
        // Every admitted Low-class scrub was either served or shed at
        // teardown — the class invariant holds with scrubs in flight.
        let stats = h.admission_stats();
        let low = stats.class(Priority::Low);
        assert_eq!(low.admitted, low.served + low.shed, "{low:?}");
        let integ = h.pool_stats().integrity;
        assert_eq!(integ.corruptions_detected, 0, "{integ:?}");
    }
}
