//! Threaded request front-end: the AXIS/queue interface of the deployed
//! system, as a worker thread owning the service and an mpsc request
//! queue (offline toolchain has no tokio; the request loop is shaped
//! identically: one owner, message passing, bounded in-flight work).

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::service::{InferenceService, Metrics};
use crate::tm::model::TMModel;

/// Requests the worker accepts.
enum Request {
    Infer {
        rows: Vec<Vec<u8>>,
        reply: mpsc::Sender<anyhow::Result<Vec<usize>>>,
    },
    Program {
        model: Box<TMModel>,
        reply: mpsc::Sender<anyhow::Result<()>>,
    },
    Stats {
        reply: mpsc::Sender<Metrics>,
    },
    Shutdown,
}

/// Snapshot returned by [`ServiceHandle::stats`].
pub type ServerStats = Metrics;

/// Cloneable client handle to a running service worker.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: mpsc::Sender<Request>,
}

/// Spawn the worker thread that owns `service`.
pub fn spawn(mut service: InferenceService) -> (ServiceHandle, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<Request>();
    let join = std::thread::spawn(move || {
        while let Ok(req) = rx.recv() {
            match req {
                Request::Infer { rows, reply } => {
                    let r = service.infer_all(&rows).map_err(anyhow::Error::from);
                    let _ = reply.send(r);
                }
                Request::Program { model, reply } => {
                    let r = service.reprogram(&model).map_err(anyhow::Error::from);
                    let _ = reply.send(r);
                }
                Request::Stats { reply } => {
                    let _ = reply.send(service.metrics.clone());
                }
                Request::Shutdown => break,
            }
        }
    });
    (ServiceHandle { tx }, join)
}

impl ServiceHandle {
    /// Blocking inference RPC.
    pub fn infer(&self, rows: Vec<Vec<u8>>) -> anyhow::Result<Vec<usize>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Infer { rows, reply })
            .map_err(|_| anyhow::anyhow!("service worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("service worker dropped reply"))?
    }

    /// Blocking reprogram RPC (the runtime-tuning path).
    pub fn program(&self, model: TMModel) -> anyhow::Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Program { model: Box::new(model), reply })
            .map_err(|_| anyhow::anyhow!("service worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("service worker dropped reply"))?
    }

    pub fn stats(&self) -> anyhow::Result<ServerStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow::anyhow!("service worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("service worker dropped reply"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::Engine;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).generate();
        (crate::trainer::train_model(&shape, &data, 4, 2), data)
    }

    #[test]
    fn rpc_roundtrip() {
        let (model, data) = trained();
        let (h, join) = spawn(InferenceService::new(Engine::base()));
        h.program(model.clone()).unwrap();
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.inferences, 96);
        h.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn infer_before_program_is_error_not_crash() {
        let (h, join) = spawn(InferenceService::new(Engine::base()));
        assert!(h.infer(vec![vec![0u8; 12]]).is_err());
        h.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn concurrent_clients_share_one_accelerator() {
        let (model, data) = trained();
        let (h, join) = spawn(InferenceService::new(Engine::base()));
        h.program(model).unwrap();
        let mut threads = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            let rows = data.xs.clone();
            threads.push(std::thread::spawn(move || h.infer(rows).unwrap().len()));
        }
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 4 * 96);
        assert_eq!(h.stats().unwrap().inferences, 4 * 96);
        h.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn reprogram_mid_serving_takes_effect() {
        let (model, data) = trained();
        let (h, join) = spawn(InferenceService::new(Engine::base()));
        h.program(model.clone()).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();
        // Retrain on drifted data and swap live.
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let new_model = crate::trainer::train_model(&shape, &drifted, 4, 3);
        h.program(new_model).unwrap();
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before.len(), after.len());
        assert_eq!(h.stats().unwrap().reprograms, 2);
        h.shutdown();
        join.join().unwrap();
    }
}
