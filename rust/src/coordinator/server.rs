//! Replica-pool request front-end: the AXIS/queue interface of the
//! deployed system scaled across N worker threads, each owning an
//! [`InferenceService`] replica, fed from one shared request queue
//! (offline toolchain has no tokio; std primitives give the same
//! shape: shared queue, condvars, message-passing replies).
//!
//! Properties the pool guarantees (EXPERIMENTS.md §Serving):
//!
//! * **Versioned broadcast reprogram.**  [`ServiceHandle::program`]
//!   publishes the model under a monotonically increasing version and
//!   blocks until *every* live replica has swapped (the version fence:
//!   each worker drains its in-flight request, swaps, then resumes).
//!   Once `program` returns, no later inference can observe an older
//!   model, and all replicas report the same version.
//! * **Panic supervision.**  A request that panics its worker does not
//!   kill the pool: the panic is caught, the failing request gets a
//!   typed [`ServeError::WorkerPanicked`], and the replica is rebuilt
//!   from its [`EngineSpec`] and reprogrammed from the last-programmed
//!   model before taking more work.  Counters survive the respawn.
//! * **Typed errors.**  Engine rejections ([`CoreError`], including
//!   the `BadBatch` malformed-request validation), worker panics and
//!   pool shutdown are distinct [`ServeError`] variants — no more
//!   opaque "service worker gone".
//! * **Aggregated metrics.**  [`ServiceHandle::pool_stats`] reports
//!   per-replica [`Metrics`] plus a pool rollup; [`ServiceHandle::stats`]
//!   keeps the old single-service shape (the rollup).

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::service::{EngineSpec, InferenceService, Metrics};
use crate::accel::core::CoreError;
use crate::tm::model::TMModel;

/// Snapshot returned by [`ServiceHandle::stats`] (the pool rollup).
pub type ServerStats = Metrics;

/// Errors a request can come back with.  Worker death, engine
/// rejection and shutdown are distinguishable, so a client can retry,
/// fix its request, or stop.
#[derive(Debug, thiserror::Error)]
pub enum ServeError {
    /// The engine rejected the request (malformed batch, model not
    /// programmed, model too big, …).  The replica is fine.
    #[error(transparent)]
    Core(#[from] CoreError),
    /// The replica serving this request panicked.  It has been rebuilt
    /// from the last-programmed model; retrying on the pool is safe.
    #[error("replica {replica} panicked serving this request (replica respawned)")]
    WorkerPanicked { replica: usize },
    /// The pool has been shut down; no further requests are accepted.
    #[error("service pool is shut down")]
    ShutDown,
    /// A worker dropped the reply without answering (worker death that
    /// supervision could not intercept).
    #[error("replica worker died without replying")]
    WorkerGone,
    /// A canary operation could not proceed (no canary active, pool too
    /// small to dedicate a replica, no baseline model to fall back to).
    #[error("canary: {0}")]
    Canary(&'static str),
    /// The request's deadline passed before a replica produced an
    /// answer (see [`ServiceHandle::infer_deadline`]).  The pool is
    /// fine — the job was either dropped unexecuted by the first worker
    /// to pick it up, or its late answer was discarded.
    #[error("request deadline exceeded before a replica could serve it")]
    DeadlineExceeded,
}

/// Per-replica snapshot inside [`PoolStats`].
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub metrics: Metrics,
    /// Last model version this replica acknowledged (see
    /// [`PoolStats::version`]).
    pub model_version: u64,
    /// Times this replica was rebuilt after a caught panic.
    pub respawns: u64,
    pub alive: bool,
}

/// Aggregated pool snapshot: per-replica metrics plus the rollup.
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub replicas: Vec<ReplicaStats>,
    /// Rollup across replicas: counters are summed; `reprograms` is the
    /// pool model VERSION — one bump per `program` broadcast and per
    /// canary program/dismiss (not the per-replica reprogram sum).
    pub total: Metrics,
    /// Current target model version (bumped by every `program` call
    /// and every canary program/dismiss).
    pub version: u64,
    /// Replica currently serving a canary candidate, if any.
    pub canary: Option<usize>,
}

/// One telemetry probe reply: predictions, per-datapoint confidence
/// margins (top-1 minus top-2 class sum), and the pool model version
/// the serving replica ran — the feed of the autotune monitor
/// ([`crate::coordinator::autotune`]).
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub preds: Vec<usize>,
    pub margins: Vec<i32>,
    /// Pool version fence value the replica had acknowledged when it
    /// served this probe.
    pub model_version: u64,
}

/// Which replicas may serve a job.  While a canary is active, `Pool`
/// jobs are served by every replica EXCEPT the canary (a candidate
/// under evaluation is never exposed to live traffic) and `CanaryOnly`
/// jobs exclusively by it (the mirrored evaluation stream).  With no
/// canary active, `Pool` means any replica and `CanaryOnly` jobs are
/// rejected at submission.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
enum Target {
    Pool,
    CanaryOnly,
}

/// One queued unit of work.
enum Job {
    Infer {
        rows: Vec<Vec<u8>>,
        target: Target,
        /// Expiry instant of a deadline request: a worker that pops an
        /// already-expired job replies [`ServeError::DeadlineExceeded`]
        /// without executing it, so a saturated queue sheds abandoned
        /// work instead of computing answers nobody is waiting for.
        deadline: Option<std::time::Instant>,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Fault injection: occupy the owning worker for `dur` (tests and
    /// chaos drills — the deterministic "saturated pool" for deadline
    /// coverage).
    Stall {
        dur: std::time::Duration,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Inference plus the confidence-margin telemetry the drift monitor
    /// and the canary comparator consume.  Rides the same queue as
    /// plain requests — telemetry IS traffic, so the monitor observes
    /// exactly what clients do.
    Telemetry {
        rows: Vec<Vec<u8>>,
        target: Target,
        reply: mpsc::Sender<Result<Telemetry, ServeError>>,
    },
    /// Fault injection: panic inside the owning worker.  Exercises the
    /// real supervision path (tests, chaos drills) — targetable, so the
    /// canary replica's respawn-with-candidate path is reachable too.
    Crash {
        target: Target,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
}

impl Job {
    fn target(&self) -> Target {
        match self {
            Job::Infer { target, .. }
            | Job::Telemetry { target, .. }
            | Job::Crash { target, .. } => *target,
            // Stalls are a pool-wide chaos tool, never canary-targeted.
            Job::Stall { .. } => Target::Pool,
        }
    }

    /// Reply with a canary error (the job was targeted at a canary that
    /// no longer exists).
    fn fail_canary(self, reason: &'static str) {
        match self {
            Job::Infer { reply, .. } | Job::Crash { reply, .. } | Job::Stall { reply, .. } => {
                let _ = reply.send(Err(ServeError::Canary(reason)));
            }
            Job::Telemetry { reply, .. } => {
                let _ = reply.send(Err(ServeError::Canary(reason)));
            }
        }
    }
}

/// Sentinel for "no canary active" in the lock-free replica mirror.
const NO_CANARY: usize = usize::MAX;

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// An active canary: one replica serving a candidate model while the
/// rest of the pool stays on [`ModelCell::model`].
struct CanaryCell {
    replica: usize,
    model: Arc<TMModel>,
}

/// The versioned model cell — the fence state.
struct ModelCell {
    /// Target version; bumped by every `program` broadcast AND every
    /// canary program/dismiss (versions stay strictly monotone across
    /// canary lifecycles).
    version: u64,
    /// Last-programmed pool model (what non-canary replicas swap to /
    /// respawn from).
    model: Option<Arc<TMModel>>,
    /// Active canary, if any.  The canary replica programs
    /// `canary.model` instead of `model` at the fence.
    canary: Option<CanaryCell>,
    /// Per-replica acknowledged version (monotone).
    acks: Vec<u64>,
    /// Per-replica swap failure, tagged with the version it failed at.
    errors: Vec<Option<(u64, CoreError)>>,
    alive: Vec<bool>,
}

#[derive(Clone, Default)]
struct ReplicaMetrics {
    metrics: Metrics,
    respawns: u64,
}

struct Shared {
    queue: Mutex<QueueState>,
    /// Wakes workers: new job, shutdown, or a pending version fence.
    queue_cv: Condvar,
    cell: Mutex<ModelCell>,
    /// Wakes `program` callers waiting on replica acks.
    fence_cv: Condvar,
    /// Mirror of `cell.version`, readable without the cell lock (the
    /// workers' queue-wait loop polls it; never lock cell inside the
    /// queue lock).
    version: AtomicU64,
    /// Mirror of the canary replica index ([`NO_CANARY`] when none),
    /// readable without the cell lock — the queue-wait eligibility
    /// check polls it alongside `version`.
    canary_replica: AtomicUsize,
    metrics: Mutex<Vec<ReplicaMetrics>>,
    spec: EngineSpec,
}

/// Cloneable client handle to a running replica pool.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Shared>,
}

/// Joiner for the pool's worker threads.  `join` is idempotent: the
/// first call joins everything, later calls are no-ops.  Dropping the
/// joiner shuts the pool down (queued requests drain first) and joins.
pub struct PoolJoin {
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl PoolJoin {
    pub fn join(&mut self) {
        for h in self.workers.drain(..) {
            // Workers catch request panics themselves; a join error here
            // would mean supervision itself died, which Exit handling
            // already recorded in `alive`.
            let _ = h.join();
        }
    }
}

impl Drop for PoolJoin {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
            self.shared.queue_cv.notify_all();
        }
        self.join();
    }
}

/// Spawn a single-replica pool — the drop-in shape of the old
/// one-worker front-end.
pub fn spawn(spec: EngineSpec) -> (ServiceHandle, PoolJoin) {
    spawn_pool(spec, 1)
}

/// Spawn a pool of `replicas` workers, each owning one engine built
/// from `spec`, all fed from one shared FIFO request queue.
pub fn spawn_pool(spec: EngineSpec, replicas: usize) -> (ServiceHandle, PoolJoin) {
    let n = replicas.max(1);
    let shared = Arc::new(Shared {
        queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
        queue_cv: Condvar::new(),
        cell: Mutex::new(ModelCell {
            version: 0,
            model: None,
            canary: None,
            acks: vec![0; n],
            errors: (0..n).map(|_| None).collect(),
            alive: vec![true; n],
        }),
        fence_cv: Condvar::new(),
        version: AtomicU64::new(0),
        canary_replica: AtomicUsize::new(NO_CANARY),
        metrics: Mutex::new(vec![ReplicaMetrics::default(); n]),
        spec,
    });
    let workers = (0..n)
        .map(|i| {
            let s = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("rttm-replica-{i}"))
                .spawn(move || worker_loop(&s, i))
                .expect("spawn replica worker")
        })
        .collect();
    let join = PoolJoin { workers, shared: Arc::clone(&shared) };
    (ServiceHandle { shared }, join)
}

impl ServiceHandle {
    /// Blocking inference RPC.  Any number of rows; the replica splits
    /// them into 32-lane batches through the bulk scheduler.  Never
    /// served by an active canary replica.
    pub fn infer(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Infer { rows, target: Target::Pool, deadline: None, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Inference RPC with a per-request deadline: blocks at most
    /// `timeout`, then returns [`ServeError::DeadlineExceeded`] instead
    /// of waiting forever on a saturated queue.  An expired job is shed
    /// by the first worker to pop it (it replies the same typed error
    /// without executing), so abandoned requests cost the pool a queue
    /// slot, not an inference; a job that was already mid-execution at
    /// expiry completes and its late answer is discarded.
    pub fn infer_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: std::time::Duration,
    ) -> Result<Vec<usize>, ServeError> {
        let deadline = std::time::Instant::now() + timeout;
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Infer {
            rows,
            target: Target::Pool,
            deadline: Some(deadline),
            reply,
        })?;
        match rx.recv_timeout(timeout) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServeError::DeadlineExceeded),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::WorkerGone),
        }
    }

    /// Blocking inference RPC served EXCLUSIVELY by the canary replica
    /// (the mirrored evaluation stream).  Errors with
    /// [`ServeError::Canary`] when no canary is active.
    pub fn infer_canary(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Infer { rows, target: Target::CanaryOnly, deadline: None, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Blocking telemetry RPC: inference plus confidence margins and
    /// the serving replica's acknowledged model version.  The autotune
    /// monitor's probe path — it queues behind (and alongside) regular
    /// traffic on purpose, and is never served by an active canary.
    pub fn infer_telemetry(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Telemetry { rows, target: Target::Pool, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Telemetry served exclusively by the canary replica — the
    /// candidate half of a paired canary window.
    pub fn infer_telemetry_canary(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Telemetry { rows, target: Target::CanaryOnly, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Blocking reprogram RPC (the runtime-tuning path), broadcast to
    /// every replica behind the version fence: returns once all live
    /// replicas serve the new model.  A failed swap (e.g. model too big
    /// for the configured memories) leaves the failing replicas
    /// *unprogrammed* — never on a stale model — so the pool still
    /// cannot serve mixed versions.  An active canary is dismissed by
    /// the broadcast (the whole pool converges on `model`).
    pub fn program(&self, model: TMModel) -> Result<(), ServeError> {
        self.program_arc(Arc::new(model))
    }

    fn program_arc(&self, model: Arc<TMModel>) -> Result<(), ServeError> {
        let (target, had_canary) = {
            let q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err(ServeError::ShutDown);
            }
            drop(q);
            let mut cell = self.shared.cell.lock().unwrap();
            cell.version += 1;
            cell.model = Some(model);
            let had_canary = cell.canary.take().is_some();
            if had_canary {
                self.shared.canary_replica.store(NO_CANARY, Ordering::Release);
            }
            // Publish under the cell lock so the mirror stays ordered.
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, had_canary)
        };
        // Only a broadcast that actually dismissed a canary can have
        // stranded CanaryOnly jobs; the common path skips the queue
        // rebuild entirely.
        if had_canary {
            self.drain_canary_jobs("canary dismissed by a pool broadcast");
        }
        self.fence_wait(target)
    }

    /// Program `model` onto EXACTLY ONE replica — the canary — behind
    /// the version fence; the rest of the pool keeps serving the
    /// current model, and live traffic is routed away from the canary
    /// until it is promoted ([`Self::promote_canary`]) or dismissed
    /// ([`Self::dismiss_canary`]).  Returns the canary replica index.
    ///
    /// Re-programming an active canary replaces its candidate in
    /// place.  Requires a programmed pool (the baseline to compare
    /// against) and at least two live replicas (a 1-replica "canary"
    /// would be a whole-pool swap).  On error the canary replica is
    /// left unprogrammed — call [`Self::dismiss_canary`] to restore it
    /// to the pool model.
    pub fn program_canary(&self, model: TMModel) -> Result<usize, ServeError> {
        let (target, replica) = {
            let q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err(ServeError::ShutDown);
            }
            drop(q);
            let mut cell = self.shared.cell.lock().unwrap();
            if cell.model.is_none() {
                return Err(ServeError::Canary("pool has no baseline model"));
            }
            if cell.alive.iter().filter(|&&a| a).count() < 2 {
                return Err(ServeError::Canary("need at least 2 live replicas"));
            }
            // Keep an already-chosen canary replica; otherwise dedicate
            // the highest-index live replica.
            let replica = match &cell.canary {
                Some(c) => c.replica,
                None => cell.alive.iter().rposition(|&a| a).expect("checked above"),
            };
            cell.canary = Some(CanaryCell { replica, model: Arc::new(model) });
            self.shared.canary_replica.store(replica, Ordering::Release);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, replica)
        };
        self.fence_wait(target)?;
        Ok(replica)
    }

    /// Broadcast the active canary's candidate to the whole pool (the
    /// promote half of a canary verdict).  One fence: every replica —
    /// canary included — converges on the candidate.
    pub fn promote_canary(&self) -> Result<(), ServeError> {
        let model = {
            let cell = self.shared.cell.lock().unwrap();
            match &cell.canary {
                Some(c) => Arc::clone(&c.model),
                None => return Err(ServeError::Canary("no canary active")),
            }
        };
        self.program_arc(model)
    }

    /// Tear the canary down: the canary replica is re-programmed with
    /// the pool model behind the fence (the reject half of a verdict,
    /// and the cleanup after a failed [`Self::program_canary`]).
    /// Returns `false` (without touching anything) when no canary is
    /// active — dismissal is idempotent.
    pub fn dismiss_canary(&self) -> Result<bool, ServeError> {
        let target = {
            let q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err(ServeError::ShutDown);
            }
            drop(q);
            let mut cell = self.shared.cell.lock().unwrap();
            if cell.canary.is_none() {
                return Ok(false);
            }
            cell.canary = None;
            self.shared.canary_replica.store(NO_CANARY, Ordering::Release);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            cell.version
        };
        self.drain_canary_jobs("canary dismissed");
        self.fence_wait(target)?;
        Ok(true)
    }

    /// Replica currently serving a canary candidate, if any.
    pub fn canary_replica(&self) -> Option<usize> {
        match self.shared.canary_replica.load(Ordering::Acquire) {
            NO_CANARY => None,
            idx => Some(idx),
        }
    }

    /// Wake workers, wait until every live replica acked `target`, and
    /// surface a swap failure recorded for EXACTLY this fence.  Version
    /// targets are unique per broadcast, so only this caller can own a
    /// matching error; failures belonging to a newer concurrent
    /// broadcast are left for that caller (a superseded model returns
    /// Ok — the fence still guarantees no replica serves anything older
    /// than it).
    fn fence_wait(&self, target: u64) -> Result<(), ServeError> {
        // Wake parked workers so they observe the fence.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.queue_cv.notify_all();
        }
        let mut cell = self.shared.cell.lock().unwrap();
        loop {
            if !cell.alive.iter().any(|&a| a) {
                return Err(ServeError::ShutDown);
            }
            let done = cell
                .alive
                .iter()
                .zip(&cell.acks)
                .all(|(&alive, &acked)| !alive || acked >= target);
            if done {
                break;
            }
            cell = self.shared.fence_cv.wait(cell).unwrap();
        }
        for slot in cell.errors.iter_mut() {
            if slot.as_ref().is_some_and(|(v, _)| *v == target) {
                let (_, err) = slot.take().expect("checked above");
                return Err(ServeError::Core(err));
            }
        }
        Ok(())
    }

    fn drain_canary_jobs(&self, reason: &'static str) {
        drain_canary_jobs(&self.shared, reason);
    }

    /// Pool rollup in the old single-service shape (counters summed,
    /// `reprograms` = the pool model version: broadcasts plus canary
    /// lifecycle fences — see [`PoolStats::total`]).
    pub fn stats(&self) -> Result<ServerStats, ServeError> {
        Ok(self.pool_stats().total)
    }

    /// Full per-replica + rollup snapshot.
    pub fn pool_stats(&self) -> PoolStats {
        let (version, acks, alive, canary) = {
            let cell = self.shared.cell.lock().unwrap();
            (
                cell.version,
                cell.acks.clone(),
                cell.alive.clone(),
                cell.canary.as_ref().map(|c| c.replica),
            )
        };
        let per = self.shared.metrics.lock().unwrap();
        let replicas: Vec<ReplicaStats> = per
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                metrics: r.metrics.clone(),
                model_version: acks[i],
                respawns: r.respawns,
                alive: alive[i],
            })
            .collect();
        drop(per);
        let mut total = Metrics::default();
        for r in &replicas {
            total.inferences += r.metrics.inferences;
            total.batches += r.metrics.batches;
            total.simulated_cycles += r.metrics.simulated_cycles;
            total.errors += r.metrics.errors;
        }
        total.reprograms = version;
        PoolStats { replicas, total, version, canary }
    }

    /// Ask the pool to stop.  Queued requests are drained first; new
    /// submissions are rejected with [`ServeError::ShutDown`].
    /// Idempotent.
    pub fn shutdown(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        q.shutdown = true;
        self.shared.queue_cv.notify_all();
    }

    /// Fault injection: make the replica that picks this request panic
    /// mid-request.  Returns the same typed error a real panic would,
    /// after supervision has respawned the replica.  For tests and
    /// chaos drills.  Never lands on an active canary (like any Pool
    /// job).
    #[doc(hidden)]
    pub fn inject_panic(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Crash { target: Target::Pool, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Fault injection on the CANARY replica: exercises the
    /// respawn-while-canary supervision path (the rebuilt replica must
    /// come back serving the CANDIDATE, not the pool model).
    #[doc(hidden)]
    pub fn inject_panic_canary(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Crash { target: Target::CanaryOnly, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Fault injection: occupy whichever replica pops this job for
    /// `dur` — the deterministic "saturated pool" for deadline tests
    /// and chaos drills.  Returns immediately; the returned receiver
    /// resolves when the stall ends (drop it to fire and forget).
    #[doc(hidden)]
    pub fn inject_stall(
        &self,
        dur: std::time::Duration,
    ) -> Result<mpsc::Receiver<Result<Vec<usize>, ServeError>>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Stall { dur, reply })?;
        Ok(rx)
    }

    fn submit(&self, job: Job) -> Result<(), ServeError> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.shutdown {
            return Err(ServeError::ShutDown);
        }
        // Canary existence is checked UNDER the queue lock: dismissal
        // clears the mirror first and then drains the queue (also under
        // this lock), so a CanaryOnly job admitted here is either
        // rejected now or found by the drain — never stranded.
        if job.target() == Target::CanaryOnly && self.canary_replica().is_none() {
            return Err(ServeError::Canary("no canary active"));
        }
        q.jobs.push_back(job);
        // With a canary active, the one woken worker might be
        // ineligible for the new job (e.g. the canary woken for a Pool
        // job) and would park again without another wake-up — wake
        // everyone.  With no canary, every worker is eligible for every
        // admissible job, so notify_one avoids a per-request thundering
        // herd on the serving hot path.  (A canary appearing right
        // after this check is fine: program_canary's fence does its own
        // notify_all.)
        if self.canary_replica().is_none() {
            self.shared.queue_cv.notify_one();
        } else {
            self.shared.queue_cv.notify_all();
        }
        Ok(())
    }
}

/// What the queue wait resolved to.
enum Next {
    Work(Job),
    /// A newer model version is pending — swap before taking work.
    Resync,
    Exit,
}

/// Runs on every worker exit — normal return or a panic that escaped
/// `catch_unwind` (e.g. an invalid spec panicking in `build()`): marks
/// the replica dead and wakes fence waiters so `program` can never
/// hang on a corpse.  When the LAST replica dies, flips the pool to
/// shutdown and drops any parked jobs, so clients blocked on replies
/// get [`ServeError::WorkerGone`] instead of waiting forever.
struct DeathWatch<'a> {
    shared: &'a Shared,
    idx: usize,
}

impl Drop for DeathWatch<'_> {
    fn drop(&mut self) {
        let (all_dead, canary_cleared) = {
            let mut cell = self.shared.cell.lock().unwrap();
            cell.alive[self.idx] = false;
            // A dying canary takes its candidate with it: clear the
            // canary state so Pool traffic stops avoiding a corpse and
            // new CanaryOnly submissions are rejected instead of
            // stranded.  Symmetrically, if this death leaves ONLY the
            // canary alive, the canary must be dismissed — Pool jobs
            // would otherwise have no eligible worker and their callers
            // would block forever.  The version bump makes the
            // surviving canary resync onto the pool model before it
            // serves live traffic.
            let was_canary = cell.canary.as_ref().is_some_and(|c| c.replica == self.idx);
            let only_canary_left = cell
                .canary
                .as_ref()
                .is_some_and(|c| {
                    cell.alive.iter().enumerate().all(|(i, &a)| !a || i == c.replica)
                });
            let canary_cleared = was_canary || only_canary_left;
            if canary_cleared {
                cell.canary = None;
                self.shared.canary_replica.store(NO_CANARY, Ordering::Release);
                cell.version += 1;
                self.shared.version.store(cell.version, Ordering::Release);
            }
            (!cell.alive.iter().any(|&a| a), canary_cleared)
        };
        self.shared.fence_cv.notify_all();
        if canary_cleared && !all_dead {
            drain_canary_jobs(self.shared, "canary replica died");
            // Wake survivors: the version bump above needs a resync.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.queue_cv.notify_all();
        }
        if all_dead {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
            // Dropping a Job drops its reply Sender -> clients unblock.
            q.jobs.clear();
            self.shared.queue_cv.notify_all();
        }
    }
}

/// Fail any still-queued canary-targeted jobs with a typed error.
/// Called after the canary is cleared (dismissal, pool broadcast, or
/// canary-worker death): no worker is eligible for them anymore, so
/// leaving them queued would strand their callers.  The replies are
/// sent outside the queue lock.
fn drain_canary_jobs(shared: &Shared, reason: &'static str) {
    let stranded: Vec<Job> = {
        let mut q = shared.queue.lock().unwrap();
        let mut kept = VecDeque::with_capacity(q.jobs.len());
        let mut out = Vec::new();
        for job in q.jobs.drain(..) {
            if job.target() == Target::CanaryOnly {
                out.push(job);
            } else {
                kept.push_back(job);
            }
        }
        q.jobs = kept;
        out
    };
    for job in stranded {
        job.fail_canary(reason);
    }
}

/// May a worker serve a job with this target?  While a worker is the
/// canary it serves ONLY CanaryOnly jobs and every other worker serves
/// ONLY Pool jobs — a candidate under evaluation is never exposed to
/// live traffic, and the baseline never answers the mirrored stream.
///
/// `am_canary` is the worker-local answer learned at its last fence
/// resync from the AUTHORITATIVE cell (every canary mutation bumps the
/// version, so a worker always resyncs before taking work under a new
/// canary assignment) — deliberately not the lock-free mirror, whose
/// propagation lag could otherwise let a freshly-assigned canary pick
/// up one live request.
fn eligible(target: Target, am_canary: bool) -> bool {
    match target {
        Target::Pool => !am_canary,
        Target::CanaryOnly => am_canary,
    }
}

/// Worker-local execution state: the service, the model Arc it last
/// programmed (so fences that do not change THIS replica's model — e.g.
/// a sibling becoming the canary — ack without a redundant reprogram),
/// and whether the cell named this worker the canary at its last
/// resync.
struct WorkerState {
    service: InferenceService,
    last_model: Option<Arc<TMModel>>,
    am_canary: bool,
}

fn worker_loop(shared: &Shared, idx: usize) {
    let _watch = DeathWatch { shared, idx };
    let mut state = WorkerState {
        service: InferenceService::new(shared.spec.build()),
        last_model: None,
        am_canary: false,
    };
    let mut my_version = 0u64;
    loop {
        // Fence check between requests: drain (we are between jobs),
        // swap, resume.
        if shared.version.load(Ordering::Acquire) != my_version {
            my_version = program_from_cell(shared, idx, &mut state);
        }
        let am_canary = state.am_canary;
        let next = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Pending reprogram outranks new work: no job may start
                // on a stale replica once the fence is up.
                if shared.version.load(Ordering::Acquire) != my_version {
                    break Next::Resync;
                }
                let slot = q.jobs.iter().position(|j| eligible(j.target(), am_canary));
                if let Some(s) = slot {
                    break Next::Work(q.jobs.remove(s).expect("position just found"));
                }
                if q.shutdown {
                    break Next::Exit;
                }
                q = shared.queue_cv.wait(q).unwrap();
            }
        };
        match next {
            Next::Resync => continue,
            // DeathWatch marks the replica dead on the way out.
            Next::Exit => return,
            Next::Work(job) => run_job(shared, idx, &mut state, &mut my_version, job),
        }
    }
}

fn run_job(shared: &Shared, idx: usize, state: &mut WorkerState, my_version: &mut u64, job: Job) {
    match job {
        Job::Infer { rows, deadline, reply, .. } => {
            // Shed expired work before computing it: the client already
            // got DeadlineExceeded from its recv_timeout, so executing
            // the job would burn the replica for a discarded answer.
            if deadline.is_some_and(|d| std::time::Instant::now() > d) {
                let _ = reply.send(Err(ServeError::DeadlineExceeded));
                return;
            }
            let outcome =
                panic::catch_unwind(AssertUnwindSafe(|| state.service.infer_all(&rows)));
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Stall { dur, reply } => {
            std::thread::sleep(dur);
            let _ = reply.send(Ok(Vec::new()));
        }
        Job::Telemetry { rows, reply, .. } => {
            // Capture the fence version the request runs under BEFORE
            // the work: a panic respawn may advance `my_version`.
            let version = *my_version;
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                state
                    .service
                    .infer_with_margins(&rows)
                    .map(|(preds, margins)| Telemetry { preds, margins, model_version: version })
            }));
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Crash { reply, .. } => {
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| -> Result<Vec<usize>, CoreError> {
                panic!("injected fault (ServiceHandle::inject_panic)")
            }));
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
    }
}

/// Shared tail of the per-request supervision protocol, for every job
/// flavour: on success, publish this replica's metrics BEFORE replying
/// (a client that got its answer always sees it reflected in
/// `stats()`); on a caught panic, respawn the replica and fail only
/// the offending request.
fn reply_or_respawn<T>(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
    outcome: std::thread::Result<Result<T, CoreError>>,
    reply: mpsc::Sender<Result<T, ServeError>>,
) {
    match outcome {
        Ok(result) => {
            shared.metrics.lock().unwrap()[idx].metrics = state.service.metrics.clone();
            let _ = reply.send(result.map_err(ServeError::Core));
        }
        Err(_panic) => {
            respawn_replica(shared, idx, state, my_version);
            let _ = reply.send(Err(ServeError::WorkerPanicked { replica: idx }));
        }
    }
}

/// Supervision: a panicking request may have left the replica in an
/// arbitrary state.  Rebuild the engine from the spec, carry the
/// counters over (plus the error), reprogram from the last-programmed
/// model, then let the caller fail only the offending request.
fn respawn_replica(shared: &Shared, idx: usize, state: &mut WorkerState, my_version: &mut u64) {
    let mut carried = state.service.metrics.clone();
    carried.errors += 1;
    state.service = InferenceService::new(shared.spec.build());
    // The fresh engine is unprogrammed: the reprogram-skip memo must
    // not survive the rebuild.
    state.last_model = None;
    state.service.metrics = carried;
    {
        let mut per = shared.metrics.lock().unwrap();
        per[idx].respawns += 1;
        per[idx].metrics = state.service.metrics.clone();
    }
    *my_version = program_from_cell(shared, idx, state);
}

/// Swap this worker's service to the model the cell assigns IT — the
/// canary candidate when this replica is the canary, the pool model
/// otherwise — and acknowledge the version (the worker half of the
/// fence).  Also the respawn path: called with a freshly built engine,
/// it re-installs the assigned model.  Returns the version applied.
///
/// A fence that does not change this replica's model (same Arc as the
/// last programmed one — e.g. a sibling became the canary) acks without
/// touching the engine, so canary lifecycle operations cost the
/// non-participating replicas one drain, not one reprogram.
fn program_from_cell(shared: &Shared, idx: usize, state: &mut WorkerState) -> u64 {
    let (target, model) = {
        let cell = shared.cell.lock().unwrap();
        let am_canary = cell.canary.as_ref().is_some_and(|c| c.replica == idx);
        state.am_canary = am_canary;
        let model = if am_canary {
            cell.canary.as_ref().map(|c| Arc::clone(&c.model))
        } else {
            cell.model.clone()
        };
        (cell.version, model)
    };
    // Program outside the lock: encoding + programming a large model is
    // the slow part, and siblings must be able to ack concurrently.
    let failure = match &model {
        Some(m) if state.last_model.as_ref().is_some_and(|l| Arc::ptr_eq(l, m)) => None,
        Some(m) => match state.service.reprogram(m) {
            Ok(()) => {
                state.last_model = Some(Arc::clone(m));
                None
            }
            Err(e) => {
                // A failed swap must not leave this replica on the
                // stale model: a single core keeps its old program
                // when the new one overflows instruction memory, and a
                // multi-core can stop half-programmed.  Rebuild the
                // engine unprogrammed (counters carried) so the
                // replica serves NotProgrammed, never version N-1.
                let carried = state.service.metrics.clone();
                state.service = InferenceService::new(shared.spec.build());
                state.service.metrics = carried;
                state.last_model = None;
                Some(e)
            }
        },
        None => None,
    };
    // Keep the published per-replica metrics fresh (reprogram bumps a
    // counter outside the job path).
    shared.metrics.lock().unwrap()[idx].metrics = state.service.metrics.clone();
    let mut cell = shared.cell.lock().unwrap();
    if cell.acks[idx] < target {
        cell.acks[idx] = target;
        cell.errors[idx] = failure.map(|e| (target, e));
        shared.fence_cv.notify_all();
    }
    target
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).generate();
        (crate::trainer::train_model(&shape, &data, 4, 2), data)
    }

    #[test]
    fn rpc_roundtrip() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.inferences, 96);
        assert_eq!(stats.reprograms, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn infer_before_program_is_error_not_crash() {
        let (h, mut join) = spawn(EngineSpec::base());
        assert!(matches!(
            h.infer(vec![vec![0u8; 12]]),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn concurrent_clients_share_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model).unwrap();
        let mut threads = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            let rows = data.xs.clone();
            threads.push(std::thread::spawn(move || h.infer(rows).unwrap().len()));
        }
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 4 * 96);
        assert_eq!(h.stats().unwrap().inferences, 4 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn reprogram_mid_serving_takes_effect() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let new_model = crate::trainer::train_model(&shape, &drifted, 4, 3);
        h.program(new_model).unwrap();
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before.len(), after.len());
        let stats = h.pool_stats();
        assert_eq!(stats.version, 2);
        assert_eq!(stats.total.reprograms, 2);
        // The fence: both replicas on the new version once program() returned.
        for r in &stats.replicas {
            assert_eq!(r.model_version, 2);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn telemetry_matches_single_service_and_reports_fence_version() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();

        let mut reference = InferenceService::new(EngineSpec::base().build());
        reference.reprogram(&model).unwrap();
        let (want_preds, want_margins) = reference.infer_with_margins(&data.xs).unwrap();

        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_preds);
        assert_eq!(tel.margins, want_margins);
        assert_eq!(tel.model_version, 1);

        // Telemetry rides the version fence like any request.
        h.program(model).unwrap();
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.model_version, 2);

        // Malformed telemetry probes are typed errors, not pool deaths.
        assert!(matches!(
            h.infer_telemetry(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn malformed_requests_do_not_kill_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();

        assert!(matches!(
            h.infer(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        let ragged = vec![vec![0u8; 12], vec![0u8; 5]];
        assert!(matches!(
            h.infer(ragged),
            Err(ServeError::Core(CoreError::BadBatch { .. }))
        ));
        // The pool keeps serving on the same handle.
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.inferences, 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn injected_panic_respawns_replica_and_pool_survives() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();

        match h.inject_panic() {
            Err(ServeError::WorkerPanicked { replica }) => assert_eq!(replica, 0),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        // Same handle, same answers: the replica was respawned from the
        // last-programmed model.
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before, after);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 1);
        assert!(stats.replicas[0].alive);
        // The panic is visible as an error, and counters survived.
        assert_eq!(stats.total.errors, 1);
        assert_eq!(stats.total.inferences, 2 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_swap_never_leaves_stale_or_mixed_models() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        // A bigger model that cannot fit the instruction memory sized
        // exactly for the small one.
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        let n_big = crate::isa::instruction_count(&big);
        assert!(n_big > n_small, "test premise: {n_big} > {n_small}");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 2);
        h.program(small.clone()).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());

        // The too-big model must fail the swap as a typed error…
        assert!(matches!(h.program(big), Err(ServeError::Core(_))));
        // …and replicas must be unprogrammed — not stale on the old
        // model with the new version acked.
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        // A fitting reprogram fully recovers the pool.
        h.program(small).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());
        h.shutdown();
        join.join();
    }

    #[test]
    fn dead_pool_errors_instead_of_hanging() {
        use crate::accel::core::AccelConfig;
        use crate::accel::multicore::ParallelMode;

        // An invalid spec panics in build() at worker startup — outside
        // the per-request catch_unwind.  The DeathWatch must surface
        // this as errors, never as a hang.
        let bad = EngineSpec::Multi {
            cores: 0,
            per_core: AccelConfig::multicore_core(),
            parallel: ParallelMode::Auto,
        };
        let (h, mut join) = spawn_pool(bad, 2);
        join.join();
        let (model, data) = trained();
        assert!(matches!(h.program(model), Err(ServeError::ShutDown)));
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::ShutDown) | Err(ServeError::WorkerGone)
        ));
    }

    #[test]
    fn canary_serves_only_the_mirrored_stream() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model_a.clone()).unwrap();
        let want_a = h.infer(data.xs.clone()).unwrap();

        // Reference answers for both models.
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();
        assert_ne!(want_a, want_b, "test premise: the models must disagree");

        // No canary yet: canary-targeted requests are typed errors.
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        assert!(h.canary_replica().is_none());

        let replica = h.program_canary(model_b.clone()).unwrap();
        assert_eq!(replica, 2, "highest-index live replica is the canary");
        assert_eq!(h.canary_replica(), Some(2));
        assert_eq!(h.pool_stats().canary, Some(2));

        // Live traffic NEVER sees the candidate; the mirror ONLY does.
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        assert_eq!(h.infer_canary(data.xs.clone()).unwrap(), want_b);
        let tel = h.infer_telemetry_canary(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_b);
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_a);

        // Dismiss: the canary replica returns to the pool model.
        assert!(h.dismiss_canary().unwrap());
        assert!(h.canary_replica().is_none());
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        // Dismissal is idempotent.
        assert!(!h.dismiss_canary().unwrap());

        // Versions strictly monotone: program(1), canary(2), dismiss(3).
        let stats = h.pool_stats();
        assert_eq!(stats.version, 3);
        for r in &stats.replicas {
            assert_eq!(r.model_version, 3);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_promote_broadcasts_the_candidate() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        // Promote with no canary is a typed error.
        assert!(matches!(h.promote_canary(), Err(ServeError::Canary(_))));
        h.program(model_a).unwrap();
        h.program_canary(model_b).unwrap();
        h.promote_canary().unwrap();
        assert!(h.canary_replica().is_none());
        // Every replica now serves the candidate.
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_b);
        }
        let stats = h.pool_stats();
        assert_eq!(stats.version, 3); // program, canary, promote
        for r in &stats.replicas {
            assert_eq!(r.model_version, 3);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_panic_respawns_with_the_candidate_not_the_pool_model() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        // No canary yet: canary-targeted injection is a typed error.
        assert!(matches!(h.inject_panic_canary(), Err(ServeError::Canary(_))));
        h.program(model_a).unwrap();
        let want_a = h.infer(data.xs.clone()).unwrap();
        let replica = h.program_canary(model_b).unwrap();

        // Panic the CANARY worker mid-request: supervision must rebuild
        // it serving the CANDIDATE (a respawn onto the pool model would
        // make every paired window tie and promote any candidate).
        match h.inject_panic_canary() {
            Err(ServeError::WorkerPanicked { replica: r }) => assert_eq!(r, replica),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        assert_eq!(h.infer_canary(data.xs.clone()).unwrap(), want_b);
        // And the pool half is untouched throughout.
        for _ in 0..4 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[replica].respawns, 1);
        assert!(stats.replicas[replica].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_requires_a_baseline_and_two_replicas() {
        let (model, _) = trained();
        // No baseline model programmed yet.
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        assert!(matches!(
            h.program_canary(model.clone()),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
        // Single-replica pool: a "canary" would be a whole-pool swap.
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        assert!(matches!(
            h.program_canary(model),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_canary_program_is_recoverable_by_dismissal() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        assert!(crate::isa::instruction_count(&big) > n_small, "test premise");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 3);
        h.program(small).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        // The candidate overflows the canary replica's memories: typed
        // error, and ONLY that replica was ever disturbed.
        assert!(matches!(h.program_canary(big), Err(ServeError::Core(_))));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        // Dismissal restores the canary replica to the pool model.
        assert!(h.dismiss_canary().unwrap());
        assert!(h.canary_replica().is_none());
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn pool_broadcast_dismisses_an_active_canary() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        h.program_canary(model.clone()).unwrap();
        assert_eq!(h.canary_replica(), Some(1));
        h.program(model).unwrap();
        assert!(h.canary_replica().is_none());
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn deadline_request_errors_on_a_stalled_pool() {
        use std::time::{Duration, Instant};

        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        // Idle pool: a generous deadline behaves exactly like infer().
        let want = h.infer(data.xs.clone()).unwrap();
        assert_eq!(
            h.infer_deadline(data.xs.clone(), Duration::from_secs(30)).unwrap(),
            want
        );
        // Stall the lone replica; a tight deadline must come back as a
        // typed error instead of blocking until the stall clears.
        let stall = h.inject_stall(Duration::from_millis(400)).unwrap();
        let t0 = Instant::now();
        assert!(matches!(
            h.infer_deadline(data.xs.clone(), Duration::from_millis(40)),
            Err(ServeError::DeadlineExceeded)
        ));
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "deadline must not wait out the stall"
        );
        // Once the stall ends the pool recovers; the expired job was
        // shed unexecuted (its late answer had nowhere to go anyway).
        stall.recv().unwrap().unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        h.shutdown();
        join.join();
    }

    #[test]
    fn shutdown_and_join_are_idempotent() {
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.shutdown();
        h.shutdown();
        join.join();
        join.join();
        assert!(matches!(h.infer(vec![vec![0u8; 4]]), Err(ServeError::ShutDown)));
        let (m, _) = trained();
        assert!(matches!(h.program(m), Err(ServeError::ShutDown)));
        // Stats still readable after shutdown (final reporting).
        assert_eq!(h.stats().unwrap().inferences, 0);
    }
}
