//! Replica-pool request front-end: the AXIS/queue interface of the
//! deployed system scaled across N worker threads, each owning an
//! [`InferenceService`] replica, fed through the admission front-end
//! (offline toolchain has no tokio; std primitives give the same
//! shape: sharded queues, condvars, message-passing replies).
//!
//! Properties the pool guarantees (EXPERIMENTS.md §Serving and
//! §Admission):
//!
//! * **Versioned broadcast reprogram.**  [`ServiceHandle::program`]
//!   publishes the model under a monotonically increasing version and
//!   blocks until *every* live replica has swapped (the version fence:
//!   each worker drains its in-flight request, swaps, then resumes).
//!   Once `program` returns, no later inference can observe an older
//!   model, and all replicas report the same version.
//! * **Panic supervision.**  A request that panics its worker does not
//!   kill the pool: the panic is caught, the failing request gets a
//!   typed [`ServeError::WorkerPanicked`], and the replica is rebuilt
//!   from its [`EngineSpec`] and reprogrammed from the last-programmed
//!   model before taking more work.  Counters survive the respawn.
//! * **Classed admission.**  Every request carries a [`Priority`]
//!   class (`Normal` by default, `Critical` for canary mirrors).
//!   Workers pop class-major — `Critical` overtakes queued `Low`
//!   everywhere — and each class has a bounded queue with a
//!   [`ShedPolicy`] (block / reject / shed-oldest), so under overload
//!   the control plane keeps flowing while bulk traffic queues or
//!   sheds ([`ServeError::Overloaded`]).
//! * **Sharded queues with work stealing.**  Jobs are routed
//!   round-robin to per-replica shards; a worker pops its own shard
//!   first and steals from siblings, so replicas no longer contend on
//!   one global lock and an idle replica never watches a busy one.
//! * **Deadline-aware admission.**  A request whose deadline cannot be
//!   met given current same-or-higher-class queue depth (projected by
//!   a service-time EWMA) is refused at submit with
//!   [`ServeError::DeadlineExceeded`] — not discovered at pop.  Queued
//!   requests that expire anyway are shed unexecuted by the first
//!   worker to pop them.
//! * **Autoscaling.**  With an [`AutoscaleConfig`], a supervisor
//!   thread scales the live replica count between `min..=max` from
//!   observed queue depth and deadline-miss rate (never retiring the
//!   canary).
//! * **Typed errors.**  Engine rejections ([`CoreError`], including
//!   the `BadBatch` malformed-request validation), worker panics,
//!   admission refusals and pool shutdown are distinct [`ServeError`]
//!   variants — no more opaque "service worker gone".
//! * **Aggregated metrics.**  [`ServiceHandle::pool_stats`] reports
//!   per-replica [`Metrics`], a pool rollup, and the per-class
//!   [`AdmissionStats`]; [`ServiceHandle::stats`] keeps the old
//!   single-service shape (the rollup).

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{
    AdmissionConfig, AdmissionStats, AutoscaleConfig, ClassCounters, Fault, FaultArmory,
    FaultPlan, PoolConfig, Priority, ServiceEstimator, ShedPolicy, PRIORITY_COUNT,
};
use super::service::{EngineSpec, InferenceService, Metrics};
use crate::accel::core::CoreError;
use crate::tm::model::TMModel;

/// Snapshot returned by [`ServiceHandle::stats`] (the pool rollup).
pub type ServerStats = Metrics;

/// Errors a request can come back with.  Worker death, engine
/// rejection, admission refusal and shutdown are distinguishable, so a
/// client can retry, back off, fix its request, or stop.
#[derive(Debug, thiserror::Error)]
pub enum ServeError {
    /// The engine rejected the request (malformed batch, model not
    /// programmed, model too big, …).  The replica is fine.
    #[error(transparent)]
    Core(#[from] CoreError),
    /// The replica serving this request panicked.  It has been rebuilt
    /// from the last-programmed model; retrying on the pool is safe.
    #[error("replica {replica} panicked serving this request (replica respawned)")]
    WorkerPanicked { replica: usize },
    /// The pool has been shut down; no further requests are accepted.
    #[error("service pool is shut down")]
    ShutDown,
    /// A worker dropped the reply without answering (worker death that
    /// supervision could not intercept).
    #[error("replica worker died without replying")]
    WorkerGone,
    /// A canary operation could not proceed (no canary active, pool too
    /// small to dedicate a replica, no baseline model to fall back to).
    #[error("canary: {0}")]
    Canary(&'static str),
    /// The request's deadline passed before a replica produced an
    /// answer, or admission projected it could never be met (see
    /// [`ServiceHandle::infer_deadline`]).  The pool is fine — the job
    /// was refused at submit, dropped unexecuted by the first worker to
    /// pick it up, or its late answer was discarded.
    #[error("request deadline exceeded before a replica could serve it")]
    DeadlineExceeded,
    /// The request's class queue is at capacity and its backpressure
    /// policy refuses new work (`Reject`), or this request was evicted
    /// by a newer one (`ShedOldest`).  Retry with backoff, downgrade,
    /// or drop — the pool is saturated, not broken.
    #[error("pool overloaded: request refused by admission control")]
    Overloaded,
}

/// Per-replica snapshot inside [`PoolStats`].
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub metrics: Metrics,
    /// Last model version this replica acknowledged (see
    /// [`PoolStats::version`]).
    pub model_version: u64,
    /// Times this replica was rebuilt after a caught panic.
    pub respawns: u64,
    pub alive: bool,
}

/// Aggregated pool snapshot: per-replica metrics plus the rollup and
/// the per-class admission counters.
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub replicas: Vec<ReplicaStats>,
    /// Rollup across replicas: counters are summed; `reprograms` is the
    /// pool model VERSION — one bump per `program` broadcast and per
    /// canary program/dismiss (not the per-replica reprogram sum).
    pub total: Metrics,
    /// Current target model version (bumped by every `program` call
    /// and every canary program/dismiss).
    pub version: u64,
    /// Replica currently serving a canary candidate, if any.
    pub canary: Option<usize>,
    /// Per-class admission counters plus autoscaler activity.
    pub admission: AdmissionStats,
}

/// One telemetry probe reply: predictions, per-datapoint confidence
/// margins (top-1 minus top-2 class sum), and the pool model version
/// the serving replica ran — the feed of the autotune monitor
/// ([`crate::coordinator::autotune`]).
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub preds: Vec<usize>,
    pub margins: Vec<i32>,
    /// Pool version fence value the replica had acknowledged when it
    /// served this probe.
    pub model_version: u64,
}

/// Which replicas may serve a job.  While a canary is active, `Pool`
/// jobs are served by every replica EXCEPT the canary (a candidate
/// under evaluation is never exposed to live traffic) and `CanaryOnly`
/// jobs exclusively by it (the mirrored evaluation stream).  With no
/// canary active, `Pool` means any replica and `CanaryOnly` jobs are
/// rejected at submission.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
enum Target {
    Pool,
    CanaryOnly,
}

/// One queued unit of work.  The class it was admitted under is the
/// queue it sits in, not a field.
enum Job {
    Infer {
        rows: Vec<Vec<u8>>,
        target: Target,
        /// Expiry instant of a deadline request: a worker that pops an
        /// already-expired job replies [`ServeError::DeadlineExceeded`]
        /// without executing it, so a saturated queue sheds abandoned
        /// work instead of computing answers nobody is waiting for.
        deadline: Option<Instant>,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Fault injection: occupy the owning worker for `dur` (tests and
    /// chaos drills — the deterministic "saturated pool" for deadline
    /// coverage).
    Stall {
        dur: Duration,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Inference plus the confidence-margin telemetry the drift monitor
    /// and the canary comparator consume.  Rides the same queues as
    /// plain requests — telemetry IS traffic, so the monitor observes
    /// exactly what clients do.
    Telemetry {
        rows: Vec<Vec<u8>>,
        target: Target,
        /// Same shed-unexecuted expiry semantics as `Infer::deadline`.
        deadline: Option<Instant>,
        reply: mpsc::Sender<Result<Telemetry, ServeError>>,
    },
    /// Fault injection: panic inside the owning worker.  Exercises the
    /// real supervision path (tests, chaos drills) — targetable, so the
    /// canary replica's respawn-with-candidate path is reachable too.
    Crash {
        target: Target,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
}

impl Job {
    fn target(&self) -> Target {
        match self {
            Job::Infer { target, .. }
            | Job::Telemetry { target, .. }
            | Job::Crash { target, .. } => *target,
            // Stalls are a pool-wide chaos tool, never canary-targeted.
            Job::Stall { .. } => Target::Pool,
        }
    }

    fn deadline(&self) -> Option<Instant> {
        match self {
            Job::Infer { deadline, .. } | Job::Telemetry { deadline, .. } => *deadline,
            Job::Stall { .. } | Job::Crash { .. } => None,
        }
    }

    /// Reply with a typed error without executing (shed, eviction,
    /// canary drain).
    fn fail(self, err: impl FnOnce() -> ServeError) {
        match self {
            Job::Infer { reply, .. } | Job::Crash { reply, .. } | Job::Stall { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
            Job::Telemetry { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
        }
    }

    /// Reply with a canary error (the job was targeted at a canary that
    /// no longer exists).
    fn fail_canary(self, reason: &'static str) {
        self.fail(|| ServeError::Canary(reason));
    }
}

/// Sentinel for "no canary active" in the lock-free replica mirror.
const NO_CANARY: usize = usize::MAX;

/// One replica's work-queue shard: a bounded-by-admission FIFO per
/// priority class.  Workers pop their own shard first, then steal.
#[derive(Default)]
struct ShardQueue {
    /// Per-class FIFOs, indexed by [`Priority::index`].
    classes: [VecDeque<Job>; PRIORITY_COUNT],
    /// Set at pool teardown: a closed shard accepts no new jobs, so a
    /// submission racing the last replica's death cannot strand its
    /// client.
    closed: bool,
}

#[derive(Default)]
struct Shard {
    q: Mutex<ShardQueue>,
}

/// An active canary: one replica serving a candidate model while the
/// rest of the pool stays on [`ModelCell::model`].
struct CanaryCell {
    replica: usize,
    model: Arc<TMModel>,
}

/// The versioned model cell — the fence state.
struct ModelCell {
    /// Target version; bumped by every `program` broadcast AND every
    /// canary program/dismiss (versions stay strictly monotone across
    /// canary lifecycles).
    version: u64,
    /// Last-programmed pool model (what non-canary replicas swap to /
    /// respawn from).
    model: Option<Arc<TMModel>>,
    /// Active canary, if any.  The canary replica programs
    /// `canary.model` instead of `model` at the fence.
    canary: Option<CanaryCell>,
    /// Per-replica acknowledged version (monotone).
    acks: Vec<u64>,
    /// Per-replica swap failure, tagged with the version it failed at.
    errors: Vec<Option<(u64, CoreError)>>,
    alive: Vec<bool>,
}

#[derive(Clone, Default)]
struct ReplicaMetrics {
    metrics: Metrics,
    respawns: u64,
}

struct Shared {
    /// Per-replica work-queue shards; workers pop their own shard first
    /// and steal from siblings, class-major.
    shards: Vec<Shard>,
    /// Guards parking of idle workers and blocked submitters.  Held
    /// only to park or wake — never while queueing or serving.
    park: Mutex<()>,
    /// Workers park here when every shard they can serve is empty.
    work_cv: Condvar,
    /// Submitters blocked by a full class queue (`ShedPolicy::Block`)
    /// park here until a pop frees a slot.
    space_cv: Condvar,
    /// Bumped under `park` by every enqueue, fence and shutdown wake; a
    /// worker records it before scanning the shards and refuses to park
    /// if it moved — the lost-wakeup guard, without holding any shard
    /// lock while parked.
    epoch: AtomicU64,
    shutdown: AtomicBool,
    /// Submitters currently blocked on a full class queue (lets the pop
    /// hot path skip the park lock when nobody waits).
    space_waiters: AtomicUsize,
    /// Round-robin cursor for Pool job routing.
    rr: AtomicUsize,
    /// Admission policy (per-class caps and shed policies).
    config: AdmissionConfig,
    /// Per-class admission accounting, indexed by [`Priority::index`].
    counters: [ClassCounters; PRIORITY_COUNT],
    /// Service-time EWMA feeding deadline-aware admission.
    estimator: ServiceEstimator,
    /// Lock-free liveness mirror of `cell.alive` (routing and
    /// feasibility read it without the cell lock).
    alive_mirror: Vec<AtomicBool>,
    /// Scale-down requests from the supervisor; the flagged worker
    /// exits at its next pop instead of taking work.
    retire: Vec<AtomicBool>,
    /// Set when a worker thread has fully exited (its DeathWatch ran);
    /// the supervisor only revives slots whose previous thread is gone.
    exited: Vec<AtomicBool>,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    /// Worker threads started by the supervisor after spawn (joined by
    /// [`PoolJoin`]).
    extra_workers: Mutex<Vec<JoinHandle<()>>>,
    /// Armed fault plans, polled by workers once per popped job.
    faults: FaultArmory,
    cell: Mutex<ModelCell>,
    /// Wakes `program` callers waiting on replica acks.
    fence_cv: Condvar,
    /// Mirror of `cell.version`, readable without the cell lock (the
    /// workers' pop loop polls it; never lock cell inside a shard
    /// lock).
    version: AtomicU64,
    /// Mirror of the canary replica index ([`NO_CANARY`] when none),
    /// readable without the cell lock — routing and the submit-time
    /// canary check poll it alongside `version`.
    canary_replica: AtomicUsize,
    metrics: Mutex<Vec<ReplicaMetrics>>,
    spec: EngineSpec,
}

/// Cloneable client handle to a running replica pool.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Shared>,
}

/// Joiner for the pool's worker threads (and the autoscaling
/// supervisor, when configured).  `join` is idempotent: the first call
/// joins everything, later calls are no-ops.  Dropping the joiner
/// shuts the pool down (queued requests drain first) and joins.
pub struct PoolJoin {
    workers: Vec<JoinHandle<()>>,
    supervisor: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl PoolJoin {
    pub fn join(&mut self) {
        for h in self.workers.drain(..) {
            // Workers catch request panics themselves; a join error here
            // would mean supervision itself died, which Exit handling
            // already recorded in `alive`.
            let _ = h.join();
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        // Workers the supervisor scaled up after spawn.  The supervisor
        // is joined above, so no more can appear while we drain.
        loop {
            let extra: Vec<JoinHandle<()>> = {
                let mut held = self.shared.extra_workers.lock().unwrap();
                held.drain(..).collect()
            };
            if extra.is_empty() {
                break;
            }
            for h in extra {
                let _ = h.join();
            }
        }
    }
}

impl Drop for PoolJoin {
    fn drop(&mut self) {
        shutdown_shared(&self.shared);
        self.join();
    }
}

/// Spawn a single-replica pool — the drop-in shape of the old
/// one-worker front-end.
pub fn spawn(spec: EngineSpec) -> (ServiceHandle, PoolJoin) {
    spawn_pool(spec, 1)
}

/// Spawn a fixed pool of `replicas` workers with default admission
/// (every class: cap 1024, block when full — nothing is ever refused).
pub fn spawn_pool(spec: EngineSpec, replicas: usize) -> (ServiceHandle, PoolJoin) {
    spawn_pool_cfg(spec, PoolConfig::fixed(replicas))
}

/// Spawn a pool under a full [`PoolConfig`]: initial replica count,
/// per-class admission policy, and (optionally) the autoscaling
/// supervisor.  Panics on an invalid config (zero caps, `min > max`) —
/// configs come from validated CLI flags or test literals.
pub fn spawn_pool_cfg(spec: EngineSpec, cfg: PoolConfig) -> (ServiceHandle, PoolJoin) {
    if let Err(e) = cfg.validate() {
        panic!("invalid pool config: {e}");
    }
    let initial = match &cfg.autoscale {
        Some(a) => cfg.replicas.clamp(a.min, a.max),
        None => cfg.replicas.max(1),
    };
    // Slots above `initial` are pre-provisioned for the autoscaler:
    // they exist in every per-replica structure but start dead/exited.
    let slots = cfg.autoscale.as_ref().map_or(initial, |a| a.max.max(initial));
    let shared = Arc::new(Shared {
        shards: (0..slots).map(|_| Shard::default()).collect(),
        park: Mutex::new(()),
        work_cv: Condvar::new(),
        space_cv: Condvar::new(),
        epoch: AtomicU64::new(0),
        shutdown: AtomicBool::new(false),
        space_waiters: AtomicUsize::new(0),
        rr: AtomicUsize::new(0),
        config: cfg.admission.clone(),
        counters: Default::default(),
        estimator: ServiceEstimator::default(),
        alive_mirror: (0..slots).map(|i| AtomicBool::new(i < initial)).collect(),
        retire: (0..slots).map(|_| AtomicBool::new(false)).collect(),
        exited: (0..slots).map(|i| AtomicBool::new(i >= initial)).collect(),
        scale_ups: AtomicU64::new(0),
        scale_downs: AtomicU64::new(0),
        extra_workers: Mutex::new(Vec::new()),
        faults: FaultArmory::default(),
        cell: Mutex::new(ModelCell {
            version: 0,
            model: None,
            canary: None,
            acks: vec![0; slots],
            errors: (0..slots).map(|_| None).collect(),
            alive: (0..slots).map(|i| i < initial).collect(),
        }),
        fence_cv: Condvar::new(),
        version: AtomicU64::new(0),
        canary_replica: AtomicUsize::new(NO_CANARY),
        metrics: Mutex::new(vec![ReplicaMetrics::default(); slots]),
        spec,
    });
    let workers = (0..initial).map(|i| spawn_worker(&shared, i)).collect();
    let supervisor = cfg.autoscale.map(|auto| {
        let s = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("rttm-supervisor".into())
            .spawn(move || supervisor_loop(&s, &auto))
            .expect("spawn pool supervisor")
    });
    let join = PoolJoin { workers, supervisor, shared: Arc::clone(&shared) };
    (ServiceHandle { shared }, join)
}

fn spawn_worker(shared: &Arc<Shared>, idx: usize) -> JoinHandle<()> {
    let s = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("rttm-replica-{idx}"))
        .spawn(move || worker_loop(&s, idx))
        .expect("spawn replica worker")
}

impl ServiceHandle {
    /// Blocking inference RPC at [`Priority::Normal`].  Any number of
    /// rows; the replica splits them into 32-lane batches through the
    /// bulk scheduler.  Never served by an active canary replica.
    pub fn infer(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        self.infer_class(rows, Priority::Normal)
    }

    /// Blocking inference RPC at an explicit priority class.
    pub fn infer_class(
        &self,
        rows: Vec<Vec<u8>>,
        class: Priority,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::Pool, class, None)
    }

    /// Inference RPC with a per-request deadline: blocks at most
    /// `timeout`, then returns [`ServeError::DeadlineExceeded`] instead
    /// of waiting forever on a saturated queue.  Admission refuses the
    /// request outright when projected queue wait already exceeds the
    /// deadline; an admitted job that expires anyway is shed by the
    /// first worker to pop it (it replies the same typed error without
    /// executing), so abandoned requests cost the pool a queue slot,
    /// not an inference; a job that was already mid-execution at
    /// expiry completes and its late answer is discarded.
    pub fn infer_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_deadline_class(rows, timeout, Priority::Normal)
    }

    /// [`Self::infer_deadline`] at an explicit priority class.
    pub fn infer_deadline_class(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
        class: Priority,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::Pool, class, Some(timeout))
    }

    /// Blocking inference RPC served EXCLUSIVELY by the canary replica
    /// (the mirrored evaluation stream), at [`Priority::Critical`] —
    /// the verdict pipeline must survive overload.  Errors with
    /// [`ServeError::Canary`] when no canary is active.
    pub fn infer_canary(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::CanaryOnly, Priority::Critical, None)
    }

    /// [`Self::infer_canary`] with a deadline, riding the same
    /// shed-unexecuted path as [`Self::infer_deadline`].
    pub fn infer_canary_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Vec<usize>, ServeError> {
        self.infer_job(rows, Target::CanaryOnly, Priority::Critical, Some(timeout))
    }

    /// Blocking telemetry RPC: inference plus confidence margins and
    /// the serving replica's acknowledged model version.  The autotune
    /// monitor's probe path — it queues behind (and alongside) regular
    /// traffic on purpose, and is never served by an active canary.
    pub fn infer_telemetry(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::Pool, Priority::Normal, None)
    }

    /// [`Self::infer_telemetry`] at an explicit priority class (the
    /// autotuner probes at [`Priority::High`] so drift detection keeps
    /// working under saturation).
    pub fn infer_telemetry_class(
        &self,
        rows: Vec<Vec<u8>>,
        class: Priority,
    ) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::Pool, class, None)
    }

    /// [`Self::infer_telemetry`] with a deadline, riding the same
    /// shed-unexecuted path as [`Self::infer_deadline`].
    pub fn infer_telemetry_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::Pool, Priority::Normal, Some(timeout))
    }

    /// Telemetry served exclusively by the canary replica — the
    /// candidate half of a paired canary window, at
    /// [`Priority::Critical`].
    pub fn infer_telemetry_canary(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::CanaryOnly, Priority::Critical, None)
    }

    /// [`Self::infer_telemetry_canary`] with a deadline.
    pub fn infer_telemetry_canary_deadline(
        &self,
        rows: Vec<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Telemetry, ServeError> {
        self.telemetry_job(rows, Target::CanaryOnly, Priority::Critical, Some(timeout))
    }

    fn infer_job(
        &self,
        rows: Vec<Vec<u8>>,
        target: Target,
        class: Priority,
        timeout: Option<Duration>,
    ) -> Result<Vec<usize>, ServeError> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Infer { rows, target, deadline, reply }, class)?;
        recv_reply(&rx, timeout)
    }

    fn telemetry_job(
        &self,
        rows: Vec<Vec<u8>>,
        target: Target,
        class: Priority,
        timeout: Option<Duration>,
    ) -> Result<Telemetry, ServeError> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Telemetry { rows, target, deadline, reply }, class)?;
        recv_reply(&rx, timeout)
    }

    /// Blocking reprogram RPC (the runtime-tuning path), broadcast to
    /// every replica behind the version fence: returns once all live
    /// replicas serve the new model.  A failed swap (e.g. model too big
    /// for the configured memories) leaves the failing replicas
    /// *unprogrammed* — never on a stale model — so the pool still
    /// cannot serve mixed versions.  An active canary is dismissed by
    /// the broadcast (the whole pool converges on `model`).
    pub fn program(&self, model: TMModel) -> Result<(), ServeError> {
        self.program_arc(Arc::new(model))
    }

    fn program_arc(&self, model: Arc<TMModel>) -> Result<(), ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let (target, had_canary) = {
            let mut cell = self.shared.cell.lock().unwrap();
            cell.version += 1;
            cell.model = Some(model);
            let had_canary = cell.canary.take().is_some();
            if had_canary {
                self.shared.canary_replica.store(NO_CANARY, Ordering::Release);
            }
            // Publish under the cell lock so the mirror stays ordered.
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, had_canary)
        };
        // Only a broadcast that actually dismissed a canary can have
        // stranded CanaryOnly jobs; the common path skips the shard
        // sweep entirely.
        if had_canary {
            drain_canary_jobs(&self.shared, "canary dismissed by a pool broadcast");
        }
        self.fence_wait(target)
    }

    /// Program `model` onto EXACTLY ONE replica — the canary — behind
    /// the version fence; the rest of the pool keeps serving the
    /// current model, and live traffic is routed away from the canary
    /// until it is promoted ([`Self::promote_canary`]) or dismissed
    /// ([`Self::dismiss_canary`]).  Returns the canary replica index.
    ///
    /// Re-programming an active canary replaces its candidate in
    /// place.  Requires a programmed pool (the baseline to compare
    /// against) and at least two live replicas (a 1-replica "canary"
    /// would be a whole-pool swap).  On error the canary replica is
    /// left unprogrammed — call [`Self::dismiss_canary`] to restore it
    /// to the pool model.
    pub fn program_canary(&self, model: TMModel) -> Result<usize, ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let (target, replica) = {
            let mut cell = self.shared.cell.lock().unwrap();
            if cell.model.is_none() {
                return Err(ServeError::Canary("pool has no baseline model"));
            }
            if cell.alive.iter().filter(|&&a| a).count() < 2 {
                return Err(ServeError::Canary("need at least 2 live replicas"));
            }
            // Keep an already-chosen canary replica; otherwise dedicate
            // the highest-index live replica.
            let replica = match &cell.canary {
                Some(c) => c.replica,
                None => cell.alive.iter().rposition(|&a| a).expect("checked above"),
            };
            cell.canary = Some(CanaryCell { replica, model: Arc::new(model) });
            self.shared.canary_replica.store(replica, Ordering::Release);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            (cell.version, replica)
        };
        self.fence_wait(target)?;
        Ok(replica)
    }

    /// Broadcast the active canary's candidate to the whole pool (the
    /// promote half of a canary verdict).  One fence: every replica —
    /// canary included — converges on the candidate.
    pub fn promote_canary(&self) -> Result<(), ServeError> {
        let model = {
            let cell = self.shared.cell.lock().unwrap();
            match &cell.canary {
                Some(c) => Arc::clone(&c.model),
                None => return Err(ServeError::Canary("no canary active")),
            }
        };
        self.program_arc(model)
    }

    /// Tear the canary down: the canary replica is re-programmed with
    /// the pool model behind the fence (the reject half of a verdict,
    /// and the cleanup after a failed [`Self::program_canary`]).
    /// Returns `false` (without touching anything) when no canary is
    /// active — dismissal is idempotent.
    pub fn dismiss_canary(&self) -> Result<bool, ServeError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let target = {
            let mut cell = self.shared.cell.lock().unwrap();
            if cell.canary.is_none() {
                return Ok(false);
            }
            cell.canary = None;
            self.shared.canary_replica.store(NO_CANARY, Ordering::Release);
            cell.version += 1;
            self.shared.version.store(cell.version, Ordering::Release);
            cell.version
        };
        drain_canary_jobs(&self.shared, "canary dismissed");
        self.fence_wait(target)?;
        Ok(true)
    }

    /// Replica currently serving a canary candidate, if any.
    pub fn canary_replica(&self) -> Option<usize> {
        match self.shared.canary_replica.load(Ordering::Acquire) {
            NO_CANARY => None,
            idx => Some(idx),
        }
    }

    /// Wake workers, wait until every live replica acked `target`, and
    /// surface a swap failure recorded for EXACTLY this fence.  Version
    /// targets are unique per broadcast, so only this caller can own a
    /// matching error; failures belonging to a newer concurrent
    /// broadcast are left for that caller (a superseded model returns
    /// Ok — the fence still guarantees no replica serves anything older
    /// than it).
    fn fence_wait(&self, target: u64) -> Result<(), ServeError> {
        // Wake parked workers so they observe the fence.
        wake_work(&self.shared, true);
        let mut cell = self.shared.cell.lock().unwrap();
        loop {
            if !cell.alive.iter().any(|&a| a) {
                return Err(ServeError::ShutDown);
            }
            let done = cell
                .alive
                .iter()
                .zip(&cell.acks)
                .all(|(&alive, &acked)| !alive || acked >= target);
            if done {
                break;
            }
            cell = self.shared.fence_cv.wait(cell).unwrap();
        }
        for slot in cell.errors.iter_mut() {
            if slot.as_ref().is_some_and(|(v, _)| *v == target) {
                let (_, err) = slot.take().expect("checked above");
                return Err(ServeError::Core(err));
            }
        }
        Ok(())
    }

    /// Pool rollup in the old single-service shape (counters summed,
    /// `reprograms` = the pool model version: broadcasts plus canary
    /// lifecycle fences — see [`PoolStats::total`]).
    pub fn stats(&self) -> Result<ServerStats, ServeError> {
        Ok(self.pool_stats().total)
    }

    /// Per-class admission counters plus autoscaler activity.
    pub fn admission_stats(&self) -> AdmissionStats {
        let mut stats = AdmissionStats {
            classes: Default::default(),
            scale_ups: self.shared.scale_ups.load(Ordering::Acquire),
            scale_downs: self.shared.scale_downs.load(Ordering::Acquire),
        };
        for (slot, counters) in stats.classes.iter_mut().zip(&self.shared.counters) {
            *slot = counters.snapshot();
        }
        stats
    }

    /// Full per-replica + rollup + admission snapshot.
    pub fn pool_stats(&self) -> PoolStats {
        let (version, acks, alive, canary) = {
            let cell = self.shared.cell.lock().unwrap();
            (
                cell.version,
                cell.acks.clone(),
                cell.alive.clone(),
                cell.canary.as_ref().map(|c| c.replica),
            )
        };
        let per = self.shared.metrics.lock().unwrap();
        let replicas: Vec<ReplicaStats> = per
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                metrics: r.metrics.clone(),
                model_version: acks[i],
                respawns: r.respawns,
                alive: alive[i],
            })
            .collect();
        drop(per);
        let mut total = Metrics::default();
        for r in &replicas {
            total.inferences += r.metrics.inferences;
            total.batches += r.metrics.batches;
            total.simulated_cycles += r.metrics.simulated_cycles;
            total.busy_micros += r.metrics.busy_micros;
            total.errors += r.metrics.errors;
        }
        total.reprograms = version;
        PoolStats { replicas, total, version, canary, admission: self.admission_stats() }
    }

    /// Ask the pool to stop.  Queued requests are drained first; new
    /// submissions are rejected with [`ServeError::ShutDown`].
    /// Idempotent.
    pub fn shutdown(&self) {
        shutdown_shared(&self.shared);
    }

    /// Arm a [`FaultPlan`] against a chosen replica: its next popped
    /// job is stalled, panicked on, or dropped without a reply.  The
    /// generalized fault-injection surface overload and supervision
    /// tests share instead of hand-rolling failure modes.
    #[doc(hidden)]
    pub fn inject_fault(&self, plan: FaultPlan) {
        self.shared.faults.arm(plan);
    }

    /// Fault injection: make the replica that picks this request panic
    /// mid-request.  Returns the same typed error a real panic would,
    /// after supervision has respawned the replica.  For tests and
    /// chaos drills.  Never lands on an active canary (like any Pool
    /// job).
    #[doc(hidden)]
    pub fn inject_panic(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Crash { target: Target::Pool, reply }, Priority::Normal)?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Fault injection on the CANARY replica: exercises the
    /// respawn-while-canary supervision path (the rebuilt replica must
    /// come back serving the CANDIDATE, not the pool model).
    #[doc(hidden)]
    pub fn inject_panic_canary(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Crash { target: Target::CanaryOnly, reply }, Priority::Critical)?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Fault injection: occupy whichever replica pops this job for
    /// `dur` — the deterministic "saturated pool" for deadline tests
    /// and chaos drills.  Returns immediately; the returned receiver
    /// resolves when the stall ends (drop it to fire and forget).
    /// Queued like a normal request; [`Self::inject_fault`] with
    /// [`FaultPlan::stall`] targets a specific replica instead.
    #[doc(hidden)]
    pub fn inject_stall(
        &self,
        dur: Duration,
    ) -> Result<mpsc::Receiver<Result<Vec<usize>, ServeError>>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Stall { dur, reply }, Priority::Normal)?;
        Ok(rx)
    }

    /// The admission front-end: shutdown and canary validity, deadline
    /// feasibility, the per-class bound with its backpressure policy,
    /// then routing to a shard.
    fn submit(&self, job: Job, class: Priority) -> Result<(), ServeError> {
        let shared = &*self.shared;
        let ci = class.index();
        if shared.shutdown.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        let target = job.target();
        if target == Target::CanaryOnly && self.canary_replica().is_none() {
            return Err(ServeError::Canary("no canary active"));
        }
        // Deadline-aware admission (Pool targets only — the canary
        // mirror is control traffic and never feasibility-rejected):
        // refuse a request whose projected queue wait behind
        // same-or-higher-class work already exceeds its deadline.
        let feasibility = job.deadline().filter(|_| target == Target::Pool);
        if let Some(deadline) = feasibility {
            let ahead: u64 = Priority::ALL[ci..]
                .iter()
                .map(|p| shared.counters[p.index()].depth())
                .sum();
            let replicas = self.live_pool_replicas();
            if let Some(wait) = shared.estimator.projected_wait(ahead, replicas) {
                let slack = deadline.saturating_duration_since(Instant::now());
                if wait > slack {
                    shared.counters[ci].reject_deadline();
                    return Err(ServeError::DeadlineExceeded);
                }
            }
        }
        // Per-class bound + backpressure policy.
        let cap = shared.config.cap(class) as u64;
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return Err(ServeError::ShutDown);
            }
            if shared.counters[ci].depth() < cap {
                break;
            }
            match shared.config.policy(class) {
                ShedPolicy::Reject => {
                    shared.counters[ci].reject_overloaded();
                    return Err(ServeError::Overloaded);
                }
                ShedPolicy::ShedOldest => {
                    // Evict the oldest queued request of this class (its
                    // client gets the typed Overloaded error).  If a
                    // popper emptied the class first, the loop re-checks
                    // the bound and admits.
                    self.shed_oldest(class);
                }
                ShedPolicy::Block => {
                    shared.space_waiters.fetch_add(1, Ordering::AcqRel);
                    let guard = shared.park.lock().unwrap();
                    // Re-check under the park lock: a pop between the
                    // depth check and here would otherwise be a lost
                    // wake.  The bounded wait is a belt-and-braces
                    // backstop, not the wake mechanism.
                    if shared.counters[ci].depth() < cap
                        || shared.shutdown.load(Ordering::Acquire)
                    {
                        shared.space_waiters.fetch_sub(1, Ordering::AcqRel);
                        continue;
                    }
                    let timeout = Duration::from_millis(10);
                    let _ = shared.space_cv.wait_timeout(guard, timeout).unwrap();
                    shared.space_waiters.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
        // Route: canary jobs to the canary's shard, pool jobs
        // round-robin over live, non-canary, non-retiring replicas.
        let shard = match target {
            Target::CanaryOnly => match self.canary_replica() {
                Some(i) => i,
                None => return Err(ServeError::Canary("no canary active")),
            },
            Target::Pool => self.route_pool(),
        };
        {
            let mut q = shared.shards[shard].q.lock().unwrap();
            if q.closed {
                return Err(ServeError::ShutDown);
            }
            // Re-checked UNDER the shard lock: dismissal clears the
            // mirror and then drains this shard (also under this lock),
            // so a CanaryOnly job admitted here is either rejected now
            // or found by the drain — never stranded.
            if target == Target::CanaryOnly
                && shared.canary_replica.load(Ordering::Acquire) != shard
            {
                return Err(ServeError::Canary("no canary active"));
            }
            shared.counters[ci].admit();
            q.classes[ci].push_back(job);
        }
        // With a canary active, the one woken worker might be
        // ineligible for the new job (e.g. the canary woken for a Pool
        // job) and would park again without another wake-up — wake
        // everyone.  With no canary, every worker is eligible for every
        // admissible job, so notify_one avoids a per-request thundering
        // herd on the serving hot path.
        wake_work(shared, self.canary_replica().is_some());
        Ok(())
    }

    /// Live replicas eligible for Pool traffic (feasibility divisor).
    fn live_pool_replicas(&self) -> usize {
        let shared = &*self.shared;
        let canary = shared.canary_replica.load(Ordering::Acquire);
        shared
            .alive_mirror
            .iter()
            .enumerate()
            .filter(|(i, a)| *i != canary && a.load(Ordering::Acquire))
            .count()
            .max(1)
    }

    /// Pick a shard for a Pool job: round-robin over live, non-canary,
    /// non-retiring replicas.  With none eligible right now (mass death
    /// or mid-scale), park the job anywhere — work stealing or the
    /// teardown drain will find it.
    fn route_pool(&self) -> usize {
        let shared = &*self.shared;
        let n = shared.shards.len();
        let start = shared.rr.fetch_add(1, Ordering::Relaxed) % n;
        let canary = shared.canary_replica.load(Ordering::Acquire);
        for k in 0..n {
            let i = (start + k) % n;
            if i != canary
                && shared.alive_mirror[i].load(Ordering::Acquire)
                && !shared.retire[i].load(Ordering::Acquire)
            {
                return i;
            }
        }
        start
    }

    /// Evict the oldest queued request of `class` (scanning shards in
    /// index order — "oldest" is per-shard FIFO order, which is exact
    /// on a single shard and the oldest front across shards otherwise).
    fn shed_oldest(&self, class: Priority) {
        let shared = &*self.shared;
        let ci = class.index();
        let mut victim = None;
        for shard in &shared.shards {
            let mut q = shard.q.lock().unwrap();
            if let Some(job) = q.classes[ci].pop_front() {
                shared.counters[ci].pop_shed();
                victim = Some(job);
                break;
            }
        }
        if let Some(job) = victim {
            wake_space(shared);
            job.fail(|| ServeError::Overloaded);
        }
    }
}

/// Blocking receive with the optional deadline semantics every RPC
/// wrapper shares.
fn recv_reply<T>(
    rx: &mpsc::Receiver<Result<T, ServeError>>,
    timeout: Option<Duration>,
) -> Result<T, ServeError> {
    match timeout {
        None => rx.recv().map_err(|_| ServeError::WorkerGone)?,
        Some(t) => match rx.recv_timeout(t) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServeError::DeadlineExceeded),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::WorkerGone),
        },
    }
}

/// Wake parked workers after enqueueing work (or raising a fence):
/// the epoch is bumped UNDER the park lock, so a worker that scanned
/// the shards before this enqueue cannot park past it.
fn wake_work(shared: &Shared, all: bool) {
    let _guard = shared.park.lock().unwrap();
    shared.epoch.fetch_add(1, Ordering::Release);
    if all {
        shared.work_cv.notify_all();
    } else {
        shared.work_cv.notify_one();
    }
}

/// Wake submitters blocked on a full class queue, if any.
fn wake_space(shared: &Shared) {
    if shared.space_waiters.load(Ordering::Acquire) == 0 {
        return;
    }
    let _guard = shared.park.lock().unwrap();
    shared.space_cv.notify_all();
}

/// Flip the pool to shutdown and wake everything parked on it.
/// Idempotent.
fn shutdown_shared(shared: &Shared) {
    shared.shutdown.store(true, Ordering::Release);
    let _guard = shared.park.lock().unwrap();
    shared.epoch.fetch_add(1, Ordering::Release);
    shared.work_cv.notify_all();
    shared.space_cv.notify_all();
}

/// What the queue wait resolved to.
enum Next {
    Work { job: Job, class: Priority },
    /// A newer model version is pending — swap before taking work.
    Resync,
    Exit,
}

/// Runs on every worker exit — normal return, supervisor retirement,
/// or a panic that escaped `catch_unwind` (e.g. an invalid spec
/// panicking in `build()`): marks the replica dead and wakes fence
/// waiters so `program` can never hang on a corpse.  When the LAST
/// replica dies, flips the pool to shutdown and drops any parked jobs,
/// so clients blocked on replies get [`ServeError::WorkerGone`]
/// instead of waiting forever.
struct DeathWatch<'a> {
    shared: &'a Shared,
    idx: usize,
}

impl Drop for DeathWatch<'_> {
    fn drop(&mut self) {
        self.shared.alive_mirror[self.idx].store(false, Ordering::Release);
        let (all_dead, canary_cleared) = {
            let mut cell = self.shared.cell.lock().unwrap();
            cell.alive[self.idx] = false;
            // A dying canary takes its candidate with it: clear the
            // canary state so Pool traffic stops avoiding a corpse and
            // new CanaryOnly submissions are rejected instead of
            // stranded.  Symmetrically, if this death leaves ONLY the
            // canary alive, the canary must be dismissed — Pool jobs
            // would otherwise have no eligible worker and their callers
            // would block forever.  The version bump makes the
            // surviving canary resync onto the pool model before it
            // serves live traffic.
            let was_canary = cell.canary.as_ref().is_some_and(|c| c.replica == self.idx);
            let only_canary_left = cell.canary.as_ref().is_some_and(|c| {
                cell.alive.iter().enumerate().all(|(i, &a)| !a || i == c.replica)
            });
            let canary_cleared = was_canary || only_canary_left;
            if canary_cleared {
                cell.canary = None;
                self.shared.canary_replica.store(NO_CANARY, Ordering::Release);
                cell.version += 1;
                self.shared.version.store(cell.version, Ordering::Release);
            }
            (!cell.alive.iter().any(|&a| a), canary_cleared)
        };
        self.shared.fence_cv.notify_all();
        if canary_cleared && !all_dead {
            drain_canary_jobs(self.shared, "canary replica died");
            // Wake survivors: the version bump above needs a resync.
            wake_work(self.shared, true);
        }
        if all_dead {
            close_shards(self.shared);
            shutdown_shared(self.shared);
        }
        // Last: the supervisor may revive this slot only once the
        // worker is fully gone.
        self.shared.retire[self.idx].store(false, Ordering::Release);
        self.shared.exited[self.idx].store(true, Ordering::Release);
    }
}

/// Teardown: close every shard and drop whatever is still queued.
/// Dropping a job drops its reply sender, so blocked clients get
/// [`ServeError::WorkerGone`].
fn close_shards(shared: &Shared) {
    let mut dropped: Vec<Job> = Vec::new();
    for shard in &shared.shards {
        let mut q = shard.q.lock().unwrap();
        q.closed = true;
        for (ci, class) in q.classes.iter_mut().enumerate() {
            while let Some(job) = class.pop_front() {
                shared.counters[ci].pop_shed();
                dropped.push(job);
            }
        }
    }
    drop(dropped);
}

/// Fail any still-queued canary-targeted jobs with a typed error.
/// Called after the canary is cleared (dismissal, pool broadcast, or
/// canary-worker death): no worker is eligible for them anymore, so
/// leaving them queued would strand their callers.  The replies are
/// sent outside the shard locks.
fn drain_canary_jobs(shared: &Shared, reason: &'static str) {
    let mut stranded: Vec<Job> = Vec::new();
    for shard in &shared.shards {
        let mut q = shard.q.lock().unwrap();
        for (ci, class) in q.classes.iter_mut().enumerate() {
            let mut kept = VecDeque::with_capacity(class.len());
            while let Some(job) = class.pop_front() {
                if job.target() == Target::CanaryOnly {
                    shared.counters[ci].pop_shed();
                    stranded.push(job);
                } else {
                    kept.push_back(job);
                }
            }
            *class = kept;
        }
    }
    if !stranded.is_empty() {
        wake_space(shared);
    }
    for job in stranded {
        job.fail_canary(reason);
    }
}

/// May a worker serve a job with this target?  While a worker is the
/// canary it serves ONLY CanaryOnly jobs and every other worker serves
/// ONLY Pool jobs — a candidate under evaluation is never exposed to
/// live traffic, and the baseline never answers the mirrored stream.
///
/// `am_canary` is the worker-local answer learned at its last fence
/// resync from the AUTHORITATIVE cell (every canary mutation bumps the
/// version, so a worker always resyncs before taking work under a new
/// canary assignment) — deliberately not the lock-free mirror, whose
/// propagation lag could otherwise let a freshly-assigned canary pick
/// up one live request.
fn eligible(target: Target, am_canary: bool) -> bool {
    match target {
        Target::Pool => !am_canary,
        Target::CanaryOnly => am_canary,
    }
}

/// Worker-local execution state: the service, the model Arc it last
/// programmed (so fences that do not change THIS replica's model — e.g.
/// a sibling becoming the canary — ack without a redundant reprogram),
/// and whether the cell named this worker the canary at its last
/// resync.
struct WorkerState {
    service: InferenceService,
    last_model: Option<Arc<TMModel>>,
    am_canary: bool,
}

fn worker_loop(shared: &Shared, idx: usize) {
    let _watch = DeathWatch { shared, idx };
    let mut state = WorkerState {
        service: InferenceService::new(shared.spec.build()),
        last_model: None,
        am_canary: false,
    };
    // A revived slot carries the counters its previous incarnation
    // published (scale-down must not erase served history).
    state.service.metrics = shared.metrics.lock().unwrap()[idx].metrics.clone();
    let mut my_version = 0u64;
    loop {
        // Fence check between requests: drain (we are between jobs),
        // swap, resume.
        if shared.version.load(Ordering::Acquire) != my_version {
            my_version = program_from_cell(shared, idx, &mut state);
        }
        let am_canary = state.am_canary;
        let next = loop {
            // Pending reprogram outranks new work: no job may start
            // on a stale replica once the fence is up.
            if shared.version.load(Ordering::Acquire) != my_version {
                break Next::Resync;
            }
            // Supervisor retirement: exit instead of taking work.  (An
            // active canary ignores the flag; the supervisor never
            // targets it, and the race where it just became one must
            // not kill the mirror.)
            if shared.retire[idx].load(Ordering::Acquire) && !am_canary {
                break Next::Exit;
            }
            let epoch = shared.epoch.load(Ordering::Acquire);
            if let Some((job, class)) = next_job(shared, idx, am_canary) {
                break Next::Work { job, class };
            }
            if shared.shutdown.load(Ordering::Acquire) {
                break Next::Exit;
            }
            // Nothing to do: park — unless an enqueue raced the scan
            // (the epoch moved), then rescan instead.  The bounded wait
            // is a backstop; the epoch check is the correctness.
            let guard = shared.park.lock().unwrap();
            if shared.epoch.load(Ordering::Acquire) == epoch {
                let _ = shared.work_cv.wait_timeout(guard, Duration::from_millis(10)).unwrap();
            }
        };
        match next {
            Next::Resync => continue,
            // DeathWatch marks the replica dead on the way out.
            Next::Exit => return,
            Next::Work { job, class } => {
                run_job(shared, idx, &mut state, &mut my_version, job, class);
            }
        }
    }
}

/// Class-major pop with work stealing: scan `Critical` down to `Low`,
/// own shard first then siblings, skipping jobs this worker is not
/// eligible for and shedding expired ones unexecuted.
fn next_job(shared: &Shared, idx: usize, am_canary: bool) -> Option<(Job, Priority)> {
    let n = shared.shards.len();
    let mut expired: Vec<Job> = Vec::new();
    let mut found: Option<(Job, Priority)> = None;
    'classes: for class in Priority::ALL.iter().rev() {
        let ci = class.index();
        // Lock-free skip of empty classes (depth is bumped before the
        // push becomes visible, so a miss here is re-driven by the
        // submitter's epoch bump).
        if shared.counters[ci].depth() == 0 {
            continue;
        }
        for k in 0..n {
            let shard = (idx + k) % n;
            let mut q = shared.shards[shard].q.lock().unwrap();
            loop {
                let pos = q.classes[ci]
                    .iter()
                    .position(|j| eligible(j.target(), am_canary));
                let Some(pos) = pos else { break };
                let job = q.classes[ci].remove(pos).expect("position just found");
                if job.deadline().is_some_and(|d| Instant::now() > d) {
                    // Shed expired work before computing it: the client
                    // already got DeadlineExceeded from its
                    // recv_timeout, so executing the job would burn the
                    // replica for a discarded answer.
                    shared.counters[ci].pop_expired();
                    expired.push(job);
                } else {
                    shared.counters[ci].pop_served();
                    found = Some((job, *class));
                    break;
                }
            }
            drop(q);
            if found.is_some() {
                break 'classes;
            }
        }
    }
    if !expired.is_empty() || found.is_some() {
        wake_space(shared);
    }
    for job in expired {
        job.fail(|| ServeError::DeadlineExceeded);
    }
    found
}

fn run_job(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
    job: Job,
    class: Priority,
) {
    // Armed fault plans apply to the next popped job on this replica.
    let mut force_panic = false;
    match shared.faults.poll(idx) {
        Some(Fault::Stall(dur)) => std::thread::sleep(dur),
        Some(Fault::PanicOnJob { .. }) => force_panic = true,
        Some(Fault::DropReply) => {
            // Dropping the job drops its reply sender: the client
            // observes WorkerGone — the supervision blind spot every
            // caller must tolerate.
            drop(job);
            return;
        }
        None => {}
    }
    match job {
        Job::Infer { rows, deadline, reply, .. } => {
            // The pop-side shed already filtered expired jobs, but an
            // injected stall may have burned the budget since: shed
            // here too rather than compute a discarded answer.  (The
            // pop already counted it served, so only the miss is
            // recorded.)
            if deadline.is_some_and(|d| Instant::now() > d) {
                shared.counters[class.index()].expire_in_service();
                let _ = reply.send(Err(ServeError::DeadlineExceeded));
                return;
            }
            let t0 = Instant::now();
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                if force_panic {
                    panic!("injected fault (FaultPlan::PanicOnJob)");
                }
                state.service.infer_all(&rows)
            }));
            if matches!(&outcome, Ok(Ok(_))) {
                shared.estimator.observe(t0.elapsed());
            }
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Stall { dur, reply } => {
            std::thread::sleep(dur);
            if force_panic {
                let outcome =
                    panic::catch_unwind(AssertUnwindSafe(|| -> Result<Vec<usize>, CoreError> {
                        panic!("injected fault (FaultPlan::PanicOnJob)")
                    }));
                reply_or_respawn(shared, idx, state, my_version, outcome, reply);
            } else {
                let _ = reply.send(Ok(Vec::new()));
            }
        }
        Job::Telemetry { rows, deadline, reply, .. } => {
            if deadline.is_some_and(|d| Instant::now() > d) {
                shared.counters[class.index()].expire_in_service();
                let _ = reply.send(Err(ServeError::DeadlineExceeded));
                return;
            }
            // Capture the fence version the request runs under BEFORE
            // the work: a panic respawn may advance `my_version`.
            let version = *my_version;
            let t0 = Instant::now();
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                if force_panic {
                    panic!("injected fault (FaultPlan::PanicOnJob)");
                }
                state
                    .service
                    .infer_with_margins(&rows)
                    .map(|(preds, margins)| Telemetry { preds, margins, model_version: version })
            }));
            if matches!(&outcome, Ok(Ok(_))) {
                shared.estimator.observe(t0.elapsed());
            }
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
        Job::Crash { reply, .. } => {
            let outcome =
                panic::catch_unwind(AssertUnwindSafe(|| -> Result<Vec<usize>, CoreError> {
                    panic!("injected fault (ServiceHandle::inject_panic)")
                }));
            reply_or_respawn(shared, idx, state, my_version, outcome, reply);
        }
    }
}

/// Shared tail of the per-request supervision protocol, for every job
/// flavour: on success, publish this replica's metrics BEFORE replying
/// (a client that got its answer always sees it reflected in
/// `stats()`); on a caught panic, respawn the replica and fail only
/// the offending request.
fn reply_or_respawn<T>(
    shared: &Shared,
    idx: usize,
    state: &mut WorkerState,
    my_version: &mut u64,
    outcome: std::thread::Result<Result<T, CoreError>>,
    reply: mpsc::Sender<Result<T, ServeError>>,
) {
    match outcome {
        Ok(result) => {
            shared.metrics.lock().unwrap()[idx].metrics = state.service.metrics.clone();
            let _ = reply.send(result.map_err(ServeError::Core));
        }
        Err(_panic) => {
            respawn_replica(shared, idx, state, my_version);
            let _ = reply.send(Err(ServeError::WorkerPanicked { replica: idx }));
        }
    }
}

/// Supervision: a panicking request may have left the replica in an
/// arbitrary state.  Rebuild the engine from the spec, carry the
/// counters over (plus the error), reprogram from the last-programmed
/// model, then let the caller fail only the offending request.
fn respawn_replica(shared: &Shared, idx: usize, state: &mut WorkerState, my_version: &mut u64) {
    let mut carried = state.service.metrics.clone();
    carried.errors += 1;
    state.service = InferenceService::new(shared.spec.build());
    // The fresh engine is unprogrammed: the reprogram-skip memo must
    // not survive the rebuild.
    state.last_model = None;
    state.service.metrics = carried;
    {
        let mut per = shared.metrics.lock().unwrap();
        per[idx].respawns += 1;
        per[idx].metrics = state.service.metrics.clone();
    }
    *my_version = program_from_cell(shared, idx, state);
}

/// Swap this worker's service to the model the cell assigns IT — the
/// canary candidate when this replica is the canary, the pool model
/// otherwise — and acknowledge the version (the worker half of the
/// fence).  Also the respawn path: called with a freshly built engine,
/// it re-installs the assigned model.  Returns the version applied.
///
/// A fence that does not change this replica's model (same Arc as the
/// last programmed one — e.g. a sibling became the canary) acks without
/// touching the engine, so canary lifecycle operations cost the
/// non-participating replicas one drain, not one reprogram.
fn program_from_cell(shared: &Shared, idx: usize, state: &mut WorkerState) -> u64 {
    let (target, model) = {
        let cell = shared.cell.lock().unwrap();
        let am_canary = cell.canary.as_ref().is_some_and(|c| c.replica == idx);
        state.am_canary = am_canary;
        let model = if am_canary {
            cell.canary.as_ref().map(|c| Arc::clone(&c.model))
        } else {
            cell.model.clone()
        };
        (cell.version, model)
    };
    // Program outside the lock: encoding + programming a large model is
    // the slow part, and siblings must be able to ack concurrently.
    let failure = match &model {
        Some(m) if state.last_model.as_ref().is_some_and(|l| Arc::ptr_eq(l, m)) => None,
        Some(m) => match state.service.reprogram(m) {
            Ok(()) => {
                state.last_model = Some(Arc::clone(m));
                None
            }
            Err(e) => {
                // A failed swap must not leave this replica on the
                // stale model: a single core keeps its old program
                // when the new one overflows instruction memory, and a
                // multi-core can stop half-programmed.  Rebuild the
                // engine unprogrammed (counters carried) so the
                // replica serves NotProgrammed, never version N-1.
                let carried = state.service.metrics.clone();
                state.service = InferenceService::new(shared.spec.build());
                state.service.metrics = carried;
                state.last_model = None;
                Some(e)
            }
        },
        None => None,
    };
    // Keep the published per-replica metrics fresh (reprogram bumps a
    // counter outside the job path).
    shared.metrics.lock().unwrap()[idx].metrics = state.service.metrics.clone();
    let mut cell = shared.cell.lock().unwrap();
    if cell.acks[idx] < target {
        cell.acks[idx] = target;
        cell.errors[idx] = failure.map(|e| (target, e));
        shared.fence_cv.notify_all();
    }
    target
}

/// Autoscaling supervisor: samples total queue depth and the
/// deadline-miss delta every `interval`; grows the pool toward `max`
/// under pressure (depth above `depth_per_replica` per live replica,
/// or any miss this interval) and retires one replica toward `min`
/// (never the canary) after `idle_ticks` consecutive idle intervals.
fn supervisor_loop(shared: &Arc<Shared>, cfg: &AutoscaleConfig) {
    let mut idle_ticks = 0u32;
    let mut last_misses = 0u64;
    loop {
        std::thread::sleep(cfg.interval);
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let depth: u64 = shared.counters.iter().map(|c| c.depth()).sum();
        let misses: u64 = shared
            .counters
            .iter()
            .map(|c| c.snapshot().deadline_misses)
            .sum();
        let new_misses = misses.saturating_sub(last_misses);
        last_misses = misses;
        // Retiring replicas are on their way out: count them neither
        // for pressure nor for the `min` floor.
        let live = shared
            .alive_mirror
            .iter()
            .zip(&shared.retire)
            .filter(|(a, r)| a.load(Ordering::Acquire) && !r.load(Ordering::Acquire))
            .count();
        let pressured =
            depth > (cfg.depth_per_replica * live.max(1)) as u64 || new_misses > 0;
        if pressured {
            idle_ticks = 0;
            if live < cfg.max {
                scale_up(shared);
            }
        } else if depth == 0 {
            idle_ticks += 1;
            if idle_ticks >= cfg.idle_ticks && live > cfg.min {
                idle_ticks = 0;
                scale_down(shared);
            }
        } else {
            idle_ticks = 0;
        }
    }
}

/// Revive one dead slot whose previous worker has fully exited.
fn scale_up(shared: &Arc<Shared>) {
    let idx = {
        let mut cell = shared.cell.lock().unwrap();
        let slot = (0..cell.alive.len())
            .find(|&i| !cell.alive[i] && shared.exited[i].load(Ordering::Acquire));
        let Some(i) = slot else { return };
        cell.alive[i] = true;
        cell.acks[i] = 0;
        cell.errors[i] = None;
        i
    };
    shared.retire[idx].store(false, Ordering::Release);
    shared.exited[idx].store(false, Ordering::Release);
    shared.alive_mirror[idx].store(true, Ordering::Release);
    let handle = spawn_worker(shared, idx);
    shared.extra_workers.lock().unwrap().push(handle);
    shared.scale_ups.fetch_add(1, Ordering::AcqRel);
}

/// Flag the highest-index live, non-canary, non-retiring replica for
/// retirement; it exits at its next pop and its queued jobs are stolen
/// by the survivors.
fn scale_down(shared: &Shared) {
    let victim = {
        let cell = shared.cell.lock().unwrap();
        let canary = cell.canary.as_ref().map(|c| c.replica);
        (0..cell.alive.len()).rev().find(|&i| {
            cell.alive[i] && Some(i) != canary && !shared.retire[i].load(Ordering::Acquire)
        })
    };
    let Some(idx) = victim else { return };
    shared.retire[idx].store(true, Ordering::Release);
    shared.scale_downs.fetch_add(1, Ordering::AcqRel);
    // Wake everyone: the retiring worker must notice the flag.
    wake_work(shared, true);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).generate();
        (crate::trainer::train_model(&shape, &data, 4, 2), data)
    }

    #[test]
    fn rpc_roundtrip() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.inferences, 96);
        assert_eq!(stats.reprograms, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn infer_before_program_is_error_not_crash() {
        let (h, mut join) = spawn(EngineSpec::base());
        assert!(matches!(
            h.infer(vec![vec![0u8; 12]]),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn concurrent_clients_share_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model).unwrap();
        let mut threads = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            let rows = data.xs.clone();
            threads.push(std::thread::spawn(move || h.infer(rows).unwrap().len()));
        }
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 4 * 96);
        assert_eq!(h.stats().unwrap().inferences, 4 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn reprogram_mid_serving_takes_effect() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let new_model = crate::trainer::train_model(&shape, &drifted, 4, 3);
        h.program(new_model).unwrap();
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before.len(), after.len());
        let stats = h.pool_stats();
        assert_eq!(stats.version, 2);
        assert_eq!(stats.total.reprograms, 2);
        // The fence: both replicas on the new version once program() returned.
        for r in &stats.replicas {
            assert_eq!(r.model_version, 2);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn telemetry_matches_single_service_and_reports_fence_version() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();

        let mut reference = InferenceService::new(EngineSpec::base().build());
        reference.reprogram(&model).unwrap();
        let (want_preds, want_margins) = reference.infer_with_margins(&data.xs).unwrap();

        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_preds);
        assert_eq!(tel.margins, want_margins);
        assert_eq!(tel.model_version, 1);

        // Telemetry rides the version fence like any request.
        h.program(model).unwrap();
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.model_version, 2);

        // Malformed telemetry probes are typed errors, not pool deaths.
        assert!(matches!(
            h.infer_telemetry(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn malformed_requests_do_not_kill_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();

        assert!(matches!(
            h.infer(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        let ragged = vec![vec![0u8; 12], vec![0u8; 5]];
        assert!(matches!(
            h.infer(ragged),
            Err(ServeError::Core(CoreError::BadBatch { .. }))
        ));
        // The pool keeps serving on the same handle.
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.inferences, 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn injected_panic_respawns_replica_and_pool_survives() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();

        match h.inject_panic() {
            Err(ServeError::WorkerPanicked { replica }) => assert_eq!(replica, 0),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        // Same handle, same answers: the replica was respawned from the
        // last-programmed model.
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before, after);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 1);
        assert!(stats.replicas[0].alive);
        // The panic is visible as an error, and counters survived.
        assert_eq!(stats.total.errors, 1);
        assert_eq!(stats.total.inferences, 2 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_swap_never_leaves_stale_or_mixed_models() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        // A bigger model that cannot fit the instruction memory sized
        // exactly for the small one.
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        let n_big = crate::isa::instruction_count(&big);
        assert!(n_big > n_small, "test premise: {n_big} > {n_small}");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 2);
        h.program(small.clone()).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());

        // The too-big model must fail the swap as a typed error…
        assert!(matches!(h.program(big), Err(ServeError::Core(_))));
        // …and replicas must be unprogrammed — not stale on the old
        // model with the new version acked.
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        // A fitting reprogram fully recovers the pool.
        h.program(small).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());
        h.shutdown();
        join.join();
    }

    #[test]
    fn dead_pool_errors_instead_of_hanging() {
        use crate::accel::core::AccelConfig;
        use crate::accel::multicore::ParallelMode;

        // An invalid spec panics in build() at worker startup — outside
        // the per-request catch_unwind.  The DeathWatch must surface
        // this as errors, never as a hang.
        let bad = EngineSpec::Multi {
            cores: 0,
            per_core: AccelConfig::multicore_core(),
            parallel: ParallelMode::Auto,
        };
        let (h, mut join) = spawn_pool(bad, 2);
        join.join();
        let (model, data) = trained();
        assert!(matches!(h.program(model), Err(ServeError::ShutDown)));
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::ShutDown) | Err(ServeError::WorkerGone)
        ));
    }

    #[test]
    fn canary_serves_only_the_mirrored_stream() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model_a.clone()).unwrap();
        let want_a = h.infer(data.xs.clone()).unwrap();

        // Reference answers for both models.
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();
        assert_ne!(want_a, want_b, "test premise: the models must disagree");

        // No canary yet: canary-targeted requests are typed errors.
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        assert!(h.canary_replica().is_none());

        let replica = h.program_canary(model_b.clone()).unwrap();
        assert_eq!(replica, 2, "highest-index live replica is the canary");
        assert_eq!(h.canary_replica(), Some(2));
        assert_eq!(h.pool_stats().canary, Some(2));

        // Live traffic NEVER sees the candidate; the mirror ONLY does.
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        assert_eq!(h.infer_canary(data.xs.clone()).unwrap(), want_b);
        let tel = h.infer_telemetry_canary(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_b);
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_a);

        // Dismiss: the canary replica returns to the pool model.
        assert!(h.dismiss_canary().unwrap());
        assert!(h.canary_replica().is_none());
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        // Dismissal is idempotent.
        assert!(!h.dismiss_canary().unwrap());

        // Versions strictly monotone: program(1), canary(2), dismiss(3).
        let stats = h.pool_stats();
        assert_eq!(stats.version, 3);
        for r in &stats.replicas {
            assert_eq!(r.model_version, 3);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_promote_broadcasts_the_candidate() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        // Promote with no canary is a typed error.
        assert!(matches!(h.promote_canary(), Err(ServeError::Canary(_))));
        h.program(model_a).unwrap();
        h.program_canary(model_b).unwrap();
        h.promote_canary().unwrap();
        assert!(h.canary_replica().is_none());
        // Every replica now serves the candidate.
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_b);
        }
        let stats = h.pool_stats();
        assert_eq!(stats.version, 3); // program, canary, promote
        for r in &stats.replicas {
            assert_eq!(r.model_version, 3);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_panic_respawns_with_the_candidate_not_the_pool_model() {
        let (model_a, data) = trained();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let model_b = crate::trainer::train_model(&shape, &drifted, 4, 3);
        let mut svc_b = InferenceService::new(EngineSpec::base().build());
        svc_b.reprogram(&model_b).unwrap();
        let want_b = svc_b.infer_all(&data.xs).unwrap();

        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        // No canary yet: canary-targeted injection is a typed error.
        assert!(matches!(h.inject_panic_canary(), Err(ServeError::Canary(_))));
        h.program(model_a).unwrap();
        let want_a = h.infer(data.xs.clone()).unwrap();
        let replica = h.program_canary(model_b).unwrap();

        // Panic the CANARY worker mid-request: supervision must rebuild
        // it serving the CANDIDATE (a respawn onto the pool model would
        // make every paired window tie and promote any candidate).
        match h.inject_panic_canary() {
            Err(ServeError::WorkerPanicked { replica: r }) => assert_eq!(r, replica),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        assert_eq!(h.infer_canary(data.xs.clone()).unwrap(), want_b);
        // And the pool half is untouched throughout.
        for _ in 0..4 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want_a);
        }
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[replica].respawns, 1);
        assert!(stats.replicas[replica].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn canary_requires_a_baseline_and_two_replicas() {
        let (model, _) = trained();
        // No baseline model programmed yet.
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        assert!(matches!(
            h.program_canary(model.clone()),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
        // Single-replica pool: a "canary" would be a whole-pool swap.
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        assert!(matches!(
            h.program_canary(model),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_canary_program_is_recoverable_by_dismissal() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        assert!(crate::isa::instruction_count(&big) > n_small, "test premise");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 3);
        h.program(small).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        // The candidate overflows the canary replica's memories: typed
        // error, and ONLY that replica was ever disturbed.
        assert!(matches!(h.program_canary(big), Err(ServeError::Core(_))));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        // Dismissal restores the canary replica to the pool model.
        assert!(h.dismiss_canary().unwrap());
        assert!(h.canary_replica().is_none());
        for _ in 0..6 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn pool_broadcast_dismisses_an_active_canary() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        h.program_canary(model.clone()).unwrap();
        assert_eq!(h.canary_replica(), Some(1));
        h.program(model).unwrap();
        assert!(h.canary_replica().is_none());
        assert!(matches!(
            h.infer_canary(data.xs.clone()),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn deadline_request_errors_on_a_stalled_pool() {
        use std::time::{Duration, Instant};

        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        // Idle pool: a generous deadline behaves exactly like infer().
        let want = h.infer(data.xs.clone()).unwrap();
        assert_eq!(
            h.infer_deadline(data.xs.clone(), Duration::from_secs(30)).unwrap(),
            want
        );
        // Stall the lone replica; a tight deadline must come back as a
        // typed error instead of blocking until the stall clears.
        let stall = h.inject_stall(Duration::from_millis(400)).unwrap();
        let t0 = Instant::now();
        assert!(matches!(
            h.infer_deadline(data.xs.clone(), Duration::from_millis(40)),
            Err(ServeError::DeadlineExceeded)
        ));
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "deadline must not wait out the stall"
        );
        // Once the stall ends the pool recovers; the expired job was
        // shed unexecuted (its late answer had nowhere to go anyway).
        stall.recv().unwrap().unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        h.shutdown();
        join.join();
    }

    #[test]
    fn shutdown_and_join_are_idempotent() {
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.shutdown();
        h.shutdown();
        join.join();
        join.join();
        assert!(matches!(h.infer(vec![vec![0u8; 4]]), Err(ServeError::ShutDown)));
        let (m, _) = trained();
        assert!(matches!(h.program(m), Err(ServeError::ShutDown)));
        // Stats still readable after shutdown (final reporting).
        assert_eq!(h.stats().unwrap().inferences, 0);
    }

    #[test]
    fn critical_overtakes_queued_low_under_stall() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        h.infer(data.xs.clone()).unwrap();

        // Wedge the lone replica so everything below queues behind it.
        let stall = h.inject_stall(Duration::from_millis(200)).unwrap();
        std::thread::sleep(Duration::from_millis(40)); // stall now being served
        let mut lows = Vec::new();
        for _ in 0..3 {
            let h = h.clone();
            let rows = data.xs[..16].to_vec();
            lows.push(std::thread::spawn(move || {
                h.infer_class(rows, Priority::Low).unwrap();
                Instant::now()
            }));
        }
        std::thread::sleep(Duration::from_millis(40)); // lows are queued
        let crit = {
            let h = h.clone();
            let rows = data.xs[..16].to_vec();
            std::thread::spawn(move || {
                h.infer_class(rows, Priority::Critical).unwrap();
                Instant::now()
            })
        };
        // Class-major pop: the Critical request submitted LAST finishes
        // before every queued Low one.
        let crit_done = crit.join().unwrap();
        for t in lows {
            let low_done = t.join().unwrap();
            assert!(
                crit_done < low_done,
                "Critical must overtake queued Low requests"
            );
        }
        stall.recv().unwrap().unwrap();
        h.shutdown();
        join.join();
    }

    #[test]
    fn reject_policy_returns_typed_overloaded() {
        let (model, data) = trained();
        let cfg = PoolConfig {
            replicas: 1,
            admission: AdmissionConfig::uniform(1, ShedPolicy::Reject),
            autoscale: None,
        };
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        let stall = h.inject_stall(Duration::from_millis(250)).unwrap();
        // Wait until the stall is being served (Normal queue empty).
        while h.admission_stats().class(Priority::Normal).depth > 0 {
            std::thread::yield_now();
        }
        // Fill the Low queue (cap 1) with one queued request…
        let queued = {
            let h = h.clone();
            let rows = data.xs.clone();
            std::thread::spawn(move || h.infer_class(rows, Priority::Low))
        };
        while h.admission_stats().class(Priority::Low).depth == 0 {
            std::thread::yield_now();
        }
        // …so the next Low submission is refused with the typed error.
        assert!(matches!(
            h.infer_class(data.xs.clone(), Priority::Low),
            Err(ServeError::Overloaded)
        ));
        assert_eq!(queued.join().unwrap().unwrap(), want);
        stall.recv().unwrap().unwrap();
        let stats = h.admission_stats();
        let low = stats.class(Priority::Low);
        assert_eq!(low.admitted, 1);
        assert_eq!(low.rejected, 1);
        assert_eq!(low.served, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn shed_oldest_evicts_the_oldest_queued_request() {
        let (model, data) = trained();
        let cfg = PoolConfig {
            replicas: 1,
            admission: AdmissionConfig::uniform(1, ShedPolicy::ShedOldest),
            autoscale: None,
        };
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();

        let stall = h.inject_stall(Duration::from_millis(250)).unwrap();
        while h.admission_stats().class(Priority::Normal).depth > 0 {
            std::thread::yield_now();
        }
        // A queues first, then B arrives: B's admission evicts A
        // (freshest data wins), and B gets A's slot.
        let first = {
            let h = h.clone();
            let rows = data.xs.clone();
            std::thread::spawn(move || h.infer_class(rows, Priority::Low))
        };
        while h.admission_stats().class(Priority::Low).depth == 0 {
            std::thread::yield_now();
        }
        let second = h.infer_class(data.xs.clone(), Priority::Low);
        assert!(matches!(first.join().unwrap(), Err(ServeError::Overloaded)));
        assert_eq!(second.unwrap(), want);
        stall.recv().unwrap().unwrap();
        let stats = h.admission_stats();
        let low = stats.class(Priority::Low);
        assert_eq!(low.admitted, 2);
        assert_eq!(low.shed, 1);
        assert_eq!(low.served, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn infeasible_deadline_is_rejected_at_submit() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        // Warm the service-time estimator with a real request.
        h.infer(data.xs.clone()).unwrap();

        // Pile up queued work so est × ahead dwarfs any slack.
        let stalls: Vec<_> = (0..64)
            .map(|_| h.inject_stall(Duration::from_millis(2)).unwrap())
            .collect();
        assert!(matches!(
            h.infer_deadline(data.xs.clone(), Duration::from_micros(1)),
            Err(ServeError::DeadlineExceeded)
        ));
        let stats = h.admission_stats();
        let normal = stats.class(Priority::Normal);
        assert!(normal.rejected >= 1, "feasibility reject must be counted");
        assert!(normal.deadline_misses >= 1);
        for s in stalls {
            s.recv().unwrap().unwrap();
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn admission_counters_reconcile_when_idle() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();
        for class in Priority::ALL {
            for _ in 0..3 {
                h.infer_class(data.xs[..8].to_vec(), class).unwrap();
            }
        }
        h.infer_telemetry_class(data.xs[..8].to_vec(), Priority::High).unwrap();
        let stats = h.admission_stats();
        for class in Priority::ALL {
            let c = stats.class(class);
            let want = if class == Priority::High { 4 } else { 3 };
            assert_eq!(c.admitted, want, "class {class}");
            assert_eq!(c.served, want, "class {class}");
            assert_eq!(c.depth, 0);
            assert_eq!(c.rejected + c.shed + c.deadline_misses, 0);
        }
        assert_eq!(stats.depth_total(), 0);
        assert_eq!(stats.lost_total(), 0);
        h.shutdown();
        join.join();
    }

    #[test]
    fn drop_reply_fault_surfaces_worker_gone() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        h.inject_fault(FaultPlan::drop_reply(0));
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::WorkerGone)
        ));
        // The fault consumed itself; the replica is healthy.
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 0);
        assert!(stats.replicas[0].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn panic_on_nth_job_fault_fires_once_and_respawns() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        // nth = 2: the next job sails through, the one after panics.
        h.inject_fault(FaultPlan::panic_on_job(0, 2));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::WorkerPanicked { replica: 0 })
        ));
        assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 1);
        assert!(stats.replicas[0].alive);
        h.shutdown();
        join.join();
    }

    #[test]
    fn stall_fault_wedges_only_the_chosen_replica() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();
        let want = h.infer(data.xs.clone()).unwrap();
        h.inject_fault(FaultPlan::stall(0, Duration::from_millis(150)));
        // Requests keep answering correctly; at most one rides out the
        // stall.  No panics, no respawns, nobody stuck forever.
        let t0 = Instant::now();
        for _ in 0..4 {
            assert_eq!(h.infer(data.xs.clone()).unwrap(), want);
        }
        assert!(t0.elapsed() < Duration::from_secs(5));
        let stats = h.pool_stats();
        assert!(stats.replicas.iter().all(|r| r.alive));
        assert_eq!(stats.replicas.iter().map(|r| r.respawns).sum::<u64>(), 0);
        h.shutdown();
        join.join();
    }

    #[test]
    fn autoscaler_grows_under_pressure_and_shrinks_when_idle() {
        let (model, data) = trained();
        let cfg = PoolConfig {
            replicas: 1,
            admission: AdmissionConfig::default(),
            autoscale: Some(AutoscaleConfig {
                min: 1,
                max: 3,
                interval: Duration::from_millis(10),
                depth_per_replica: 2,
                idle_ticks: 3,
            }),
        };
        let (h, mut join) = spawn_pool_cfg(EngineSpec::base(), cfg);
        h.program(model).unwrap();
        // Saturate the lone replica so queue depth builds up.
        let stall = h.inject_stall(Duration::from_millis(150)).unwrap();
        let clients: Vec<_> = (0..8)
            .map(|_| {
                let h = h.clone();
                let rows = data.xs[..16].to_vec();
                std::thread::spawn(move || h.infer(rows).unwrap())
            })
            .collect();
        let t0 = Instant::now();
        while h.admission_stats().scale_ups == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "no scale-up");
            std::thread::sleep(Duration::from_millis(5));
        }
        for c in clients {
            assert_eq!(c.join().unwrap().len(), 16);
        }
        stall.recv().unwrap().unwrap();
        // Idle again: the supervisor retires back toward min.
        let t0 = Instant::now();
        while h.admission_stats().scale_downs == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "no scale-down");
            std::thread::sleep(Duration::from_millis(5));
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn deadline_telemetry_and_canary_variants_work() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        // Idle pool: generous deadlines behave like the plain RPCs.
        let tel = h
            .infer_telemetry_deadline(data.xs.clone(), Duration::from_secs(30))
            .unwrap();
        assert_eq!(tel.preds.len(), data.len());
        h.program_canary(model).unwrap();
        let preds = h
            .infer_canary_deadline(data.xs.clone(), Duration::from_secs(30))
            .unwrap();
        assert_eq!(preds.len(), data.len());
        let tel = h
            .infer_telemetry_canary_deadline(data.xs.clone(), Duration::from_secs(30))
            .unwrap();
        assert_eq!(tel.preds.len(), data.len());
        h.dismiss_canary().unwrap();
        // With no canary, the deadline canary RPCs are typed errors.
        assert!(matches!(
            h.infer_canary_deadline(data.xs.clone(), Duration::from_millis(50)),
            Err(ServeError::Canary(_))
        ));
        h.shutdown();
        join.join();
    }
}
