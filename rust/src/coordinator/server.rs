//! Replica-pool request front-end: the AXIS/queue interface of the
//! deployed system scaled across N worker threads, each owning an
//! [`InferenceService`] replica, fed from one shared request queue
//! (offline toolchain has no tokio; std primitives give the same
//! shape: shared queue, condvars, message-passing replies).
//!
//! Properties the pool guarantees (EXPERIMENTS.md §Serving):
//!
//! * **Versioned broadcast reprogram.**  [`ServiceHandle::program`]
//!   publishes the model under a monotonically increasing version and
//!   blocks until *every* live replica has swapped (the version fence:
//!   each worker drains its in-flight request, swaps, then resumes).
//!   Once `program` returns, no later inference can observe an older
//!   model, and all replicas report the same version.
//! * **Panic supervision.**  A request that panics its worker does not
//!   kill the pool: the panic is caught, the failing request gets a
//!   typed [`ServeError::WorkerPanicked`], and the replica is rebuilt
//!   from its [`EngineSpec`] and reprogrammed from the last-programmed
//!   model before taking more work.  Counters survive the respawn.
//! * **Typed errors.**  Engine rejections ([`CoreError`], including
//!   the `BadBatch` malformed-request validation), worker panics and
//!   pool shutdown are distinct [`ServeError`] variants — no more
//!   opaque "service worker gone".
//! * **Aggregated metrics.**  [`ServiceHandle::pool_stats`] reports
//!   per-replica [`Metrics`] plus a pool rollup; [`ServiceHandle::stats`]
//!   keeps the old single-service shape (the rollup).

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::service::{EngineSpec, InferenceService, Metrics};
use crate::accel::core::CoreError;
use crate::tm::model::TMModel;

/// Snapshot returned by [`ServiceHandle::stats`] (the pool rollup).
pub type ServerStats = Metrics;

/// Errors a request can come back with.  Worker death, engine
/// rejection and shutdown are distinguishable, so a client can retry,
/// fix its request, or stop.
#[derive(Debug, thiserror::Error)]
pub enum ServeError {
    /// The engine rejected the request (malformed batch, model not
    /// programmed, model too big, …).  The replica is fine.
    #[error(transparent)]
    Core(#[from] CoreError),
    /// The replica serving this request panicked.  It has been rebuilt
    /// from the last-programmed model; retrying on the pool is safe.
    #[error("replica {replica} panicked serving this request (replica respawned)")]
    WorkerPanicked { replica: usize },
    /// The pool has been shut down; no further requests are accepted.
    #[error("service pool is shut down")]
    ShutDown,
    /// A worker dropped the reply without answering (worker death that
    /// supervision could not intercept).
    #[error("replica worker died without replying")]
    WorkerGone,
}

/// Per-replica snapshot inside [`PoolStats`].
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub metrics: Metrics,
    /// Last model version this replica acknowledged (see
    /// [`PoolStats::version`]).
    pub model_version: u64,
    /// Times this replica was rebuilt after a caught panic.
    pub respawns: u64,
    pub alive: bool,
}

/// Aggregated pool snapshot: per-replica metrics plus the rollup.
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub replicas: Vec<ReplicaStats>,
    /// Rollup across replicas: counters are summed; `reprograms` is the
    /// number of pool-level `program` broadcasts (not the per-replica
    /// sum — each broadcast reprograms every replica once).
    pub total: Metrics,
    /// Current target model version (bumped by every `program` call).
    pub version: u64,
}

/// One telemetry probe reply: predictions, per-datapoint confidence
/// margins (top-1 minus top-2 class sum), and the pool model version
/// the serving replica ran — the feed of the autotune monitor
/// ([`crate::coordinator::autotune`]).
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub preds: Vec<usize>,
    pub margins: Vec<i32>,
    /// Pool version fence value the replica had acknowledged when it
    /// served this probe.
    pub model_version: u64,
}

/// One queued unit of work.
enum Job {
    Infer {
        rows: Vec<Vec<u8>>,
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
    /// Inference plus the confidence-margin telemetry the drift monitor
    /// consumes.  Rides the same queue as plain requests — telemetry IS
    /// traffic, so the monitor observes exactly what clients do.
    Telemetry {
        rows: Vec<Vec<u8>>,
        reply: mpsc::Sender<Result<Telemetry, ServeError>>,
    },
    /// Fault injection: panic inside the owning worker.  Exercises the
    /// real supervision path (tests, chaos drills).
    Crash {
        reply: mpsc::Sender<Result<Vec<usize>, ServeError>>,
    },
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// The versioned model cell — the fence state.
struct ModelCell {
    /// Target version; bumped by every `program` broadcast.
    version: u64,
    /// Last-programmed model (what replicas swap to / respawn from).
    model: Option<Arc<TMModel>>,
    /// Per-replica acknowledged version (monotone).
    acks: Vec<u64>,
    /// Per-replica swap failure, tagged with the version it failed at.
    errors: Vec<Option<(u64, CoreError)>>,
    alive: Vec<bool>,
}

#[derive(Clone, Default)]
struct ReplicaMetrics {
    metrics: Metrics,
    respawns: u64,
}

struct Shared {
    queue: Mutex<QueueState>,
    /// Wakes workers: new job, shutdown, or a pending version fence.
    queue_cv: Condvar,
    cell: Mutex<ModelCell>,
    /// Wakes `program` callers waiting on replica acks.
    fence_cv: Condvar,
    /// Mirror of `cell.version`, readable without the cell lock (the
    /// workers' queue-wait loop polls it; never lock cell inside the
    /// queue lock).
    version: AtomicU64,
    metrics: Mutex<Vec<ReplicaMetrics>>,
    spec: EngineSpec,
}

/// Cloneable client handle to a running replica pool.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Shared>,
}

/// Joiner for the pool's worker threads.  `join` is idempotent: the
/// first call joins everything, later calls are no-ops.  Dropping the
/// joiner shuts the pool down (queued requests drain first) and joins.
pub struct PoolJoin {
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl PoolJoin {
    pub fn join(&mut self) {
        for h in self.workers.drain(..) {
            // Workers catch request panics themselves; a join error here
            // would mean supervision itself died, which Exit handling
            // already recorded in `alive`.
            let _ = h.join();
        }
    }
}

impl Drop for PoolJoin {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
            self.shared.queue_cv.notify_all();
        }
        self.join();
    }
}

/// Spawn a single-replica pool — the drop-in shape of the old
/// one-worker front-end.
pub fn spawn(spec: EngineSpec) -> (ServiceHandle, PoolJoin) {
    spawn_pool(spec, 1)
}

/// Spawn a pool of `replicas` workers, each owning one engine built
/// from `spec`, all fed from one shared FIFO request queue.
pub fn spawn_pool(spec: EngineSpec, replicas: usize) -> (ServiceHandle, PoolJoin) {
    let n = replicas.max(1);
    let shared = Arc::new(Shared {
        queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
        queue_cv: Condvar::new(),
        cell: Mutex::new(ModelCell {
            version: 0,
            model: None,
            acks: vec![0; n],
            errors: (0..n).map(|_| None).collect(),
            alive: vec![true; n],
        }),
        fence_cv: Condvar::new(),
        version: AtomicU64::new(0),
        metrics: Mutex::new(vec![ReplicaMetrics::default(); n]),
        spec,
    });
    let workers = (0..n)
        .map(|i| {
            let s = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("rttm-replica-{i}"))
                .spawn(move || worker_loop(&s, i))
                .expect("spawn replica worker")
        })
        .collect();
    let join = PoolJoin { workers, shared: Arc::clone(&shared) };
    (ServiceHandle { shared }, join)
}

impl ServiceHandle {
    /// Blocking inference RPC.  Any number of rows; the replica splits
    /// them into 32-lane batches through the bulk scheduler.
    pub fn infer(&self, rows: Vec<Vec<u8>>) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Infer { rows, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Blocking telemetry RPC: inference plus confidence margins and
    /// the serving replica's acknowledged model version.  The autotune
    /// monitor's probe path — it queues behind (and alongside) regular
    /// traffic on purpose.
    pub fn infer_telemetry(&self, rows: Vec<Vec<u8>>) -> Result<Telemetry, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Telemetry { rows, reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    /// Blocking reprogram RPC (the runtime-tuning path), broadcast to
    /// every replica behind the version fence: returns once all live
    /// replicas serve the new model.  A failed swap (e.g. model too big
    /// for the configured memories) leaves the failing replicas
    /// *unprogrammed* — never on a stale model — so the pool still
    /// cannot serve mixed versions.
    pub fn program(&self, model: TMModel) -> Result<(), ServeError> {
        let target = {
            let q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err(ServeError::ShutDown);
            }
            drop(q);
            let mut cell = self.shared.cell.lock().unwrap();
            cell.version += 1;
            cell.model = Some(Arc::new(model));
            // Publish under the cell lock so the mirror stays ordered.
            self.shared.version.store(cell.version, Ordering::Release);
            cell.version
        };
        // Wake parked workers so they observe the fence.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.queue_cv.notify_all();
        }
        // The fence: wait until every live replica acked `target`.
        let mut cell = self.shared.cell.lock().unwrap();
        loop {
            if !cell.alive.iter().any(|&a| a) {
                return Err(ServeError::ShutDown);
            }
            let done = cell
                .alive
                .iter()
                .zip(&cell.acks)
                .all(|(&alive, &acked)| !alive || acked >= target);
            if done {
                break;
            }
            cell = self.shared.fence_cv.wait(cell).unwrap();
        }
        // Surface a swap failure recorded for EXACTLY this broadcast.
        // Version targets are unique per program() call, so only this
        // caller can own a matching error; failures belonging to a
        // newer concurrent broadcast are left for that caller (a
        // superseded model returns Ok — the fence still guarantees no
        // replica serves anything older than it).  All replicas share
        // one config, so failures are uniform; the first recorded one
        // is representative.
        for slot in cell.errors.iter_mut() {
            if slot.as_ref().is_some_and(|(v, _)| *v == target) {
                let (_, err) = slot.take().expect("checked above");
                return Err(ServeError::Core(err));
            }
        }
        Ok(())
    }

    /// Pool rollup in the old single-service shape (counters summed,
    /// `reprograms` = number of `program` broadcasts).
    pub fn stats(&self) -> Result<ServerStats, ServeError> {
        Ok(self.pool_stats().total)
    }

    /// Full per-replica + rollup snapshot.
    pub fn pool_stats(&self) -> PoolStats {
        let (version, acks, alive) = {
            let cell = self.shared.cell.lock().unwrap();
            (cell.version, cell.acks.clone(), cell.alive.clone())
        };
        let per = self.shared.metrics.lock().unwrap();
        let replicas: Vec<ReplicaStats> = per
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                metrics: r.metrics.clone(),
                model_version: acks[i],
                respawns: r.respawns,
                alive: alive[i],
            })
            .collect();
        drop(per);
        let mut total = Metrics::default();
        for r in &replicas {
            total.inferences += r.metrics.inferences;
            total.batches += r.metrics.batches;
            total.simulated_cycles += r.metrics.simulated_cycles;
            total.errors += r.metrics.errors;
        }
        total.reprograms = version;
        PoolStats { replicas, total, version }
    }

    /// Ask the pool to stop.  Queued requests are drained first; new
    /// submissions are rejected with [`ServeError::ShutDown`].
    /// Idempotent.
    pub fn shutdown(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        q.shutdown = true;
        self.shared.queue_cv.notify_all();
    }

    /// Fault injection: make the replica that picks this request panic
    /// mid-request.  Returns the same typed error a real panic would,
    /// after supervision has respawned the replica.  For tests and
    /// chaos drills.
    #[doc(hidden)]
    pub fn inject_panic(&self) -> Result<Vec<usize>, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Crash { reply })?;
        rx.recv().map_err(|_| ServeError::WorkerGone)?
    }

    fn submit(&self, job: Job) -> Result<(), ServeError> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.shutdown {
            return Err(ServeError::ShutDown);
        }
        q.jobs.push_back(job);
        self.shared.queue_cv.notify_one();
        Ok(())
    }
}

/// What the queue wait resolved to.
enum Next {
    Work(Job),
    /// A newer model version is pending — swap before taking work.
    Resync,
    Exit,
}

/// Runs on every worker exit — normal return or a panic that escaped
/// `catch_unwind` (e.g. an invalid spec panicking in `build()`): marks
/// the replica dead and wakes fence waiters so `program` can never
/// hang on a corpse.  When the LAST replica dies, flips the pool to
/// shutdown and drops any parked jobs, so clients blocked on replies
/// get [`ServeError::WorkerGone`] instead of waiting forever.
struct DeathWatch<'a> {
    shared: &'a Shared,
    idx: usize,
}

impl Drop for DeathWatch<'_> {
    fn drop(&mut self) {
        let all_dead = {
            let mut cell = self.shared.cell.lock().unwrap();
            cell.alive[self.idx] = false;
            !cell.alive.iter().any(|&a| a)
        };
        self.shared.fence_cv.notify_all();
        if all_dead {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
            // Dropping a Job drops its reply Sender -> clients unblock.
            q.jobs.clear();
            self.shared.queue_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared, idx: usize) {
    let _watch = DeathWatch { shared, idx };
    let mut service = InferenceService::new(shared.spec.build());
    let mut my_version = 0u64;
    loop {
        // Fence check between requests: drain (we are between jobs),
        // swap, resume.
        if shared.version.load(Ordering::Acquire) != my_version {
            my_version = program_from_cell(shared, idx, &mut service);
        }
        let next = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Pending reprogram outranks new work: no job may start
                // on a stale replica once the fence is up.
                if shared.version.load(Ordering::Acquire) != my_version {
                    break Next::Resync;
                }
                if let Some(job) = q.jobs.pop_front() {
                    break Next::Work(job);
                }
                if q.shutdown {
                    break Next::Exit;
                }
                q = shared.queue_cv.wait(q).unwrap();
            }
        };
        match next {
            Next::Resync => continue,
            // DeathWatch marks the replica dead on the way out.
            Next::Exit => return,
            Next::Work(job) => run_job(shared, idx, &mut service, &mut my_version, job),
        }
    }
}

fn run_job(
    shared: &Shared,
    idx: usize,
    service: &mut InferenceService,
    my_version: &mut u64,
    job: Job,
) {
    match job {
        Job::Infer { rows, reply } => {
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| service.infer_all(&rows)));
            reply_or_respawn(shared, idx, service, my_version, outcome, reply);
        }
        Job::Telemetry { rows, reply } => {
            // Capture the fence version the request runs under BEFORE
            // the work: a panic respawn may advance `my_version`.
            let version = *my_version;
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                service.infer_with_margins(&rows).map(|(preds, margins)| Telemetry {
                    preds,
                    margins,
                    model_version: version,
                })
            }));
            reply_or_respawn(shared, idx, service, my_version, outcome, reply);
        }
        Job::Crash { reply } => {
            let outcome = panic::catch_unwind(AssertUnwindSafe(|| -> Result<Vec<usize>, CoreError> {
                panic!("injected fault (ServiceHandle::inject_panic)")
            }));
            reply_or_respawn(shared, idx, service, my_version, outcome, reply);
        }
    }
}

/// Shared tail of the per-request supervision protocol, for every job
/// flavour: on success, publish this replica's metrics BEFORE replying
/// (a client that got its answer always sees it reflected in
/// `stats()`); on a caught panic, respawn the replica and fail only
/// the offending request.
fn reply_or_respawn<T>(
    shared: &Shared,
    idx: usize,
    service: &mut InferenceService,
    my_version: &mut u64,
    outcome: std::thread::Result<Result<T, CoreError>>,
    reply: mpsc::Sender<Result<T, ServeError>>,
) {
    match outcome {
        Ok(result) => {
            shared.metrics.lock().unwrap()[idx].metrics = service.metrics.clone();
            let _ = reply.send(result.map_err(ServeError::Core));
        }
        Err(_panic) => {
            respawn_replica(shared, idx, service, my_version);
            let _ = reply.send(Err(ServeError::WorkerPanicked { replica: idx }));
        }
    }
}

/// Supervision: a panicking request may have left the replica in an
/// arbitrary state.  Rebuild the engine from the spec, carry the
/// counters over (plus the error), reprogram from the last-programmed
/// model, then let the caller fail only the offending request.
fn respawn_replica(
    shared: &Shared,
    idx: usize,
    service: &mut InferenceService,
    my_version: &mut u64,
) {
    let mut carried = service.metrics.clone();
    carried.errors += 1;
    *service = InferenceService::new(shared.spec.build());
    service.metrics = carried;
    {
        let mut per = shared.metrics.lock().unwrap();
        per[idx].respawns += 1;
        per[idx].metrics = service.metrics.clone();
    }
    *my_version = program_from_cell(shared, idx, service);
}

/// Swap `service` to the cell's current model and acknowledge the
/// version (the worker half of the fence).  Also the respawn path —
/// called with a freshly built engine, it re-installs the
/// last-programmed model.  Returns the version applied.
fn program_from_cell(shared: &Shared, idx: usize, service: &mut InferenceService) -> u64 {
    let (target, model) = {
        let cell = shared.cell.lock().unwrap();
        (cell.version, cell.model.clone())
    };
    // Program outside the lock: encoding + programming a large model is
    // the slow part, and siblings must be able to ack concurrently.
    let failure = match &model {
        Some(m) => match service.reprogram(m) {
            Ok(()) => None,
            Err(e) => {
                // A failed swap must not leave this replica on the
                // stale model: a single core keeps its old program
                // when the new one overflows instruction memory, and a
                // multi-core can stop half-programmed.  Rebuild the
                // engine unprogrammed (counters carried) so the
                // replica serves NotProgrammed, never version N-1.
                let carried = service.metrics.clone();
                *service = InferenceService::new(shared.spec.build());
                service.metrics = carried;
                Some(e)
            }
        },
        None => None,
    };
    // Keep the published per-replica metrics fresh (reprogram bumps a
    // counter outside the job path).
    shared.metrics.lock().unwrap()[idx].metrics = service.metrics.clone();
    let mut cell = shared.cell.lock().unwrap();
    if cell.acks[idx] < target {
        cell.acks[idx] = target;
        cell.errors[idx] = failure.map(|e| (target, e));
        shared.fence_cv.notify_all();
    }
    target
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).generate();
        (crate::trainer::train_model(&shape, &data, 4, 2), data)
    }

    #[test]
    fn rpc_roundtrip() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model.clone()).unwrap();
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.inferences, 96);
        assert_eq!(stats.reprograms, 1);
        h.shutdown();
        join.join();
    }

    #[test]
    fn infer_before_program_is_error_not_crash() {
        let (h, mut join) = spawn(EngineSpec::base());
        assert!(matches!(
            h.infer(vec![vec![0u8; 12]]),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn concurrent_clients_share_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 3);
        h.program(model).unwrap();
        let mut threads = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            let rows = data.xs.clone();
            threads.push(std::thread::spawn(move || h.infer(rows).unwrap().len()));
        }
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 4 * 96);
        assert_eq!(h.stats().unwrap().inferences, 4 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn reprogram_mid_serving_takes_effect() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();
        let drifted = SynthSpec::new(12, 3, 96).noise(0.05).seed(8).drift(0.4).generate();
        let shape = TMShape::synthetic(12, 3, 8);
        let new_model = crate::trainer::train_model(&shape, &drifted, 4, 3);
        h.program(new_model).unwrap();
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before.len(), after.len());
        let stats = h.pool_stats();
        assert_eq!(stats.version, 2);
        assert_eq!(stats.total.reprograms, 2);
        // The fence: both replicas on the new version once program() returned.
        for r in &stats.replicas {
            assert_eq!(r.model_version, 2);
        }
        h.shutdown();
        join.join();
    }

    #[test]
    fn telemetry_matches_single_service_and_reports_fence_version() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model.clone()).unwrap();

        let mut reference = InferenceService::new(EngineSpec::base().build());
        reference.reprogram(&model).unwrap();
        let (want_preds, want_margins) = reference.infer_with_margins(&data.xs).unwrap();

        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.preds, want_preds);
        assert_eq!(tel.margins, want_margins);
        assert_eq!(tel.model_version, 1);

        // Telemetry rides the version fence like any request.
        h.program(model).unwrap();
        let tel = h.infer_telemetry(data.xs.clone()).unwrap();
        assert_eq!(tel.model_version, 2);

        // Malformed telemetry probes are typed errors, not pool deaths.
        assert!(matches!(
            h.infer_telemetry(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        h.shutdown();
        join.join();
    }

    #[test]
    fn malformed_requests_do_not_kill_the_pool() {
        let (model, data) = trained();
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.program(model).unwrap();

        assert!(matches!(
            h.infer(Vec::new()),
            Err(ServeError::Core(CoreError::BadBatch { rows: 0, .. }))
        ));
        let ragged = vec![vec![0u8; 12], vec![0u8; 5]];
        assert!(matches!(
            h.infer(ragged),
            Err(ServeError::Core(CoreError::BadBatch { .. }))
        ));
        // The pool keeps serving on the same handle.
        let preds = h.infer(data.xs.clone()).unwrap();
        assert_eq!(preds.len(), data.len());
        let stats = h.stats().unwrap();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.inferences, 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn injected_panic_respawns_replica_and_pool_survives() {
        let (model, data) = trained();
        let (h, mut join) = spawn(EngineSpec::base());
        h.program(model).unwrap();
        let before = h.infer(data.xs.clone()).unwrap();

        match h.inject_panic() {
            Err(ServeError::WorkerPanicked { replica }) => assert_eq!(replica, 0),
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        // Same handle, same answers: the replica was respawned from the
        // last-programmed model.
        let after = h.infer(data.xs.clone()).unwrap();
        assert_eq!(before, after);
        let stats = h.pool_stats();
        assert_eq!(stats.replicas[0].respawns, 1);
        assert!(stats.replicas[0].alive);
        // The panic is visible as an error, and counters survived.
        assert_eq!(stats.total.errors, 1);
        assert_eq!(stats.total.inferences, 2 * 96);
        h.shutdown();
        join.join();
    }

    #[test]
    fn failed_swap_never_leaves_stale_or_mixed_models() {
        use crate::accel::core::AccelConfig;

        let (small, data) = trained();
        // A bigger model that cannot fit the instruction memory sized
        // exactly for the small one.
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 96).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        let n_small = crate::isa::instruction_count(&small);
        let n_big = crate::isa::instruction_count(&big);
        assert!(n_big > n_small, "test premise: {n_big} > {n_small}");

        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (h, mut join) = spawn_pool(spec, 2);
        h.program(small.clone()).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());

        // The too-big model must fail the swap as a typed error…
        assert!(matches!(h.program(big), Err(ServeError::Core(_))));
        // …and replicas must be unprogrammed — not stale on the old
        // model with the new version acked.
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::Core(CoreError::NotProgrammed))
        ));
        // A fitting reprogram fully recovers the pool.
        h.program(small).unwrap();
        assert_eq!(h.infer(data.xs.clone()).unwrap().len(), data.len());
        h.shutdown();
        join.join();
    }

    #[test]
    fn dead_pool_errors_instead_of_hanging() {
        use crate::accel::core::AccelConfig;
        use crate::accel::multicore::ParallelMode;

        // An invalid spec panics in build() at worker startup — outside
        // the per-request catch_unwind.  The DeathWatch must surface
        // this as errors, never as a hang.
        let bad = EngineSpec::Multi {
            cores: 0,
            per_core: AccelConfig::multicore_core(),
            parallel: ParallelMode::Auto,
        };
        let (h, mut join) = spawn_pool(bad, 2);
        join.join();
        let (model, data) = trained();
        assert!(matches!(h.program(model), Err(ServeError::ShutDown)));
        assert!(matches!(
            h.infer(data.xs.clone()),
            Err(ServeError::ShutDown) | Err(ServeError::WorkerGone)
        ));
    }

    #[test]
    fn shutdown_and_join_are_idempotent() {
        let (h, mut join) = spawn_pool(EngineSpec::base(), 2);
        h.shutdown();
        h.shutdown();
        join.join();
        join.join();
        assert!(matches!(h.infer(vec![vec![0u8; 4]]), Err(ServeError::ShutDown)));
        let (m, _) = trained();
        assert!(matches!(h.program(m), Err(ServeError::ShutDown)));
        // Stats still readable after shutdown (final reporting).
        assert_eq!(h.stats().unwrap().inferences, 0);
    }
}
