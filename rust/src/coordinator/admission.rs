//! Admission front-end policy for the replica-pool server: priority
//! classes, per-class bounded queues with backpressure policies,
//! deadline-aware admission, and the pool autoscaler/fault-injection
//! configuration.  (EXPERIMENTS.md §Admission.)
//!
//! This module owns the *policy* half of the front-end — what gets in,
//! what gets shed, and what the counters mean.  The *mechanics* (the
//! sharded per-replica work queues, work stealing, the worker pop loop)
//! live in [`super::server`], which consults these types at every
//! submit and pop:
//!
//! * [`Priority`] — four request classes, `Low < Normal < Critical`.
//!   Workers always pop the highest class first, so under overload the
//!   control plane (autotune telemetry at `High`, canary mirrors at
//!   `Critical`) keeps flowing while bulk `Low` traffic queues or sheds.
//! * [`ShedPolicy`] — what a full class queue does to a new submission:
//!   block until space, reject it (`ServeError::Overloaded`), or shed
//!   the oldest queued request of the same class to make room.
//! * [`ClassCounters`] / [`ClassStats`] — per-class accounting with a
//!   closed-form reconciliation invariant (see [`ClassStats`]): every
//!   submitted request is admitted or rejected, and every admitted
//!   request is served, shed, or still queued.
//! * [`ServiceEstimator`] — an EWMA of observed per-request service
//!   time; the submit path uses it to reject requests whose deadline
//!   cannot be met given current queue depth (deadline-aware admission:
//!   infeasible work is refused at submit, not discovered at pop).
//! * [`AutoscaleConfig`] — the supervisor policy scaling the pool
//!   between `min..=max` replicas from queue depth and deadline misses.
//! * [`FaultPlan`] — the generalized fault-injection surface (stall /
//!   panic-on-nth-job / drop-reply on a chosen replica) that overload
//!   and supervision tests share instead of hand-rolling failure modes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Number of priority classes (the length of every per-class array).
pub const PRIORITY_COUNT: usize = 4;

/// Request priority class.  Ordered: a worker looking for its next job
/// always drains higher classes first, across every queue shard it can
/// see, so `Critical` requests overtake queued `Low` ones everywhere.
///
/// The default for every pre-existing `ServiceHandle` RPC is `Normal`;
/// canary-targeted requests default to `Critical` (the mirrored
/// evaluation stream is control traffic — starving it under overload
/// would stall promote/reject verdicts exactly when they matter).
#[derive(Debug, Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    Normal,
    High,
    Critical,
}

impl Priority {
    /// All classes, lowest first (index order).
    pub const ALL: [Priority; PRIORITY_COUNT] =
        [Priority::Low, Priority::Normal, Priority::High, Priority::Critical];

    /// Stable index into per-class arrays (`Low = 0 … Critical = 3`).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
            Priority::Critical => "critical",
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Priority {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            "critical" => Ok(Priority::Critical),
            other => Err(format!(
                "unknown priority {other:?} (expected low|normal|high|critical)"
            )),
        }
    }
}

/// What a full class queue does to the next submission of that class.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Hold the submitting client until a slot frees up (or the pool
    /// shuts down).  The pre-admission behaviour for every class —
    /// nothing is ever refused, clients just wait.
    Block,
    /// Refuse the new submission with `ServeError::Overloaded`.  The
    /// client finds out immediately and can back off or downgrade.
    Reject,
    /// Evict the oldest queued request of the SAME class (its client
    /// gets `ServeError::Overloaded`) and admit the new one — freshest
    /// data wins, which is what a telemetry or sensor stream wants.
    ShedOldest,
}

impl std::fmt::Display for ShedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShedPolicy::Block => "block",
            ShedPolicy::Reject => "reject",
            ShedPolicy::ShedOldest => "shed-oldest",
        })
    }
}

impl std::str::FromStr for ShedPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "block" => Ok(ShedPolicy::Block),
            "reject" => Ok(ShedPolicy::Reject),
            "shed-oldest" | "shed_oldest" | "shedoldest" => Ok(ShedPolicy::ShedOldest),
            other => Err(format!(
                "unknown shed policy {other:?} (expected block|reject|shed-oldest)"
            )),
        }
    }
}

/// Per-class queue bounds and backpressure policies.
///
/// The default (`cap = 1024`, `Block` everywhere) reproduces the
/// pre-admission single-queue behaviour for every existing caller: no
/// request is ever refused, submitters just queue.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Per-class queue capacity, indexed by [`Priority::index`].  The
    /// bound is enforced at submit time; under concurrent submitters it
    /// is a soft cap (a handful of in-flight submissions may overshoot
    /// by one each — never unbounded).
    pub queue_cap: [usize; PRIORITY_COUNT],
    /// Per-class policy when the class queue is at capacity.
    pub policy: [ShedPolicy; PRIORITY_COUNT],
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            queue_cap: [1024; PRIORITY_COUNT],
            policy: [ShedPolicy::Block; PRIORITY_COUNT],
        }
    }
}

impl AdmissionConfig {
    /// The `rttm serve --queue-cap N --shed-policy P` shape: one cap
    /// for every class, `P` applied to the *data* classes (`Low`,
    /// `Normal`) while the control classes (`High`, `Critical`) always
    /// block — control traffic is delayed under overload, never shed.
    pub fn uniform(queue_cap: usize, data_policy: ShedPolicy) -> Self {
        AdmissionConfig {
            queue_cap: [queue_cap; PRIORITY_COUNT],
            policy: [data_policy, data_policy, ShedPolicy::Block, ShedPolicy::Block],
        }
    }

    pub fn cap(&self, p: Priority) -> usize {
        self.queue_cap[p.index()]
    }

    pub fn policy(&self, p: Priority) -> ShedPolicy {
        self.policy[p.index()]
    }

    pub fn validate(&self) -> Result<(), String> {
        for p in Priority::ALL {
            if self.cap(p) == 0 {
                return Err(format!("queue cap for class {p} must be >= 1"));
            }
        }
        Ok(())
    }
}

/// Snapshot of one class's admission counters.
///
/// Reconciliation invariants (the overload tests assert both):
///
/// * every submission is accounted exactly once at the front door:
///   `submitted_by_clients == admitted + rejected`;
/// * every admitted request is accounted exactly once at the back:
///   `admitted == served + shed + depth`.
///
/// `deadline_misses` overlaps the other counters (an infeasible-at-
/// submit request is also `rejected`; an expired-at-pop job is also
/// `shed`) — it answers "how often are deadlines missed", not "where
/// did the request go".
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClassStats {
    /// Requests currently queued (admitted, not yet popped).
    pub depth: u64,
    /// Requests accepted into a queue.
    pub admitted: u64,
    /// Requests refused at submit (`Overloaded` under the `Reject`
    /// policy, or `DeadlineExceeded` from deadline-aware admission).
    pub rejected: u64,
    /// Admitted requests dropped without execution: evicted by
    /// `ShedOldest`, expired at pop, or discarded at pool teardown.
    pub shed: u64,
    /// Admitted requests popped for execution.
    pub served: u64,
    /// Deadline misses: infeasible at submit plus expired at pop.
    pub deadline_misses: u64,
}

/// Lock-free per-class counters (the live half of [`ClassStats`]).
/// `depth` is maintained under the queue shard locks (increment before
/// push, decrement on removal), so it can never underflow.
#[derive(Debug, Default)]
pub struct ClassCounters {
    depth: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    served: AtomicU64,
    deadline_misses: AtomicU64,
}

impl ClassCounters {
    pub fn depth(&self) -> u64 {
        self.depth.load(Ordering::Acquire)
    }

    /// A request was accepted and enqueued (call before the push is
    /// visible to poppers).
    pub fn admit(&self) {
        self.admitted.fetch_add(1, Ordering::AcqRel);
        self.depth.fetch_add(1, Ordering::AcqRel);
    }

    /// A request was refused at submit under the `Reject` policy.
    pub fn reject_overloaded(&self) {
        self.rejected.fetch_add(1, Ordering::AcqRel);
    }

    /// A request was refused at submit because its deadline is
    /// infeasible at current queue depth.
    pub fn reject_deadline(&self) {
        self.rejected.fetch_add(1, Ordering::AcqRel);
        self.deadline_misses.fetch_add(1, Ordering::AcqRel);
    }

    /// A queued request was removed to be executed.
    pub fn pop_served(&self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
        self.served.fetch_add(1, Ordering::AcqRel);
    }

    /// A queued request was removed and dropped unexecuted (eviction,
    /// canary drain, pool teardown).
    pub fn pop_shed(&self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
        self.shed.fetch_add(1, Ordering::AcqRel);
    }

    /// A queued request was removed already past its deadline: shed
    /// unexecuted AND counted as a deadline miss.
    pub fn pop_expired(&self) {
        self.pop_shed();
        self.deadline_misses.fetch_add(1, Ordering::AcqRel);
    }

    /// A popped request expired between pop and execution (e.g. behind
    /// an injected stall): it was already counted `served`, so only the
    /// deadline miss is recorded — the reconciliation invariant holds.
    pub fn expire_in_service(&self) {
        self.deadline_misses.fetch_add(1, Ordering::AcqRel);
    }

    pub fn snapshot(&self) -> ClassStats {
        ClassStats {
            depth: self.depth.load(Ordering::Acquire),
            admitted: self.admitted.load(Ordering::Acquire),
            rejected: self.rejected.load(Ordering::Acquire),
            shed: self.shed.load(Ordering::Acquire),
            served: self.served.load(Ordering::Acquire),
            deadline_misses: self.deadline_misses.load(Ordering::Acquire),
        }
    }
}

/// Per-class admission snapshot plus supervisor activity, reported
/// inside `PoolStats`.
#[derive(Debug, Clone, Default)]
pub struct AdmissionStats {
    /// Indexed by [`Priority::index`].
    pub classes: [ClassStats; PRIORITY_COUNT],
    /// Replicas started by the autoscaling supervisor.
    pub scale_ups: u64,
    /// Replicas retired by the autoscaling supervisor.
    pub scale_downs: u64,
}

impl AdmissionStats {
    pub fn class(&self, p: Priority) -> &ClassStats {
        &self.classes[p.index()]
    }

    /// Total queued requests across all classes.
    pub fn depth_total(&self) -> u64 {
        self.classes.iter().map(|c| c.depth).sum()
    }

    /// Total requests that never executed (rejected at submit or shed
    /// after admission), across all classes.
    pub fn lost_total(&self) -> u64 {
        self.classes.iter().map(|c| c.rejected + c.shed).sum()
    }

    pub fn deadline_misses_total(&self) -> u64 {
        self.classes.iter().map(|c| c.deadline_misses).sum()
    }
}

/// Lock-free per-model admission counters: one [`ClassCounters`] per
/// priority class plus the sharding-switch count.  Every routed job
/// carries an `Arc<ModelCounters>` resolved once at submit, and every
/// site that touches the pool-wide counters mirrors the same transition
/// here — so the per-model arrays obey exactly the [`ClassStats`]
/// reconciliation invariants, model by model.
#[derive(Debug, Default)]
pub struct ModelCounters {
    /// Indexed by [`Priority::index`].
    pub classes: [ClassCounters; PRIORITY_COUNT],
    /// Replica self-reassignments TO this model under the `TimeShared`
    /// sharding policy (the reprogram-thrash metric's numerator).
    pub switches: AtomicU64,
}

impl ModelCounters {
    pub fn record_switch(&self) {
        self.switches.fetch_add(1, Ordering::AcqRel);
    }

    pub fn snapshot(&self) -> [ClassStats; PRIORITY_COUNT] {
        [
            self.classes[0].snapshot(),
            self.classes[1].snapshot(),
            self.classes[2].snapshot(),
            self.classes[3].snapshot(),
        ]
    }
}

/// One model's admission/serving rollup, reported inside `PoolStats`
/// and by `ServiceHandle::model_stats`.
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub id: super::registry::ModelId,
    /// Registered deployment name, or `m<id>` for routes that carried
    /// traffic without ever being registered.
    pub name: String,
    /// Indexed by [`Priority::index`]; each class reconciles on its own
    /// (see [`ClassStats`]).
    pub classes: [ClassStats; PRIORITY_COUNT],
    /// Replica self-reassignments to this model (`TimeShared` thrash).
    pub switches: u64,
}

impl ModelStats {
    pub fn class(&self, p: Priority) -> &ClassStats {
        &self.classes[p.index()]
    }

    pub fn submitted(&self) -> u64 {
        self.classes.iter().map(|c| c.admitted + c.rejected).sum()
    }

    pub fn admitted(&self) -> u64 {
        self.classes.iter().map(|c| c.admitted).sum()
    }

    pub fn rejected(&self) -> u64 {
        self.classes.iter().map(|c| c.rejected).sum()
    }

    pub fn served(&self) -> u64 {
        self.classes.iter().map(|c| c.served).sum()
    }

    pub fn shed(&self) -> u64 {
        self.classes.iter().map(|c| c.shed).sum()
    }

    pub fn depth(&self) -> u64 {
        self.classes.iter().map(|c| c.depth).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.classes.iter().map(|c| c.deadline_misses).sum()
    }
}

/// EWMA of observed per-request service time, feeding deadline-aware
/// admission: a request whose projected queue wait already exceeds its
/// deadline is refused at submit.
///
/// The estimate starts at zero ("unknown"), in which case admission
/// never rejects on feasibility — the estimator only gains authority
/// after real requests have been timed, and a long idle gap never makes
/// it MORE aggressive.  The projection is deliberately conservative
/// (it ignores work-stealing overlap and counts only same-or-higher
/// class work ahead), so borderline requests are admitted and left to
/// the pop-side expiry shed.
#[derive(Debug, Default)]
pub struct ServiceEstimator {
    /// EWMA of request service time in microseconds (alpha = 1/8);
    /// zero means "no observation yet".
    est_us: AtomicU64,
}

impl ServiceEstimator {
    /// Fold one observed request service time into the EWMA.
    ///
    /// CAS loop, not load→store: with N workers finishing requests
    /// concurrently, racing plain stores overwrite each other and the
    /// estimate can stall on one worker's stale value under exactly the
    /// load where deadline admission needs it.  `fetch_update` retries
    /// against the freshest value, so every sample lands.
    pub fn observe(&self, service: Duration) {
        let sample = service.as_micros().min(u64::MAX as u128) as u64;
        let _ = self
            .est_us
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |old| {
                let new = if old == 0 { sample } else { old - old / 8 + sample / 8 };
                Some(new.max(1))
            });
    }

    /// Current estimate, `None` until the first observation.
    pub fn estimate(&self) -> Option<Duration> {
        match self.est_us.load(Ordering::Acquire) {
            0 => None,
            us => Some(Duration::from_micros(us)),
        }
    }

    /// Projected queue wait for a request with `ahead` same-or-higher
    /// class requests queued in front of it on a pool of `replicas`
    /// live workers.  `None` while the estimator has no data.
    pub fn projected_wait(&self, ahead: u64, replicas: usize) -> Option<Duration> {
        let est = self.est_us.load(Ordering::Acquire);
        if est == 0 {
            return None;
        }
        let us = est.saturating_mul(ahead) / replicas.max(1) as u64;
        Some(Duration::from_micros(us))
    }
}

/// Supervisor policy: autoscale the live replica count between
/// `min..=max` from observed queue depth and deadline misses.
///
/// Scale **up** one replica when total queue depth exceeds
/// `depth_per_replica * live` or any deadline miss was recorded in the
/// last interval.  Scale **down** one replica (never the canary, never
/// below `min`) after `idle_ticks` consecutive intervals with an empty
/// queue and no misses.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    pub min: usize,
    pub max: usize,
    /// Supervisor sampling interval.
    pub interval: Duration,
    /// Queue depth per live replica that triggers a scale-up.
    pub depth_per_replica: usize,
    /// Consecutive idle intervals before one replica is retired.
    pub idle_ticks: u32,
}

impl AutoscaleConfig {
    pub fn new(min: usize, max: usize) -> Self {
        AutoscaleConfig {
            min,
            max,
            interval: Duration::from_millis(25),
            depth_per_replica: 4,
            idle_ticks: 8,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.min == 0 {
            return Err("autoscale min must be >= 1".into());
        }
        if self.min > self.max {
            return Err(format!(
                "autoscale min {} must be <= max {}",
                self.min, self.max
            ));
        }
        if self.interval.is_zero() {
            return Err("autoscale interval must be > 0".into());
        }
        Ok(())
    }
}

/// Self-healing integrity policy: model-memory scrubbing plus the
/// per-replica flap circuit breaker (EXPERIMENTS.md §Integrity).
///
/// Scrubbing treats the registry's golden model `Arc` as the single
/// point of truth: each replica records an FNV-1a digest of its derived
/// program buffers at fence time, re-verifies it before serving and on
/// every background scrub tick, and re-derives from the golden copy on
/// mismatch.  The breaker quarantines a replica that keeps tripping
/// (panic respawns, scrub corruptions, failed heals) with exponential
/// backoff; a half-open probe gates rejoin.
#[derive(Debug, Clone)]
pub struct IntegrityConfig {
    /// Background scrub cadence.  `None` disables the integrity layer
    /// entirely (no digests recorded, no pre-serve verify, no scrubber
    /// thread) — the zero-overhead default.
    pub scrub_interval: Option<Duration>,
    /// Trips inside `breaker_window` that quarantine a replica.
    pub breaker_trips: u32,
    /// Sliding window over which trips are counted.
    pub breaker_window: Duration,
    /// First quarantine hold; doubles per consecutive quarantine.
    pub quarantine_base: Duration,
    /// Backoff ceiling for the exponential quarantine hold.
    pub quarantine_max: Duration,
}

impl Default for IntegrityConfig {
    fn default() -> Self {
        IntegrityConfig {
            scrub_interval: None,
            breaker_trips: 3,
            breaker_window: Duration::from_secs(10),
            quarantine_base: Duration::from_millis(50),
            quarantine_max: Duration::from_secs(5),
        }
    }
}

impl IntegrityConfig {
    /// Scrubbing on at `interval`, default breaker policy.
    pub fn scrubbed(interval: Duration) -> Self {
        IntegrityConfig { scrub_interval: Some(interval), ..IntegrityConfig::default() }
    }

    pub fn validate(&self) -> Result<(), String> {
        if let Some(iv) = self.scrub_interval {
            if iv.is_zero() {
                return Err("scrub interval must be > 0 (or None to disable)".into());
            }
        }
        if self.breaker_trips == 0 {
            return Err("breaker trip threshold must be >= 1".into());
        }
        if self.breaker_window.is_zero() || self.quarantine_base.is_zero() {
            return Err("breaker window and quarantine base must be > 0".into());
        }
        Ok(())
    }
}

/// Pool-wide integrity counters snapshot, reported inside `PoolStats`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IntegrityStats {
    /// Digest verifications performed (background ticks + pre-serve).
    pub scrubs: u64,
    /// Verifications whose recomputed digest differed from the fence
    /// record — silent model-memory corruption caught in the act.
    pub corruptions_detected: u64,
    /// Corrupted replicas re-derived from the golden model `Arc` and
    /// re-verified clean.
    pub heals: u64,
    /// Heal attempts that could not restore a clean digest (no golden
    /// copy, program error, or still-dirty re-verify) — these trip the
    /// circuit breaker.
    pub failed_heals: u64,
    /// Replicas moved to `Quarantined` by the flap breaker.
    pub quarantines: u64,
    /// Quarantined replicas readmitted through the half-open probe.
    pub rejoins: u64,
}

/// Lock-free live half of [`IntegrityStats`].
#[derive(Debug, Default)]
pub struct IntegrityCounters {
    pub scrubs: AtomicU64,
    pub corruptions_detected: AtomicU64,
    pub heals: AtomicU64,
    pub failed_heals: AtomicU64,
    pub quarantines: AtomicU64,
    pub rejoins: AtomicU64,
}

impl IntegrityCounters {
    pub fn snapshot(&self) -> IntegrityStats {
        IntegrityStats {
            scrubs: self.scrubs.load(Ordering::Acquire),
            corruptions_detected: self.corruptions_detected.load(Ordering::Acquire),
            heals: self.heals.load(Ordering::Acquire),
            failed_heals: self.failed_heals.load(Ordering::Acquire),
            quarantines: self.quarantines.load(Ordering::Acquire),
            rejoins: self.rejoins.load(Ordering::Acquire),
        }
    }
}

/// Full pool configuration: initial replica count, admission policy,
/// the self-healing integrity layer, and (optionally) the autoscaling
/// supervisor.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Initial replica count (clamped into the autoscale range when a
    /// supervisor is configured).
    pub replicas: usize,
    pub admission: AdmissionConfig,
    pub autoscale: Option<AutoscaleConfig>,
    pub integrity: IntegrityConfig,
}

impl PoolConfig {
    /// A fixed-size pool with default (block-everywhere) admission —
    /// the `spawn_pool(spec, n)` shape.
    pub fn fixed(replicas: usize) -> Self {
        PoolConfig {
            replicas,
            admission: AdmissionConfig::default(),
            autoscale: None,
            integrity: IntegrityConfig::default(),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        self.admission.validate()?;
        if let Some(a) = &self.autoscale {
            a.validate()?;
        }
        self.integrity.validate()?;
        Ok(())
    }
}

/// One injected fault, armed against a chosen replica.
#[derive(Debug, Copy, Clone)]
pub enum Fault {
    /// Sleep for the duration before executing the replica's next job
    /// (the deterministic "one replica wedged" saturation).
    Stall(Duration),
    /// Panic inside the replica's `nth` next job (1 = the very next),
    /// exercising the real catch-unwind/respawn supervision path.
    PanicOnJob { nth: u64 },
    /// Drop the replica's next job without replying — the client
    /// observes `WorkerGone`, the supervision blind spot every caller
    /// must tolerate.
    DropReply,
    /// Flip `n_bits` pseudo-random bits (seeded, reproducible) in the
    /// replica's own derived-program buffers — never the golden model
    /// `Arc` — simulating an SEU / torn reprogram in model memory.
    /// Detected by the scrub layer, healed from the golden copy.
    FlipModelBits { seed: u64, n_bits: u32 },
}

/// A fault armed against one replica.  Replaces the ad-hoc
/// `inject_stall`-style hooks: tests compose stall / panic-on-nth-job /
/// drop-reply against any replica through one surface
/// (`ServiceHandle::inject_fault`).
#[derive(Debug, Copy, Clone)]
pub struct FaultPlan {
    pub replica: usize,
    pub fault: Fault,
}

impl FaultPlan {
    pub fn stall(replica: usize, dur: Duration) -> Self {
        FaultPlan { replica, fault: Fault::Stall(dur) }
    }

    pub fn panic_on_job(replica: usize, nth: u64) -> Self {
        FaultPlan { replica, fault: Fault::PanicOnJob { nth: nth.max(1) } }
    }

    pub fn drop_reply(replica: usize) -> Self {
        FaultPlan { replica, fault: Fault::DropReply }
    }

    pub fn flip_model_bits(replica: usize, seed: u64, n_bits: u32) -> Self {
        FaultPlan { replica, fault: Fault::FlipModelBits { seed, n_bits: n_bits.max(1) } }
    }
}

/// Armed faults, polled by workers once per popped job.  At most a
/// handful are ever armed (tests), so a single mutex-guarded vec is
/// plenty and keeps the job hot path to one uncontended lock when the
/// armory is empty — guarded by a lock-free emptiness check.
#[derive(Debug, Default)]
pub struct FaultArmory {
    armed: Mutex<Vec<FaultPlan>>,
    count: AtomicU64,
}

impl FaultArmory {
    /// Arm a fault against a replica.  Multiple faults may be armed
    /// (even against the same replica); each triggers once, in arming
    /// order.
    pub fn arm(&self, plan: FaultPlan) {
        // Poison-tolerant like every pool-internal lock: a worker
        // panicking mid-poll must not wedge fault arming.
        self.armed.lock().unwrap_or_else(|p| p.into_inner()).push(plan);
        self.count.fetch_add(1, Ordering::AcqRel);
    }

    /// Called by worker `replica` for each job it pops.  Returns the
    /// fault to apply to THIS job, if any.  `PanicOnJob` counts down
    /// across calls and fires when its countdown reaches zero.
    pub fn poll(&self, replica: usize) -> Option<Fault> {
        if self.count.load(Ordering::Acquire) == 0 {
            return None;
        }
        let mut armed = self.armed.lock().unwrap_or_else(|p| p.into_inner());
        let slot = armed.iter().position(|p| p.replica == replica)?;
        match &mut armed[slot].fault {
            Fault::PanicOnJob { nth } if *nth > 1 => {
                *nth -= 1;
                None
            }
            _ => {
                let plan = armed.remove(slot);
                self.count.fetch_sub(1, Ordering::AcqRel);
                Some(plan.fault)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priorities_are_ordered_and_indexed() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert!(Priority::High < Priority::Critical);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
            // Round-trip through the CLI spelling.
            assert_eq!(p.name().parse::<Priority>().unwrap(), *p);
        }
        assert!("urgent".parse::<Priority>().is_err());
    }

    #[test]
    fn shed_policy_parses_cli_spellings() {
        assert_eq!("block".parse::<ShedPolicy>().unwrap(), ShedPolicy::Block);
        assert_eq!("reject".parse::<ShedPolicy>().unwrap(), ShedPolicy::Reject);
        assert_eq!(
            "shed-oldest".parse::<ShedPolicy>().unwrap(),
            ShedPolicy::ShedOldest
        );
        assert!("drop".parse::<ShedPolicy>().is_err());
    }

    #[test]
    fn uniform_config_shields_control_classes() {
        let cfg = AdmissionConfig::uniform(8, ShedPolicy::Reject);
        assert_eq!(cfg.policy(Priority::Low), ShedPolicy::Reject);
        assert_eq!(cfg.policy(Priority::Normal), ShedPolicy::Reject);
        assert_eq!(cfg.policy(Priority::High), ShedPolicy::Block);
        assert_eq!(cfg.policy(Priority::Critical), ShedPolicy::Block);
        assert!(cfg.validate().is_ok());
        let mut bad = cfg;
        bad.queue_cap[0] = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn counters_reconcile() {
        let c = ClassCounters::default();
        for _ in 0..10 {
            c.admit();
        }
        for _ in 0..3 {
            c.reject_overloaded();
        }
        c.reject_deadline();
        for _ in 0..6 {
            c.pop_served();
        }
        c.pop_shed();
        c.pop_expired();
        let s = c.snapshot();
        // Front door: submitted (14) == admitted + rejected.
        assert_eq!(s.admitted + s.rejected, 14);
        // Back door: admitted == served + shed + depth.
        assert_eq!(s.admitted, s.served + s.shed + s.depth);
        assert_eq!(s.depth, 2);
        assert_eq!(s.deadline_misses, 2);
    }

    #[test]
    fn model_counters_reconcile_per_class() {
        let m = ModelCounters::default();
        let hi = Priority::High.index();
        let lo = Priority::Low.index();
        for _ in 0..5 {
            m.classes[hi].admit();
        }
        m.classes[hi].pop_served();
        m.classes[hi].pop_expired();
        m.classes[lo].admit();
        m.classes[lo].reject_overloaded();
        m.record_switch();
        let snap = ModelStats {
            id: super::super::registry::ModelId(3),
            name: "t".into(),
            classes: m.snapshot(),
            switches: m.switches.load(Ordering::Acquire),
        };
        assert_eq!(snap.submitted(), 7);
        assert_eq!(snap.admitted(), snap.served() + snap.shed() + snap.depth());
        assert_eq!(snap.class(Priority::High).depth, 3);
        assert_eq!(snap.deadline_misses(), 1);
        assert_eq!(snap.switches, 1);
        assert_eq!(snap.id.to_string(), "m3");
    }

    #[test]
    fn estimator_warms_up_then_projects() {
        let e = ServiceEstimator::default();
        assert!(e.estimate().is_none());
        assert!(e.projected_wait(100, 1).is_none(), "no authority before data");
        e.observe(Duration::from_micros(800));
        let first = e.estimate().unwrap();
        assert_eq!(first, Duration::from_micros(800), "first sample adopted whole");
        // EWMA pulls toward later samples without jumping.
        for _ in 0..64 {
            e.observe(Duration::from_micros(1600));
        }
        let settled = e.estimate().unwrap();
        assert!(settled > first && settled <= Duration::from_micros(1601));
        // Ten requests ahead on two replicas ≈ five service times.
        let wait = e.projected_wait(10, 2).unwrap();
        assert!(wait >= Duration::from_micros(4000));
        assert_eq!(e.projected_wait(0, 2).unwrap(), Duration::ZERO);
    }

    #[test]
    fn estimator_concurrent_observes_are_never_lost() {
        // Regression for the load→compute→store race: warm the EWMA on
        // a low value, then hammer it from N threads with a much higher
        // one.  Every sample pulls the estimate up by at least 1/8 of
        // the remaining gap, so after THREADS x PER_THREAD folded
        // samples the estimate must sit essentially at the new level;
        // with racing plain stores, overwritten updates routinely leave
        // it far below.  Single alpha=1/8 step from 100us toward
        // 100_000us ≈ 12_587us — reaching >= 90_000us needs ~17
        // *applied* samples, far fewer than the 1024 issued.
        let e = std::sync::Arc::new(ServiceEstimator::default());
        e.observe(Duration::from_micros(100));
        assert_eq!(e.estimate().unwrap(), Duration::from_micros(100));
        const THREADS: usize = 8;
        const PER_THREAD: usize = 128;
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                let e = std::sync::Arc::clone(&e);
                scope.spawn(move || {
                    for _ in 0..PER_THREAD {
                        e.observe(Duration::from_micros(100_000));
                    }
                });
            }
        });
        let settled = e.estimate().unwrap();
        assert!(
            settled >= Duration::from_micros(90_000),
            "estimate stalled at {settled:?}: concurrent observes were lost"
        );
        assert!(settled <= Duration::from_micros(100_000));
    }

    #[test]
    fn fault_armory_counts_down_and_fires_once() {
        let a = FaultArmory::default();
        assert!(a.poll(0).is_none());
        a.arm(FaultPlan::panic_on_job(1, 3));
        a.arm(FaultPlan::drop_reply(0));
        // Replica 0: fires immediately, exactly once.
        assert!(matches!(a.poll(0), Some(Fault::DropReply)));
        assert!(a.poll(0).is_none());
        // Replica 1: two jobs pass, the third panics.
        assert!(a.poll(1).is_none());
        assert!(a.poll(1).is_none());
        assert!(matches!(a.poll(1), Some(Fault::PanicOnJob { .. })));
        assert!(a.poll(1).is_none());
    }
}
