//! The Model Training Node + recalibration loop (Fig 8).
//!
//! Deployment story the paper proposes: the accelerator serves inference
//! from edge-sensor data; a local training node keeps a labeled,
//! *updating* dataset (sensor readings shift with aging/temperature/
//! humidity [13]); when monitored accuracy drops below a threshold the
//! node retrains and reprograms the accelerator over the stream — no
//! synthesis tools anywhere (the paper's key contrast with MATADOR/
//! FINN/hls4ml/PolyLUT).
//!
//! Two interchangeable training backends:
//! * [`TrainBackend::Pjrt`] — the AOT-compiled JAX train step executed
//!   through the PJRT runtime (the default; exercises all three layers).
//! * [`TrainBackend::Native`] — the pure-rust trainer (used when no
//!   artifacts are available, and for cross-checking).

use crate::config::TMShape;
use crate::datasets::synth::Dataset;
use crate::runtime::TrainExecutable;
use crate::tm::model::TMModel;

use super::service::InferenceService;

/// Where the training node's compute runs.
pub enum TrainBackend {
    Pjrt(TrainExecutable),
    Native,
}

/// The local training node.
pub struct TrainingNode {
    pub shape: TMShape,
    pub backend: TrainBackend,
    pub epochs: usize,
    pub seed: u64,
}

impl TrainingNode {
    pub fn native(shape: TMShape) -> Self {
        TrainingNode { shape, backend: TrainBackend::Native, epochs: 6, seed: 7 }
    }

    pub fn pjrt(shape: TMShape, exe: TrainExecutable) -> Self {
        TrainingNode { shape, backend: TrainBackend::Pjrt(exe), epochs: 6, seed: 7 }
    }

    /// Train a fresh model on the node's current dataset.
    pub fn retrain(&self, data: &Dataset) -> anyhow::Result<TMModel> {
        match &self.backend {
            TrainBackend::Native => {
                Ok(crate::trainer::train_model(&self.shape, data, self.epochs, self.seed))
            }
            TrainBackend::Pjrt(exe) => {
                let ta = exe.fit(&data.xs, &data.ys, self.epochs, self.seed)?;
                Ok(exe.model_from_states(&ta))
            }
        }
    }
}

/// One recalibration decision record.
#[derive(Debug, Clone)]
pub struct RecalEvent {
    pub step: usize,
    pub accuracy_before: f64,
    pub accuracy_after: f64,
    pub instruction_count: usize,
}

/// Report of a monitored deployment window.
#[derive(Debug, Clone, Default)]
pub struct RecalReport {
    /// (step, accuracy) trace of the monitor probes.
    pub probes: Vec<(usize, f64)>,
    pub recalibrations: Vec<RecalEvent>,
}

/// Drift monitor + retune policy — the *offline* compatibility shape.
///
/// Since the autotune subsystem landed this is a thin wrapper over the
/// shared policy core ([`crate::coordinator::autotune::DriftDetector`]
/// with `patience = 1`, fixed shape, no budget): same decisions as the
/// original Fig 8 loop, but the drift judgment itself lives in one
/// place.  For serving-scale deployments use
/// [`crate::coordinator::autotune::Autotuner`], which runs the same
/// policy live against the replica pool with hysteresis, a
/// budget-constrained shape search and rollback.
pub struct RecalibrationLoop {
    pub node: TrainingNode,
    /// Reprogram when probe accuracy falls below this.
    pub threshold: f64,
}

impl RecalibrationLoop {
    pub fn new(node: TrainingNode, threshold: f64) -> Self {
        RecalibrationLoop { node, threshold }
    }

    /// Drive one monitored deployment: at each step the service classifies
    /// the step's probe set; if accuracy < threshold, the node retrains
    /// on that step's (drifted) data and live-reprograms the accelerator.
    ///
    /// `windows` yields (probe dataset, retrain dataset) per step —
    /// in the field both come from the same labeled trickle.
    pub fn run(
        &self,
        service: &mut InferenceService,
        windows: &[(Dataset, Dataset)],
    ) -> anyhow::Result<RecalReport> {
        let mut report = RecalReport::default();
        // Patience-1 detector == the original `acc < threshold` check;
        // the offline loop has no margin telemetry, so the label-free
        // signal stays dormant (margin 0 never beats a 0 baseline).
        let mut detector = crate::coordinator::autotune::DriftDetector::new(self.threshold, 1);
        for (step, (probe, retrain)) in windows.iter().enumerate() {
            let acc = service.measure_accuracy(&probe.xs, &probe.ys)?;
            report.probes.push((step, acc));
            if detector.push(Some(acc), 0.0) {
                let model = self.node.retrain(retrain)?;
                service.reprogram(&model)?;
                // Post-recalibration accuracy lives ONLY in the
                // RecalEvent: pushing it into `probes` as well would
                // duplicate the step index in the monitor trace.
                let after = service.measure_accuracy(&probe.xs, &probe.ys)?;
                report.recalibrations.push(RecalEvent {
                    step,
                    accuracy_before: acc,
                    accuracy_after: after,
                    instruction_count: crate::isa::instruction_count(&model),
                });
                detector.reset();
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::Engine;
    use crate::datasets::synth::SynthSpec;

    fn shape() -> TMShape {
        TMShape::synthetic(16, 2, 10)
    }

    fn dataset(drift: f64, n: usize) -> Dataset {
        SynthSpec::new(16, 2, n).noise(0.05).seed(7).drift(drift).generate()
    }

    #[test]
    fn native_node_trains_working_model() {
        let node = TrainingNode::native(shape());
        let data = dataset(0.0, 512);
        let model = node.retrain(&data).unwrap();
        let acc = crate::tm::reference::accuracy(&model, &data.xs, &data.ys);
        assert!(acc > 0.9, "acc {acc}");
    }

    #[test]
    fn recalibration_recovers_from_drift() {
        // Train clean, deploy, drift arrives, loop must detect + recover.
        let node = TrainingNode::native(shape());
        let clean = dataset(0.0, 512);
        let drifted = dataset(0.35, 512);

        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&node.retrain(&clean).unwrap()).unwrap();

        let looped = RecalibrationLoop::new(node, 0.85);
        let windows = vec![
            (clean.clone(), clean.clone()),
            (drifted.clone(), drifted.clone()),
        ];
        let report = looped.run(&mut svc, &windows).unwrap();

        assert_eq!(report.recalibrations.len(), 1, "exactly the drift step retunes");
        let ev = &report.recalibrations[0];
        assert!(ev.accuracy_before < 0.85);
        assert!(
            ev.accuracy_after > ev.accuracy_before + 0.1,
            "no recovery: {} -> {}",
            ev.accuracy_before,
            ev.accuracy_after
        );
        assert_eq!(svc.metrics.reprograms, 2); // initial + recalibration
    }

    #[test]
    fn probe_trace_has_one_entry_per_step() {
        // Regression: a recalibrating step used to push a second
        // (step, accuracy_after) tuple into the probe trace.
        let node = TrainingNode::native(shape());
        let clean = dataset(0.0, 512);
        let drifted = dataset(0.35, 512);
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&node.retrain(&clean).unwrap()).unwrap();
        let looped = RecalibrationLoop::new(node, 0.85);
        let windows = vec![
            (clean.clone(), clean.clone()),
            (drifted.clone(), drifted.clone()),
            (drifted.clone(), drifted.clone()),
        ];
        let report = looped.run(&mut svc, &windows).unwrap();
        assert!(!report.recalibrations.is_empty(), "drift step must retune");
        assert_eq!(report.probes.len(), windows.len());
        for (i, &(step, _)) in report.probes.iter().enumerate() {
            assert_eq!(step, i, "exactly one probe entry per step, in order");
        }
        // Post-recal accuracy is still recorded — in the event.
        for ev in &report.recalibrations {
            assert!(ev.accuracy_after > 0.0);
        }
    }

    #[test]
    fn healthy_deployment_never_reprograms() {
        let node = TrainingNode::native(shape());
        let clean = dataset(0.0, 256);
        let mut svc = InferenceService::new(Engine::base());
        svc.reprogram(&node.retrain(&clean).unwrap()).unwrap();
        let looped = RecalibrationLoop::new(node, 0.80);
        let windows = vec![(clean.clone(), clean.clone()); 3];
        let report = looped.run(&mut svc, &windows).unwrap();
        assert!(report.recalibrations.is_empty());
        assert_eq!(svc.metrics.reprograms, 1);
    }
}
