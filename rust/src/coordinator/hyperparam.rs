//! Hyperparameter search on the training node (paper §3: "Users can
//! also run a hyperparameter search to update the architecture if
//! needed"; [21] highlights the TM's small search space — only T and s,
//! plus the clause budget).
//!
//! Grid search with a held-out split, pruned by an accuracy floor; the
//! scoring penalizes model size lightly so the search prefers smaller
//! instruction streams at equal accuracy (they are faster on the
//! accelerator — latency is linear in instructions).

use crate::config::TMShape;
use crate::datasets::synth::Dataset;
use crate::model_cost::energy::EnergyModel;
use crate::model_cost::resources::{
    compressed_model_bytes, estimate, fitted_config, ResourceBudget, ResourceEstimate,
};
use crate::tm::model::TMModel;
use crate::tm::reference;

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct Trial {
    pub t: i32,
    pub s: f64,
    pub clauses: usize,
    pub accuracy: f64,
    pub instructions: usize,
    pub score: f64,
}

/// Search configuration.
pub struct SearchSpace {
    pub t_grid: Vec<i32>,
    pub s_grid: Vec<f64>,
    pub clause_grid: Vec<usize>,
    /// TA memory depths (`n_states`) to sweep.  Depth sets the
    /// include/exclude hysteresis of training: shallow memories commit
    /// and un-commit literals quickly (fast adaptation, noisier
    /// clauses), deep ones are stable but slow to re-learn after drift.
    /// The right depth is workload-dependent, so the search sweeps it
    /// like T and s instead of inheriting the deployed value.
    pub n_states_grid: Vec<i32>,
    pub epochs: usize,
    pub seed: u64,
    /// Score = accuracy - size_weight * (instructions / total TAs).
    pub size_weight: f64,
}

impl SearchSpace {
    /// A small default grid around a base shape.
    pub fn around(shape: &TMShape) -> Self {
        let c = shape.clauses;
        SearchSpace {
            t_grid: vec![shape.t / 2, shape.t, shape.t * 2]
                .into_iter()
                .filter(|&t| t >= 1)
                .collect(),
            s_grid: vec![shape.s * 0.5, shape.s, shape.s * 2.0],
            clause_grid: vec![c / 2, c].into_iter().filter(|&v| v >= 2).collect(),
            n_states_grid: vec![shape.n_states / 2, shape.n_states]
                .into_iter()
                .filter(|&n| n >= 2)
                .collect(),
            epochs: 3,
            seed: 17,
            size_weight: 0.05,
        }
    }
}

/// Shared candidate enumeration for [`grid_search`] and
/// [`budget_search`]: one walk of the clause/T/s grid (one
/// T-attainability filter), one training + evaluation per point — the
/// two searches differ only in scoring/selection, so they must never
/// drift apart on WHICH candidates they consider.
fn train_grid(
    base: &TMShape,
    train: &Dataset,
    valid: &Dataset,
    space: &SearchSpace,
    mut consume: impl FnMut(f64, usize, TMModel),
) {
    for &clauses in &space.clause_grid {
        for &t in &space.t_grid {
            // T must stay attainable for the clause budget.
            if t >= clauses as i32 / 2 {
                continue;
            }
            for &s in &space.s_grid {
                for &n_states in &space.n_states_grid {
                    let mut shape = base.clone();
                    shape.clauses = clauses;
                    shape.t = t;
                    shape.s = s;
                    shape.n_states = n_states;
                    let model =
                        crate::trainer::train_model(&shape, train, space.epochs, space.seed);
                    let accuracy = reference::accuracy(&model, &valid.xs, &valid.ys);
                    let instructions = crate::isa::instruction_count(&model);
                    consume(accuracy, instructions, model);
                }
            }
        }
    }
}

/// Exhaustive grid search; returns all trials sorted by score (best
/// first) and the winning model.
pub fn grid_search(
    base: &TMShape,
    train: &Dataset,
    valid: &Dataset,
    space: &SearchSpace,
) -> (Vec<Trial>, TMModel) {
    let mut trials = Vec::new();
    let mut best: Option<(f64, TMModel)> = None;
    train_grid(base, train, valid, space, |accuracy, instructions, model| {
        let score = accuracy
            - space.size_weight * instructions as f64 / model.shape.total_tas() as f64;
        trials.push(Trial {
            t: model.shape.t,
            s: model.shape.s,
            clauses: model.shape.clauses,
            accuracy,
            instructions,
            score,
        });
        if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
            best = Some((score, model));
        }
    });
    trials.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let model = best.expect("non-empty grid").1;
    (trials, model)
}

/// One candidate of a budget-constrained search: the trial plus its
/// fitted-deployment cost and whether the budget admits it.
#[derive(Debug, Clone)]
pub struct BudgetedTrial {
    pub t: i32,
    pub s: f64,
    pub clauses: usize,
    pub accuracy: f64,
    pub instructions: usize,
    /// Resource cost of the candidate deployed at fitted memory depths
    /// ([`fitted_config`]).
    pub estimate: ResourceEstimate,
    pub watts: f64,
    /// Compressed include-list size ([`compressed_model_bytes`]) — the
    /// byte axis the budget's `max_model_bytes` is checked against.
    pub model_bytes: u32,
    pub admitted: bool,
}

/// Outcome of [`budget_search`]: every candidate costed against the
/// budget, plus the winner — the most *accurate* admitted model,
/// smaller instruction stream breaking ties (it is faster and cheaper
/// on the accelerator).  `winner` is `None` when nothing fits.
#[derive(Debug)]
pub struct BudgetedSearch {
    /// All candidates, sorted by accuracy (best first).
    pub trials: Vec<BudgetedTrial>,
    pub winner: Option<TMModel>,
}

/// Budget-constrained shape search (the autotuner's shadow retrain):
/// train every grid point of `space`, cost each candidate's *fitted*
/// deployment through the resource and energy models, and pick the most
/// accurate model that the budget admits.  Unlike [`grid_search`] the
/// constraint is an explicit resource frontier, not a soft size
/// penalty — the paper's runtime model-size tuning with the LUT/BRAM/
/// energy wall made first-class.
pub fn budget_search(
    base: &TMShape,
    train: &Dataset,
    valid: &Dataset,
    space: &SearchSpace,
    budget: &ResourceBudget,
) -> BudgetedSearch {
    let mut trials: Vec<BudgetedTrial> = Vec::new();
    let mut best: Option<(f64, usize, TMModel)> = None; // (acc, instrs, model)
    train_grid(base, train, valid, space, |accuracy, instructions, model| {
        let cfg = fitted_config(&model);
        let est = estimate(&cfg);
        let watts = EnergyModel::for_config(&cfg).watts;
        let model_bytes = compressed_model_bytes(&model);
        let admitted = budget.admits_model(&est, watts, model_bytes);
        trials.push(BudgetedTrial {
            t: model.shape.t,
            s: model.shape.s,
            clauses: model.shape.clauses,
            accuracy,
            instructions,
            estimate: est,
            watts,
            model_bytes,
            admitted,
        });
        if admitted
            && best
                .as_ref()
                .map(|(a, i, _)| accuracy > *a || (accuracy == *a && instructions < *i))
                .unwrap_or(true)
        {
            best = Some((accuracy, instructions, model));
        }
    });
    trials.sort_by(|a, b| b.accuracy.partial_cmp(&a.accuracy).unwrap());
    BudgetedSearch { trials, winner: best.map(|(_, _, m)| m) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;

    fn data() -> (Dataset, Dataset) {
        let d = SynthSpec::new(16, 2, 512).noise(0.08).seed(7).generate();
        d.split(0.75)
    }

    #[test]
    fn search_returns_sorted_trials() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let (trials, _model) = grid_search(&shape, &train, &valid, &SearchSpace::around(&shape));
        assert!(!trials.is_empty());
        for w in trials.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn winner_is_accurate() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let (trials, model) = grid_search(&shape, &train, &valid, &SearchSpace::around(&shape));
        let acc = reference::accuracy(&model, &valid.xs, &valid.ys);
        assert!(acc >= trials[0].accuracy - 1e-9);
        assert!(acc > 0.85, "winner acc {acc}");
    }

    #[test]
    #[should_panic(expected = "non-empty grid")]
    fn unattainable_t_filtered_leaves_empty_grid() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let space = SearchSpace {
            t_grid: vec![100], // unattainable for any clause budget here
            s_grid: vec![3.0],
            clause_grid: vec![10],
            n_states_grid: vec![128],
            epochs: 1,
            seed: 1,
            size_weight: 0.0,
        };
        let _ = grid_search(&shape, &train, &valid, &space);
    }

    #[test]
    fn size_penalty_prefers_smaller_at_equal_accuracy() {
        let t = Trial { t: 4, s: 3.0, clauses: 10, accuracy: 0.9, instructions: 100, score: 0.0 };
        let big = Trial { instructions: 1000, ..t.clone() };
        let w = 0.05;
        let total = 640.0;
        let score_small = t.accuracy - w * t.instructions as f64 / total;
        let score_big = big.accuracy - w * big.instructions as f64 / total;
        assert!(score_small > score_big);
    }

    #[test]
    fn budget_search_unlimited_picks_most_accurate() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let space = SearchSpace::around(&shape);
        let out = budget_search(&shape, &train, &valid, &space, &ResourceBudget::unlimited());
        assert!(!out.trials.is_empty());
        assert!(out.trials.iter().all(|t| t.admitted));
        for w in out.trials.windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy);
        }
        let winner = out.winner.expect("unlimited budget always has a winner");
        let acc = reference::accuracy(&winner, &valid.xs, &valid.ys);
        assert!((acc - out.trials[0].accuracy).abs() < 1e-12);
    }

    #[test]
    fn budget_search_winner_respects_budget() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let space = SearchSpace::around(&shape);
        // A frontier tight enough to exclude at least the deepest
        // candidates but loose enough to admit the smallest.
        let budget = ResourceBudget::unlimited().with_brams(14).with_watts(0.36);
        let out = budget_search(&shape, &train, &valid, &space, &budget);
        if let Some(winner) = &out.winner {
            let cfg = fitted_config(winner);
            let est = estimate(&cfg);
            let watts = EnergyModel::for_config(&cfg).watts;
            assert!(budget.admits(&est, watts));
        }
        // The admitted flag matches a recomputed admission check.
        for t in &out.trials {
            assert_eq!(t.admitted, budget.admits_model(&t.estimate, t.watts, t.model_bytes));
        }
    }

    #[test]
    fn budget_search_model_byte_axis_trades_accuracy_for_size() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let space = SearchSpace::around(&shape);
        let open = budget_search(&shape, &train, &valid, &space, &ResourceBudget::unlimited());
        // Cap at the median candidate's compressed size: some candidates
        // must fall out, and the winner's include-list bytes must fit.
        let mut sizes: Vec<u32> = open.trials.iter().map(|t| t.model_bytes).collect();
        sizes.sort_unstable();
        let cap = sizes[sizes.len() / 2];
        let budget = ResourceBudget::unlimited().with_model_bytes(cap);
        let out = budget_search(&shape, &train, &valid, &space, &budget);
        assert!(out.trials.iter().any(|t| !t.admitted) || sizes.iter().all(|&s| s <= cap));
        for t in &out.trials {
            assert_eq!(t.admitted, t.model_bytes <= cap);
            assert_eq!(t.model_bytes, t.instructions as u32 * 2);
        }
        if let Some(winner) = &out.winner {
            assert!(compressed_model_bytes(winner) <= cap);
            // The byte-capped winner can never beat the open winner.
            let open_acc = open.trials[0].accuracy;
            let capped_acc = reference::accuracy(winner, &valid.xs, &valid.ys);
            assert!(capped_acc <= open_acc + 1e-12);
        }
    }

    #[test]
    fn depth_axis_sweeps_every_memory_depth() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let mut space = SearchSpace::around(&shape);
        space.epochs = 1;
        let depths = space.n_states_grid.clone();
        assert_eq!(depths, vec![shape.n_states / 2, shape.n_states]);
        let two = budget_search(&shape, &train, &valid, &space, &ResourceBudget::unlimited());
        space.n_states_grid = vec![shape.n_states];
        let one = budget_search(&shape, &train, &valid, &space, &ResourceBudget::unlimited());
        // Every (clauses, t, s) point is trained once per depth.
        assert_eq!(two.trials.len(), 2 * one.trials.len());
        // The winner carries the depth it was trained at, so a swap
        // installs the searched memory depth, not the deployed one.
        let winner = two.winner.expect("unlimited budget always has a winner");
        assert!(depths.contains(&winner.shape.n_states));
    }

    #[test]
    fn budget_search_impossible_budget_has_no_winner() {
        let shape = crate::TMShape::synthetic(16, 2, 10);
        let (train, valid) = data();
        let mut space = SearchSpace::around(&shape);
        space.epochs = 1;
        let budget = ResourceBudget::unlimited().with_luts(1);
        let out = budget_search(&shape, &train, &valid, &space, &budget);
        assert!(out.winner.is_none());
        assert!(out.trials.iter().all(|t| !t.admitted));
    }
}
