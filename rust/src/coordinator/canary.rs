//! Per-replica canary evaluation: the staged half of a model swap.
//!
//! MATADOR and the online-learning FPGA architecture (PAPERS.md) stage
//! model updates through a validation path before committing; this
//! module is that path at serving scale.  A candidate model is
//! programmed onto exactly one replica ([`ServiceHandle::program_canary`]
//! — the pool keeps serving, live traffic is routed away from the
//! canary), then a configurable fraction of each observed window is
//! *mirrored*: the same sampled rows are answered by a baseline replica
//! and by the canary, producing one [`PairedWindow`] of
//! margins/accuracy/agreement per window.  A sequential comparison over
//! the paired windows yields a [`CanaryVerdict`]:
//!
//! * **Promote** — the candidate wins; broadcast it to the whole pool
//!   ([`ServiceHandle::promote_canary`], one fence).
//! * **Reject** — the candidate loses; reprogram the lone canary back
//!   ([`ServiceHandle::dismiss_canary`]).  A bad candidate is never
//!   served from more than one replica, and never to live traffic.
//! * **Extend** — keep mirroring; the evidence is not decisive yet.
//!
//! Windows judge on labeled accuracy when labels are available and on
//! **T-normalized confidence margins** when they are not (margins scale
//! with a model's threshold T, so raw margins are not comparable across
//! candidate shapes — the label-free canary compares margin/T).

use super::server::{ServeError, ServiceHandle, Telemetry};

/// Canary comparison knobs.
#[derive(Debug, Clone)]
pub struct CanaryConfig {
    /// Fraction of each observed window mirrored to the canary (strided
    /// sampling, deterministic).  Clamped to (0, 1].
    pub mirror_fraction: f64,
    /// Paired windows required before a unanimous early verdict.
    pub min_windows: usize,
    /// Forced (majority) verdict at this many paired windows.
    pub max_windows: usize,
    /// Label-free win rule: candidate mean margin/T must reach this
    /// fraction of the baseline's mean margin/T.
    pub margin_frac: f64,
    /// Labeled win rule: candidate accuracy must be within this of the
    /// baseline's (or better).
    pub accuracy_eps: f64,
    /// Baseline model's threshold T (margin normalization).
    pub baseline_t: i32,
    /// Candidate model's threshold T (margin normalization).
    pub candidate_t: i32,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        CanaryConfig {
            mirror_fraction: 0.25,
            min_windows: 2,
            max_windows: 6,
            margin_frac: 0.9,
            accuracy_eps: 0.02,
            baseline_t: 1,
            candidate_t: 1,
        }
    }
}

/// Sequential-comparison outcome after a paired window.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum CanaryVerdict {
    /// Candidate wins: broadcast it to the pool.
    Promote,
    /// Candidate loses: reprogram the lone canary back.
    Reject,
    /// Not decisive yet: keep mirroring.
    Extend,
}

impl CanaryVerdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            CanaryVerdict::Promote => "promote",
            CanaryVerdict::Reject => "reject",
            CanaryVerdict::Extend => "extend",
        }
    }
}

/// One mirrored window: the same sampled rows answered by a baseline
/// replica and by the canary.
#[derive(Debug, Clone)]
pub struct PairedWindow {
    /// Mirrored (sampled) rows in this window.
    pub samples: usize,
    /// Baseline mean confidence margin, normalized by the baseline
    /// model's T.
    pub baseline_margin: f64,
    /// Candidate mean confidence margin, normalized by the candidate
    /// model's T.
    pub candidate_margin: f64,
    /// Labeled-window accuracies (None when the window is unlabeled).
    pub baseline_accuracy: Option<f64>,
    pub candidate_accuracy: Option<f64>,
    /// Fraction of mirrored rows where both models predicted the same
    /// class.
    pub agreement: f64,
    /// Did the candidate win this window (labeled rule when labels
    /// exist, normalized-margin rule otherwise)?
    pub candidate_wins: bool,
}

/// Drives one canary evaluation: mirrors windows, accumulates
/// [`PairedWindow`]s, and renders the sequential verdict.  Owns nothing
/// but a [`ServiceHandle`] — every probe rides the pool's supervised
/// request path, exactly like live traffic.
pub struct CanaryController {
    handle: ServiceHandle,
    cfg: CanaryConfig,
    windows: Vec<PairedWindow>,
}

impl CanaryController {
    pub fn new(handle: ServiceHandle, cfg: CanaryConfig) -> Self {
        CanaryController { handle, cfg, windows: Vec::new() }
    }

    /// Paired windows accumulated so far.
    pub fn windows(&self) -> &[PairedWindow] {
        &self.windows
    }

    /// Mirror one observed window: stride-sample `mirror_fraction` of
    /// `xs`, answer the sample on a baseline replica AND on the canary,
    /// record the paired comparison, and return it with the running
    /// sequential verdict.  `ys` (when present) must be row-aligned
    /// with `xs`.
    pub fn observe(
        &mut self,
        xs: &[Vec<u8>],
        ys: Option<&[usize]>,
    ) -> Result<(PairedWindow, CanaryVerdict), ServeError> {
        check_labels(xs, ys)?;
        let (sample_xs, sample_ys) = stride_sample(xs, ys, self.cfg.mirror_fraction);
        let base = self.handle.infer_telemetry(sample_xs.clone())?;
        let cand = self.handle.infer_telemetry_canary(sample_xs)?;
        Ok(self.record(base.preds, base.margins, &cand, sample_ys))
    }

    /// Like [`Self::observe`], but reuse baseline answers the caller
    /// already holds for the FULL window (the autotuner's monitor
    /// telemetry, served by a baseline replica moments earlier —
    /// inference is deterministic and the fence keeps every baseline
    /// replica on one model, so the stride-sampled subset is exactly
    /// what a fresh probe would return).  Only the canary half costs a
    /// pool round-trip.
    pub fn observe_with_baseline(
        &mut self,
        xs: &[Vec<u8>],
        ys: Option<&[usize]>,
        baseline: &Telemetry,
    ) -> Result<(PairedWindow, CanaryVerdict), ServeError> {
        check_labels(xs, ys)?;
        if baseline.preds.len() != xs.len() || baseline.margins.len() != xs.len() {
            return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                rows: xs.len(),
                reason: "baseline telemetry does not match window rows",
            }));
        }
        let (sample_xs, sample_ys) = stride_sample(xs, ys, self.cfg.mirror_fraction);
        let stride = stride_for(self.cfg.mirror_fraction);
        let base_preds: Vec<usize> = baseline.preds.iter().step_by(stride).copied().collect();
        let base_margins: Vec<i32> = baseline.margins.iter().step_by(stride).copied().collect();
        let cand = self.handle.infer_telemetry_canary(sample_xs)?;
        Ok(self.record(base_preds, base_margins, &cand, sample_ys))
    }

    /// Shared tail of both observe flavours: compute the paired
    /// comparison, record it, return it with the running verdict.
    fn record(
        &mut self,
        base_preds: Vec<usize>,
        base_margins: Vec<i32>,
        cand: &Telemetry,
        sample_ys: Option<Vec<usize>>,
    ) -> (PairedWindow, CanaryVerdict) {
        let norm = |margins: &[i32], t: i32| {
            margins.iter().map(|&m| m as f64).sum::<f64>() / margins.len().max(1) as f64
                / t.max(1) as f64
        };
        let baseline_margin = norm(&base_margins, self.cfg.baseline_t);
        let candidate_margin = norm(&cand.margins, self.cfg.candidate_t);
        let accuracy = |preds: &[usize]| {
            sample_ys.as_ref().map(|ys| {
                preds.iter().zip(ys).filter(|(p, y)| p == y).count() as f64
                    / preds.len().max(1) as f64
            })
        };
        let baseline_accuracy = accuracy(&base_preds);
        let candidate_accuracy = accuracy(&cand.preds);
        let agreement = base_preds
            .iter()
            .zip(&cand.preds)
            .filter(|(a, b)| a == b)
            .count() as f64
            / base_preds.len().max(1) as f64;
        let candidate_wins = match (baseline_accuracy, candidate_accuracy) {
            (Some(b), Some(c)) => c >= b - self.cfg.accuracy_eps,
            // A non-positive baseline margin is degenerate (fully
            // collapsed or single-class baseline): `0 >= frac * 0`
            // would mark ANY zero-margin candidate a winner, so demand
            // strictly positive candidate confidence instead.
            _ if baseline_margin <= 0.0 => candidate_margin > 0.0,
            _ => candidate_margin >= self.cfg.margin_frac * baseline_margin,
        };
        let window = PairedWindow {
            samples: base_preds.len(),
            baseline_margin,
            candidate_margin,
            baseline_accuracy,
            candidate_accuracy,
            agreement,
            candidate_wins,
        };
        self.windows.push(window.clone());
        (window, self.verdict())
    }

    /// The running sequential verdict over the accumulated paired
    /// windows (see [`sequential_verdict`]).
    pub fn verdict(&self) -> CanaryVerdict {
        sequential_verdict(&self.windows, self.cfg.min_windows, self.cfg.max_windows)
    }

    /// Consume the controller, returning its paired windows (for the
    /// autotune report / JSON persistence).
    pub fn into_windows(self) -> Vec<PairedWindow> {
        self.windows
    }
}

/// The sequential comparison over a paired-window record — a pure
/// function of the record and the window bounds:
///
/// * fewer than `min_windows` windows → Extend (never decide on a
///   single noisy window);
/// * at `min_windows`+ with a unanimous record → early Promote /
///   Reject;
/// * at `max_windows` → forced majority verdict (ties reject: a
///   candidate that cannot beat the incumbent does not ship);
/// * otherwise → Extend.
pub fn sequential_verdict(
    windows: &[PairedWindow],
    min_windows: usize,
    max_windows: usize,
) -> CanaryVerdict {
    let n = windows.len();
    if n < min_windows.max(1) {
        return CanaryVerdict::Extend;
    }
    let wins = windows.iter().filter(|w| w.candidate_wins).count();
    let losses = n - wins;
    if losses == 0 {
        return CanaryVerdict::Promote;
    }
    if wins == 0 {
        return CanaryVerdict::Reject;
    }
    if n >= max_windows.max(min_windows) {
        if wins > losses {
            CanaryVerdict::Promote
        } else {
            CanaryVerdict::Reject
        }
    } else {
        CanaryVerdict::Extend
    }
}

fn check_labels(xs: &[Vec<u8>], ys: Option<&[usize]>) -> Result<(), ServeError> {
    if let Some(ys) = ys {
        if ys.len() != xs.len() {
            return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                rows: xs.len(),
                reason: "window labels do not match rows",
            }));
        }
    }
    Ok(())
}

/// The sampling stride for a mirror fraction: every k-th row where
/// k = ceil(1/fraction), so the effective mirrored fraction is
/// 1/k <= fraction — the knob is an upper bound on the evaluation
/// load, never exceeded (round() would mirror 100% of every window
/// for any fraction above 2/3).
fn stride_for(fraction: f64) -> usize {
    let fraction = fraction.clamp(f64::MIN_POSITIVE, 1.0);
    (1.0 / fraction).ceil().max(1.0) as usize
}

/// Deterministic strided sample of `fraction` of the rows (and the
/// matching labels).  Stride sampling spreads the mirror across the
/// window instead of taking a prefix, so the pair sees the same
/// temporal mix the pool does.
fn stride_sample(
    xs: &[Vec<u8>],
    ys: Option<&[usize]>,
    fraction: f64,
) -> (Vec<Vec<u8>>, Option<Vec<usize>>) {
    let stride = stride_for(fraction);
    let sample_xs: Vec<Vec<u8>> = xs.iter().step_by(stride).cloned().collect();
    let sample_ys = ys.map(|ys| ys.iter().step_by(stride).copied().collect());
    (sample_xs, sample_ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(wins: bool) -> PairedWindow {
        PairedWindow {
            samples: 8,
            baseline_margin: 10.0,
            candidate_margin: if wins { 11.0 } else { 2.0 },
            baseline_accuracy: None,
            candidate_accuracy: None,
            agreement: 0.5,
            candidate_wins: wins,
        }
    }

    #[test]
    fn sequential_verdict_table_driven() {
        // The verdict is a pure function of the window record — no pool
        // needed.  (window record, min, max, expected)
        let cases: &[(&[bool], usize, usize, CanaryVerdict)] = &[
            (&[], 2, 6, CanaryVerdict::Extend),
            (&[true], 2, 6, CanaryVerdict::Extend),
            (&[false], 2, 6, CanaryVerdict::Extend),
            (&[true, true], 2, 6, CanaryVerdict::Promote),
            (&[false, false], 2, 6, CanaryVerdict::Reject),
            (&[true, false], 2, 6, CanaryVerdict::Extend),
            (&[true, false, true, true], 2, 6, CanaryVerdict::Extend),
            // Forced majority at max_windows.
            (&[true, false, true, true, false, true], 2, 6, CanaryVerdict::Promote),
            (&[true, false, false, true, false, false], 2, 6, CanaryVerdict::Reject),
            // A tie at the cap rejects: the candidate must BEAT the
            // incumbent to ship.
            (&[true, false, true, false, true, false], 2, 6, CanaryVerdict::Reject),
            // min_windows = 1 allows a one-window unanimous verdict.
            (&[true], 1, 6, CanaryVerdict::Promote),
            (&[false], 1, 6, CanaryVerdict::Reject),
        ];
        for (record, min, max, expect) in cases {
            let windows: Vec<PairedWindow> = record.iter().map(|&w| window(w)).collect();
            assert_eq!(
                sequential_verdict(&windows, *min, *max),
                *expect,
                "record {record:?} min {min} max {max}"
            );
        }
    }

    #[test]
    fn stride_sampling_is_deterministic_and_label_aligned() {
        let xs: Vec<Vec<u8>> = (0..16u8).map(|i| vec![i; 4]).collect();
        let ys: Vec<usize> = (0..16).collect();
        let (sx, sy) = stride_sample(&xs, Some(&ys), 0.25);
        assert_eq!(sx.len(), 4);
        let sy = sy.unwrap();
        assert_eq!(sy, vec![0, 4, 8, 12]);
        for (x, &y) in sx.iter().zip(&sy) {
            assert_eq!(x[0] as usize, y, "rows and labels must stay paired");
        }
        // Fraction 1.0 mirrors everything; tiny fractions still sample
        // at least one row.
        let (all, _) = stride_sample(&xs, None, 1.0);
        assert_eq!(all.len(), 16);
        let (one, _) = stride_sample(&xs, None, 0.01);
        assert_eq!(one.len(), 1);
        // The fraction is an UPPER bound: 0.7 must not mirror 100%
        // (ceil stride 2 -> effective 0.5), and never exceeds the knob.
        let (most, _) = stride_sample(&xs, None, 0.7);
        assert_eq!(most.len(), 8);
    }
}
