//! Per-replica canary evaluation: the staged half of a model swap.
//!
//! MATADOR and the online-learning FPGA architecture (PAPERS.md) stage
//! model updates through a validation path before committing; this
//! module is that path at serving scale.  A candidate model is
//! programmed onto exactly one replica ([`ServiceHandle::program_canary`]
//! — the pool keeps serving, live traffic is routed away from the
//! canary), then a configurable fraction of each observed window is
//! *mirrored*: the same sampled rows are answered by a baseline replica
//! and by the canary, producing one [`PairedWindow`] of
//! margins/accuracy/agreement per window.  A sequential comparison over
//! the paired windows yields a [`CanaryVerdict`]:
//!
//! * **Promote** — the candidate wins; broadcast it to the whole pool
//!   ([`ServiceHandle::promote_canary`], one fence).
//! * **Reject** — the candidate loses; reprogram the lone canary back
//!   ([`ServiceHandle::dismiss_canary`]).  A bad candidate is never
//!   served from more than one replica, and never to live traffic.
//! * **Extend** — keep mirroring; the evidence is not decisive yet.
//!
//! Windows judge on labeled accuracy when labels are available and on
//! **T-normalized confidence margins** when they are not (margins scale
//! with a model's threshold T, so raw margins are not comparable across
//! candidate shapes — the label-free canary compares margin/T).
//!
//! Under the multi-tenant registry a canary is just another routed
//! model: build the controller on a route-scoped handle
//! ([`ServiceHandle::with_model`]) and the staged candidate, its
//! mirrors and its verdict touch that tenant's replicas only.  K
//! controllers on K routes evaluate K candidates concurrently
//! (multi-canary) with no extra machinery.

use super::server::{ServeError, ServiceHandle, Telemetry};

/// Canary comparison knobs.
#[derive(Debug, Clone)]
pub struct CanaryConfig {
    /// Expected fraction of each observed window mirrored to the canary
    /// (seeded hash-of-row sampling, deterministic; at least one row is
    /// always mirrored).  Clamped to [0, 1].
    pub mirror_fraction: f64,
    /// Paired windows required before a unanimous early verdict.
    pub min_windows: usize,
    /// Forced (majority) verdict at this many paired windows.
    pub max_windows: usize,
    /// Label-free win rule: candidate mean margin/T must reach this
    /// fraction of the baseline's mean margin/T.
    pub margin_frac: f64,
    /// Labeled win rule: candidate accuracy must be within this of the
    /// baseline's (or better).
    pub accuracy_eps: f64,
    /// Baseline model's threshold T (margin normalization).
    pub baseline_t: i32,
    /// Candidate model's threshold T (margin normalization).
    pub candidate_t: i32,
    /// Base seed of the per-window hash-of-row sampling (mixed with the
    /// window ordinal, so repeated identical windows mirror
    /// different-but-deterministic subsets).
    pub sample_seed: u64,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        CanaryConfig {
            mirror_fraction: 0.25,
            min_windows: 2,
            max_windows: 6,
            margin_frac: 0.9,
            accuracy_eps: 0.02,
            baseline_t: 1,
            candidate_t: 1,
            sample_seed: 0xC0FF_EE5E_ED,
        }
    }
}

/// Sequential-comparison outcome after a paired window.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum CanaryVerdict {
    /// Candidate wins: broadcast it to the pool.
    Promote,
    /// Candidate loses: reprogram the lone canary back.
    Reject,
    /// Not decisive yet: keep mirroring.
    Extend,
}

impl CanaryVerdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            CanaryVerdict::Promote => "promote",
            CanaryVerdict::Reject => "reject",
            CanaryVerdict::Extend => "extend",
        }
    }
}

/// One mirrored window: the same sampled rows answered by a baseline
/// replica and by the canary.
#[derive(Debug, Clone)]
pub struct PairedWindow {
    /// Mirrored (sampled) rows in this window.
    pub samples: usize,
    /// Baseline mean confidence margin, normalized by the baseline
    /// model's T.
    pub baseline_margin: f64,
    /// Candidate mean confidence margin, normalized by the candidate
    /// model's T.
    pub candidate_margin: f64,
    /// Labeled-window accuracies (None when the window is unlabeled).
    pub baseline_accuracy: Option<f64>,
    pub candidate_accuracy: Option<f64>,
    /// Fraction of mirrored rows where both models predicted the same
    /// class.
    pub agreement: f64,
    /// Did the candidate win this window (labeled rule when labels
    /// exist, normalized-margin rule otherwise)?
    pub candidate_wins: bool,
}

/// Drives one canary evaluation: mirrors windows, accumulates
/// [`PairedWindow`]s, and renders the sequential verdict.  Owns nothing
/// but a [`ServiceHandle`] — every probe rides the pool's supervised
/// request path, exactly like live traffic.
pub struct CanaryController {
    handle: ServiceHandle,
    cfg: CanaryConfig,
    windows: Vec<PairedWindow>,
}

impl CanaryController {
    pub fn new(handle: ServiceHandle, cfg: CanaryConfig) -> Self {
        CanaryController { handle, cfg, windows: Vec::new() }
    }

    /// Paired windows accumulated so far.
    pub fn windows(&self) -> &[PairedWindow] {
        &self.windows
    }

    /// The seed of the NEXT paired window's hash sample: the config's
    /// base seed mixed with the window ordinal, so two identical
    /// windows mirror different-but-deterministic subsets.
    fn window_sample_seed(&self) -> u64 {
        window_seed(self.cfg.sample_seed, self.windows.len() as u64)
    }

    /// Materialize this window's mirrored sample: the selected row
    /// indices plus the cloned rows and gathered labels.  One site, so
    /// the labeled and baseline-reuse observe paths can never sample
    /// differently.
    fn sample_window(
        &self,
        xs: &[Vec<u8>],
        ys: Option<&[usize]>,
    ) -> (Vec<usize>, Vec<Vec<u8>>, Option<Vec<usize>>) {
        let idxs = hash_sample_indices(xs, self.cfg.mirror_fraction, self.window_sample_seed());
        let sample_xs: Vec<Vec<u8>> = idxs.iter().map(|&i| xs[i].clone()).collect();
        let sample_ys: Option<Vec<usize>> =
            ys.map(|ys| idxs.iter().map(|&i| ys[i]).collect());
        (idxs, sample_xs, sample_ys)
    }

    /// Mirror one observed window: hash-sample `mirror_fraction` of
    /// `xs` (seeded FxHash-style mix of the packed row bytes — see
    /// [`hash_sample_indices`]), answer the sample on a baseline
    /// replica AND on the canary, record the paired comparison, and
    /// return it with the running sequential verdict.  `ys` (when
    /// present) must be row-aligned with `xs`.
    pub fn observe(
        &mut self,
        xs: &[Vec<u8>],
        ys: Option<&[usize]>,
    ) -> Result<(PairedWindow, CanaryVerdict), ServeError> {
        check_labels(xs, ys)?;
        let (_idxs, sample_xs, sample_ys) = self.sample_window(xs, ys);
        // Both halves of a paired window are control traffic: the
        // canary mirror is Critical by construction, and the baseline
        // probe rides at High so a saturated pool cannot starve one
        // side of the comparison and wedge the verdict.
        let base = self
            .handle
            .infer_telemetry_class(sample_xs.clone(), super::admission::Priority::High)?;
        let cand = self.handle.infer_telemetry_canary(sample_xs)?;
        Ok(self.record(base.preds, base.margins, &cand, sample_ys))
    }

    /// Like [`Self::observe`], but reuse baseline answers the caller
    /// already holds for the FULL window (the autotuner's monitor
    /// telemetry, served by a baseline replica moments earlier —
    /// inference is deterministic and the fence keeps every baseline
    /// replica on one model, so the hash-sampled subset is exactly
    /// what a fresh probe would return).  Only the canary half costs a
    /// pool round-trip.
    pub fn observe_with_baseline(
        &mut self,
        xs: &[Vec<u8>],
        ys: Option<&[usize]>,
        baseline: &Telemetry,
    ) -> Result<(PairedWindow, CanaryVerdict), ServeError> {
        check_labels(xs, ys)?;
        if baseline.preds.len() != xs.len() || baseline.margins.len() != xs.len() {
            return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                rows: xs.len(),
                reason: "baseline telemetry does not match window rows",
            }));
        }
        let (idxs, sample_xs, sample_ys) = self.sample_window(xs, ys);
        let base_preds: Vec<usize> = idxs.iter().map(|&i| baseline.preds[i]).collect();
        let base_margins: Vec<i32> = idxs.iter().map(|&i| baseline.margins[i]).collect();
        let cand = self.handle.infer_telemetry_canary(sample_xs)?;
        Ok(self.record(base_preds, base_margins, &cand, sample_ys))
    }

    /// Shared tail of both observe flavours: compute the paired
    /// comparison, record it, return it with the running verdict.
    fn record(
        &mut self,
        base_preds: Vec<usize>,
        base_margins: Vec<i32>,
        cand: &Telemetry,
        sample_ys: Option<Vec<usize>>,
    ) -> (PairedWindow, CanaryVerdict) {
        let norm = |margins: &[i32], t: i32| {
            margins.iter().map(|&m| m as f64).sum::<f64>() / margins.len().max(1) as f64
                / t.max(1) as f64
        };
        let baseline_margin = norm(&base_margins, self.cfg.baseline_t);
        let candidate_margin = norm(&cand.margins, self.cfg.candidate_t);
        let accuracy = |preds: &[usize]| {
            sample_ys.as_ref().map(|ys| {
                preds.iter().zip(ys).filter(|(p, y)| p == y).count() as f64
                    / preds.len().max(1) as f64
            })
        };
        let baseline_accuracy = accuracy(&base_preds);
        let candidate_accuracy = accuracy(&cand.preds);
        let agreement = base_preds
            .iter()
            .zip(&cand.preds)
            .filter(|(a, b)| a == b)
            .count() as f64
            / base_preds.len().max(1) as f64;
        let candidate_wins = match (baseline_accuracy, candidate_accuracy) {
            (Some(b), Some(c)) => c >= b - self.cfg.accuracy_eps,
            // A non-positive baseline margin is degenerate (fully
            // collapsed or single-class baseline): `0 >= frac * 0`
            // would mark ANY zero-margin candidate a winner, so demand
            // strictly positive candidate confidence instead.
            _ if baseline_margin <= 0.0 => candidate_margin > 0.0,
            _ => candidate_margin >= self.cfg.margin_frac * baseline_margin,
        };
        let window = PairedWindow {
            samples: base_preds.len(),
            baseline_margin,
            candidate_margin,
            baseline_accuracy,
            candidate_accuracy,
            agreement,
            candidate_wins,
        };
        self.windows.push(window.clone());
        (window, self.verdict())
    }

    /// The running sequential verdict over the accumulated paired
    /// windows (see [`sequential_verdict`]).
    pub fn verdict(&self) -> CanaryVerdict {
        sequential_verdict(&self.windows, self.cfg.min_windows, self.cfg.max_windows)
    }

    /// Consume the controller, returning its paired windows (for the
    /// autotune report / JSON persistence).
    pub fn into_windows(self) -> Vec<PairedWindow> {
        self.windows
    }
}

/// The sequential comparison over a paired-window record — a pure
/// function of the record and the window bounds:
///
/// * fewer than `min_windows` windows → Extend (never decide on a
///   single noisy window);
/// * at `min_windows`+ with a unanimous record → early Promote /
///   Reject;
/// * at `max_windows` → forced majority verdict (ties reject: a
///   candidate that cannot beat the incumbent does not ship);
/// * otherwise → Extend.
pub fn sequential_verdict(
    windows: &[PairedWindow],
    min_windows: usize,
    max_windows: usize,
) -> CanaryVerdict {
    let n = windows.len();
    if n < min_windows.max(1) {
        return CanaryVerdict::Extend;
    }
    let wins = windows.iter().filter(|w| w.candidate_wins).count();
    let losses = n - wins;
    if losses == 0 {
        return CanaryVerdict::Promote;
    }
    if wins == 0 {
        return CanaryVerdict::Reject;
    }
    if n >= max_windows.max(min_windows) {
        if wins > losses {
            CanaryVerdict::Promote
        } else {
            CanaryVerdict::Reject
        }
    } else {
        CanaryVerdict::Extend
    }
}

fn check_labels(xs: &[Vec<u8>], ys: Option<&[usize]>) -> Result<(), ServeError> {
    if let Some(ys) = ys {
        if ys.len() != xs.len() {
            return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                rows: xs.len(),
                reason: "window labels do not match rows",
            }));
        }
    }
    Ok(())
}

/// FxHash-style mix of one packed row's bytes under `seed`: the
/// multiply-rotate byte fold FxHash uses, with a murmur-style final
/// avalanche so short rows still spread over the full 64-bit space.
fn mix_row(seed: u64, row: &[u8]) -> u64 {
    const FX_K: u64 = 0x517c_c1b7_2722_0a95;
    let mut h = seed ^ 0x9e37_79b9_7f4a_7c15;
    for &b in row {
        h = (h.rotate_left(5) ^ b as u64).wrapping_mul(FX_K);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^ (h >> 33)
}

/// One splitmix64 step: derives a window's sampling seed from the base
/// seed and the window ordinal.
fn window_seed(base: u64, window: u64) -> u64 {
    let mut z = base.wrapping_add(window.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic hash-of-row sample: row `r` is mirrored when the
/// seeded mix of its packed bytes lands in the bottom `fraction` of
/// the hash space.  Replaces the old deterministic strides, which
/// mirrored the IDENTICAL subset every time a window repeated — a
/// periodic workload could park the same rows on the canary forever.
/// Hashing makes the subset a pseudo-random function of (seed, row
/// bytes): still fully deterministic and replayable, but two identical
/// windows under different window seeds mirror different subsets, and
/// duplicate rows within a window stand or fall together.  At least
/// one row (the minimum-hash row) is always mirrored so a paired
/// window can never be empty.
fn hash_sample_indices(xs: &[Vec<u8>], fraction: f64, seed: u64) -> Vec<usize> {
    let fraction = fraction.clamp(0.0, 1.0);
    let threshold = (fraction * u64::MAX as f64) as u64;
    let idxs: Vec<usize> = (0..xs.len())
        .filter(|&r| mix_row(seed, &xs[r]) <= threshold)
        .collect();
    if idxs.is_empty() && !xs.is_empty() {
        let r = (0..xs.len())
            .min_by_key(|&r| mix_row(seed, &xs[r]))
            .expect("non-empty window");
        return vec![r];
    }
    idxs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(wins: bool) -> PairedWindow {
        PairedWindow {
            samples: 8,
            baseline_margin: 10.0,
            candidate_margin: if wins { 11.0 } else { 2.0 },
            baseline_accuracy: None,
            candidate_accuracy: None,
            agreement: 0.5,
            candidate_wins: wins,
        }
    }

    #[test]
    fn sequential_verdict_table_driven() {
        // The verdict is a pure function of the window record — no pool
        // needed.  (window record, min, max, expected)
        let cases: &[(&[bool], usize, usize, CanaryVerdict)] = &[
            (&[], 2, 6, CanaryVerdict::Extend),
            (&[true], 2, 6, CanaryVerdict::Extend),
            (&[false], 2, 6, CanaryVerdict::Extend),
            (&[true, true], 2, 6, CanaryVerdict::Promote),
            (&[false, false], 2, 6, CanaryVerdict::Reject),
            (&[true, false], 2, 6, CanaryVerdict::Extend),
            (&[true, false, true, true], 2, 6, CanaryVerdict::Extend),
            // Forced majority at max_windows.
            (&[true, false, true, true, false, true], 2, 6, CanaryVerdict::Promote),
            (&[true, false, false, true, false, false], 2, 6, CanaryVerdict::Reject),
            // A tie at the cap rejects: the candidate must BEAT the
            // incumbent to ship.
            (&[true, false, true, false, true, false], 2, 6, CanaryVerdict::Reject),
            // min_windows = 1 allows a one-window unanimous verdict.
            (&[true], 1, 6, CanaryVerdict::Promote),
            (&[false], 1, 6, CanaryVerdict::Reject),
        ];
        for (record, min, max, expect) in cases {
            let windows: Vec<PairedWindow> = record.iter().map(|&w| window(w)).collect();
            assert_eq!(
                sequential_verdict(&windows, *min, *max),
                *expect,
                "record {record:?} min {min} max {max}"
            );
        }
    }

    #[test]
    fn hash_sampling_differs_across_identical_windows_but_stays_deterministic() {
        // The ROADMAP item this replaces strides for: two IDENTICAL
        // windows must mirror different-but-deterministic subsets, so a
        // periodic workload cannot park the same rows on the canary
        // forever.  Subsets pinned for the default base seed.
        let xs: Vec<Vec<u8>> = (0..32u8).map(|i| vec![i; 8]).collect();
        let base = CanaryConfig::default().sample_seed;
        let w0 = hash_sample_indices(&xs, 0.25, window_seed(base, 0));
        let w1 = hash_sample_indices(&xs, 0.25, window_seed(base, 1));
        assert_eq!(w0, vec![1, 3, 4, 6, 7, 24, 25, 30]);
        assert_eq!(w1, vec![4, 10, 11, 22, 25, 28, 30]);
        assert_ne!(w0, w1, "identical windows must not mirror identical subsets");
        // Deterministic: the same (rows, fraction, seed) replays the
        // same subset.
        assert_eq!(w0, hash_sample_indices(&xs, 0.25, window_seed(base, 0)));
        // The subset is a function of the ROW BYTES, not the position:
        // duplicate rows stand or fall together.
        let dup = vec![xs[1].clone(), xs[2].clone(), xs[1].clone()];
        let picked = hash_sample_indices(&dup, 0.25, window_seed(base, 0));
        assert_eq!(picked, vec![0, 2], "both copies of a sampled row are sampled");
    }

    #[test]
    fn hash_sampling_covers_the_fraction_extremes() {
        let xs: Vec<Vec<u8>> = (0..32u8).map(|i| vec![i; 8]).collect();
        let base = CanaryConfig::default().sample_seed;
        // Fraction 1.0 mirrors everything, in window order.
        let all = hash_sample_indices(&xs, 1.0, window_seed(base, 0));
        assert_eq!(all, (0..32).collect::<Vec<_>>());
        // A vanishing fraction still mirrors at least one row (the
        // minimum-hash row), deterministically.
        let one = hash_sample_indices(&xs, 1e-9, window_seed(base, 0));
        assert_eq!(one.len(), 1);
        assert_eq!(one, hash_sample_indices(&xs, 1e-9, window_seed(base, 0)));
        // Indices are always in-range and strictly increasing (label
        // alignment relies on it).
        let sub = hash_sample_indices(&xs, 0.5, window_seed(base, 3));
        assert!(sub.windows(2).all(|w| w[0] < w[1]));
        assert!(sub.iter().all(|&i| i < xs.len()));
        assert!(!sub.is_empty());
    }
}
