//! Model registry: the identity layer of the multi-model serving
//! platform.
//!
//! A [`ModelRegistry`] owns every model a replica pool can serve, keyed
//! by [`ModelId`].  Registration deduplicates by `(name, content hash)`
//! — the FNV-1a-64 digest of the model's canonical `.rttm` v1 wire
//! bytes ([`crate::tm::serialize::content_hash`]) scoped to the tenant
//! name — so one tenant registering the same trained model twice hands
//! back the existing id instead of burning a replica partition on a
//! duplicate, while two tenants registering byte-identical bytes stay
//! isolated under distinct ids.  Entries carry deployment
//! metadata: a human-readable name, the content hash, and an optional
//! per-model [`ResourceBudget`] (the frontier an autotuner scoped to
//! this model must respect).
//!
//! The registry is pure bookkeeping — it never touches replicas.  The
//! serving half (per-replica assignment, sharding policies, reprogram
//! fences) lives in [`super::server`], which embeds a registry inside
//! its versioned model cell and re-exposes it through
//! `ServiceHandle::register_model` / `retire_model`.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use crate::model_cost::resources::ResourceBudget;
use crate::tm::model::TMModel;
use crate::tm::serialize::content_hash;

/// Opaque route key for one registered model.
///
/// `ModelId::DEFAULT` (id 0) is reserved for the single-model
/// compatibility wrappers: a plain `ServiceHandle` routes everything —
/// programs, requests, canaries — at the default model, which is why
/// pools that never call `register_model` behave exactly like the
/// pre-registry single-model pool.  Freshly registered models get ids
/// from 1 up; ids are never reused, even after `retire`.
#[derive(Debug, Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelId(pub u64);

impl ModelId {
    /// The single-model compatibility route (see type docs).
    pub const DEFAULT: ModelId = ModelId(0);
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.0)
    }
}

/// One registered model: the shared trained artifact plus its
/// deployment metadata.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub id: ModelId,
    /// Deployment name (tenant/application label) — distinct from the
    /// model's internal shape name, which tracks architecture.
    pub name: String,
    /// FNV-1a-64 over the model's canonical v1 wire bytes.
    pub content_hash: u64,
    pub model: Arc<TMModel>,
    /// Optional per-model resource frontier for scoped autotuners.
    pub budget: Option<ResourceBudget>,
}

/// What [`ModelRegistry::register`] did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegisterOutcome {
    pub id: ModelId,
    /// True when the SAME tenant (same name) had already registered an
    /// identical model (same content hash) and `id` names that existing
    /// entry.  Dedup never spans names: two tenants registering
    /// byte-identical bytes get distinct ids, so a retrain/promote on
    /// one can never rewrite the other's serving model.
    pub deduped: bool,
    /// The entry's registered name.  On a dedup hit this is the
    /// existing entry's name, so callers (`spawn_pool_sharded`,
    /// `rttm serve --models`) can surface the true duplicate to the
    /// operator instead of silently aliasing.
    pub name: String,
}

/// Id-ordered model table with per-name content-hash dedup.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    entries: BTreeMap<ModelId, ModelEntry>,
    /// Next fresh id; starts at 1 (0 is [`ModelId::DEFAULT`]).
    next: u64,
}

impl ModelRegistry {
    pub fn new() -> Self {
        ModelRegistry { entries: BTreeMap::new(), next: 1 }
    }

    /// Register `model` under a fresh id, or hand back the existing id
    /// when THIS name already registered the same content.  The dedup
    /// key is `(name, content_hash)`: hashing alone would alias two
    /// tenants that happen to register byte-identical models onto one
    /// id, merging their budgets/counters and letting a retrain swap on
    /// one tenant silently mutate the other's serving model.
    pub fn register(&mut self, name: &str, model: Arc<TMModel>) -> RegisterOutcome {
        let hash = content_hash(&model);
        if let Some(e) = self
            .entries
            .values()
            .find(|e| e.content_hash == hash && e.name == name)
        {
            return RegisterOutcome { id: e.id, deduped: true, name: e.name.clone() };
        }
        let id = ModelId(self.next);
        self.next += 1;
        self.entries.insert(
            id,
            ModelEntry {
                id,
                name: name.to_string(),
                content_hash: hash,
                model,
                budget: None,
            },
        );
        RegisterOutcome { id, deduped: false, name: name.to_string() }
    }

    /// Upsert by id — no dedup.  This is the primitive behind scoped
    /// `program()`: installing new content under an existing route
    /// (promote, retrain swap) replaces the model but keeps the entry's
    /// registered name and budget.  Returns true when `id` was new.
    pub fn install(&mut self, id: ModelId, name_hint: &str, model: Arc<TMModel>) -> bool {
        let hash = content_hash(&model);
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.model = model;
                e.content_hash = hash;
                false
            }
            None => {
                self.next = self.next.max(id.0 + 1);
                self.entries.insert(
                    id,
                    ModelEntry {
                        id,
                        name: name_hint.to_string(),
                        content_hash: hash,
                        model,
                        budget: None,
                    },
                );
                true
            }
        }
    }

    /// Remove a model; true if it was present.  Its id is never reused.
    pub fn retire(&mut self, id: ModelId) -> bool {
        self.entries.remove(&id).is_some()
    }

    pub fn get(&self, id: ModelId) -> Option<&ModelEntry> {
        self.entries.get(&id)
    }

    pub fn model(&self, id: ModelId) -> Option<Arc<TMModel>> {
        self.entries.get(&id).map(|e| Arc::clone(&e.model))
    }

    pub fn contains(&self, id: ModelId) -> bool {
        self.entries.contains_key(&id)
    }

    pub fn name_of(&self, id: ModelId) -> Option<&str> {
        self.entries.get(&id).map(|e| e.name.as_str())
    }

    /// Registered ids in ascending order (the rebalance partition order).
    pub fn ids(&self) -> Vec<ModelId> {
        self.entries.keys().copied().collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Attach (or clear) a per-model resource budget; false if `id` is
    /// unknown.
    pub fn set_budget(&mut self, id: ModelId, budget: Option<ResourceBudget>) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.budget = budget;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TMShape;

    fn model(tag: u8) -> Arc<TMModel> {
        let mut m = TMModel::empty(TMShape::synthetic(4, 2, 4));
        m.set_include(0, 0, usize::from(tag) % 8, true);
        Arc::new(m)
    }

    #[test]
    fn register_allocates_sequential_ids_from_one() {
        let mut reg = ModelRegistry::new();
        let a = reg.register("a", model(0));
        let b = reg.register("b", model(1));
        assert_eq!(a.id, ModelId(1));
        assert_eq!(b.id, ModelId(2));
        assert!(!a.deduped && !b.deduped);
        assert_eq!(reg.ids(), vec![ModelId(1), ModelId(2)]);
    }

    #[test]
    fn register_dedups_identical_content_within_one_name() {
        let mut reg = ModelRegistry::new();
        let first = reg.register("orig", model(3));
        let dup = reg.register("orig", model(3));
        assert_eq!(dup.id, first.id);
        assert!(dup.deduped);
        assert_eq!(dup.name, "orig", "dedup surfaces the existing entry's name");
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.name_of(first.id), Some("orig"));
    }

    #[test]
    fn register_never_dedups_across_tenant_names() {
        // The PR-7 aliasing bug: hash-only dedup handed tenant B
        // tenant A's id for byte-identical bytes, so installs on A
        // rewrote B's serving model.  `(name, hash)` keeps them apart.
        let mut reg = ModelRegistry::new();
        let a = reg.register("tenant-a", model(3));
        let b = reg.register("tenant-b", model(3));
        assert_ne!(a.id, b.id, "identical bytes under two names must not alias");
        assert!(!b.deduped);
        assert_eq!((a.name.as_str(), b.name.as_str()), ("tenant-a", "tenant-b"));
        assert_eq!(reg.len(), 2);
        // An install (retrain swap) on A leaves B's entry untouched.
        let b_hash = reg.get(b.id).unwrap().content_hash;
        assert!(!reg.install(a.id, "tenant-a", model(4)));
        assert_eq!(reg.get(b.id).unwrap().content_hash, b_hash);
        assert_ne!(reg.get(a.id).unwrap().content_hash, b_hash);
        // ... and same-name dedup still works afterwards: A's content
        // changed, so re-registering A's ORIGINAL bytes is a fresh id,
        // while B's bytes under B's name dedup onto B.
        assert!(reg.register("tenant-b", model(3)).deduped);
        assert!(!reg.register("tenant-a", model(3)).deduped);
    }

    #[test]
    fn retired_ids_are_never_reused() {
        let mut reg = ModelRegistry::new();
        let a = reg.register("a", model(0)).id;
        assert!(reg.retire(a));
        assert!(!reg.retire(a));
        let b = reg.register("a-again", model(0)).id;
        assert_eq!(b, ModelId(2), "retired id 1 must not be recycled");
    }

    #[test]
    fn install_upserts_without_dedup_and_keeps_metadata() {
        let mut reg = ModelRegistry::new();
        assert!(reg.install(ModelId::DEFAULT, "default", model(0)));
        assert!(reg.set_budget(ModelId::DEFAULT, Some(ResourceBudget::unlimited())));
        // Re-install under the same id: content changes, name and
        // budget survive, no new entry.
        assert!(!reg.install(ModelId::DEFAULT, "ignored", model(1)));
        let e = reg.get(ModelId::DEFAULT).unwrap();
        assert_eq!(e.name, "default");
        assert!(e.budget.is_some());
        assert_eq!(reg.len(), 1);
        // Fresh ids still start above any installed id.
        assert_eq!(reg.register("next", model(2)).id, ModelId(1));
    }

    #[test]
    fn model_id_display_and_default() {
        assert_eq!(ModelId::DEFAULT.to_string(), "m0");
        assert_eq!(ModelId(7).to_string(), "m7");
    }

    #[test]
    fn set_budget_on_unknown_id_is_false() {
        let mut reg = ModelRegistry::new();
        assert!(!reg.set_budget(ModelId(9), None));
    }
}
