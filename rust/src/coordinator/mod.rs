//! L3 coordinator: the deployed system of Fig 8.
//!
//! One process owns:
//! * an [`service::InferenceService`] wrapping the accelerator (base,
//!   single- or multi-core simulator) and its stream programming port;
//! * a [`tuner::TrainingNode`] — the "Raspberry-Pi class" local trainer,
//!   which executes the AOT-compiled JAX train step through PJRT
//!   (Python never runs here) or the native rust trainer;
//! * the [`tuner::RecalibrationLoop`] that watches live accuracy and
//!   reprograms the accelerator with a freshly trained model when drift
//!   degrades it — the paper's headline runtime-tunability story;
//! * a replica-pool [`server`] front-end: N worker threads, each owning
//!   an `InferenceService` replica, fed through the [`admission`]
//!   front-end — four priority classes over per-class bounded queues
//!   with backpressure policies (block / reject / shed-oldest), sharded
//!   per-replica work queues with work stealing, deadline-aware
//!   admission, and an optional autoscaling supervisor — with versioned
//!   broadcast reprogramming (no inference ever observes a
//!   mixed-version pool) and panic supervision (a dying replica is
//!   respawned from the last-programmed model) — std primitives only;
//!   the offline toolchain has no tokio, and the request loop is the
//!   same shape;
//! * the [`autotune`] subsystem: a live drift-aware autotuner that runs
//!   against the pool *while it serves* — sliding-window telemetry with
//!   hysteresis (fully label-free if need be: margins trigger, delayed
//!   labels backfill), a budget-constrained shadow shape search on
//!   sustained drift, and staged swaps with rollback.  Policy code
//!   talks only to [`server::ServiceHandle`]; the old [`tuner`] loop is
//!   a thin offline wrapper over the same policy core;
//! * the [`canary`] gate: every autotune swap is first programmed onto
//!   exactly ONE replica, a fraction of live traffic is mirrored to it,
//!   and a sequential comparison over paired baseline-vs-candidate
//!   windows renders promote / reject / extend — a bad candidate is
//!   never served from more than one replica, and never to live
//!   traffic.

//! * the [`registry`] identity layer: every model a pool can serve is
//!   registered under a stable [`registry::ModelId`] (content-hash
//!   deduplicated, with per-model deployment metadata and resource
//!   budgets), every request carries a model route, and replicas hold
//!   per-model affinity under a [`server::ShardingPolicy`] — pinned
//!   (`Dedicated`) or affinity-aware with a reprogram-thrash dwell
//!   guard (`TimeShared`) — which turns the pool into a multi-tenant
//!   serving platform; autotuners and canary controllers become
//!   per-model instances simply by holding a route-scoped handle.

pub mod admission;
pub mod autotune;
pub mod canary;
pub mod hyperparam;
pub mod registry;
pub mod server;
pub mod service;
pub mod tuner;

pub use admission::{
    AdmissionConfig, AdmissionStats, AutoscaleConfig, ClassStats, Fault, FaultPlan,
    IntegrityConfig, IntegrityStats, ModelCounters, ModelStats, PoolConfig, Priority, ShedPolicy,
};
pub use autotune::{
    AutotuneConfig, AutotuneEvent, AutotuneReport, Autotuner, CanaryOutcome, DriftDetector,
    WindowStats,
};
pub use canary::{CanaryConfig, CanaryController, CanaryVerdict, PairedWindow};
pub use registry::{ModelEntry, ModelId, ModelRegistry, RegisterOutcome};
pub use server::{
    spawn, spawn_pool, spawn_pool_cfg, spawn_pool_sharded, PoolJoin, PoolStats, ReplicaStats,
    ServeError, ServerStats, ServiceHandle, ShardingPolicy, Telemetry,
};
pub use service::{Engine, EngineSpec, InferenceService, Metrics};
pub use tuner::{RecalReport, RecalibrationLoop, TrainBackend, TrainingNode};
